"""Batched serving example: prefill a batch of prompts, decode with slot
reuse (a minimal continuous-batching loop over the batch-static step).

    PYTHONPATH=src python examples/serve_batch.py --arch llama3.2-1b
"""

import argparse

import numpy as np

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--admission-slots", type=int, default=4)
    args = ap.parse_args()

    out = serve(
        args.arch, smoke=True, batch=args.batch,
        prompt_len=args.prompt_len, gen_len=args.gen,
        admission_slots=args.admission_slots,
    )
    toks = out["tokens"]
    print(f"[serve_batch] generated {toks.shape[0]} sequences x "
          f"{toks.shape[1]} tokens")
    print(f"[serve_batch] prefill {out['prefill_seconds'] * 1e3:.0f} ms, "
          f"{out['decode_seconds_per_token'] * 1e3:.1f} ms/token, "
          f"{out['throughput_tok_s']:.0f} tok/s")
    for i, row in enumerate(toks[: min(4, len(toks))]):
        print(f"  seq{i}: {np.array2string(row[:12])}...")
    if "admission" in out:
        adm = out["admission"]
        print(f"[serve_batch] admitted via {adm['slot_key']} "
              f"(fence token {adm['fence_token']}); "
              f"lock-table RDMA ops on the serving host: {adm['local_rdma_ops']}")


if __name__ == "__main__":
    main()
