"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps with checkpointing + resume (deliverable (b)'s e2e driver).

    PYTHONPATH=src python examples/train_lm.py              # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --tiny       # CI-scale
    PYTHONPATH=src python examples/train_lm.py --resume     # restart demo

The ~100M config is the llama3.2-1b family at reduced width/depth (same
block structure, GQA ratio and tied embeddings).  On one CPU device this is
minutes/step at the full setting — use --tiny for a fast demonstration; the
flag changes scale only, not code paths.
"""

import argparse

from repro.configs import RunConfig, ShapeConfig, get_config
from repro.launch.train import train
from repro.models import Model, param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.tiny:
        overrides = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                         d_ff=128, vocab_size=512)
        steps = args.steps or 30
        shape = ShapeConfig("e2e", seq_len=64, global_batch=8, kind="train")
    else:
        # ~100M params: 12L, d=768, untouched llama3.2 structure otherwise.
        overrides = dict(num_layers=12, d_model=768, num_heads=12,
                         num_kv_heads=4, d_ff=2048, vocab_size=32768)
        steps = args.steps or 200
        shape = ShapeConfig("e2e", seq_len=256, global_batch=16, kind="train")

    import repro.configs.llama32_1b as base

    cfg = base.CONFIG.with_overrides(**overrides)
    n = param_count(Model(cfg).specs())
    print(f"[train_lm] model: {n / 1e6:.1f}M params, {steps} steps")

    # register a transient arch the driver can look up
    import repro.configs as configs

    configs._MODULES["_train_lm"] = "llama32_1b"
    orig_get = configs.get_config

    def patched(arch, smoke=False):
        if arch == "_train_lm":
            return cfg
        return orig_get(arch, smoke)

    configs.get_config = patched
    import repro.launch.train as train_mod

    train_mod.get_config = patched

    out = train(
        "_train_lm",
        smoke=False,
        steps=steps,
        shape=shape,
        run=RunConfig(
            learning_rate=6e-4, warmup_steps=max(10, steps // 20),
            total_steps=steps, checkpoint_every=max(10, steps // 4),
            checkpoint_dir=args.ckpt_dir,
        ),
        resume=args.resume,
        log_every=max(1, steps // 20),
    )
    losses = [h["loss"] for h in out["history"]]
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training failed to reduce loss"


if __name__ == "__main__":
    main()
