"""Quickstart: train a small LM for 30 steps and watch the loss fall.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3.2-1b]

Uses the reduced ("smoke") config of any assigned architecture; runs on one
CPU device.  The same `train` entry point drives the production meshes.
"""

import argparse

from repro.configs import RunConfig, ShapeConfig
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    out = train(
        args.arch,
        smoke=True,
        steps=args.steps,
        shape=ShapeConfig("quickstart", seq_len=64, global_batch=8, kind="train"),
        run=RunConfig(
            learning_rate=1e-3, warmup_steps=5, total_steps=args.steps,
            checkpoint_every=10 ** 9, checkpoint_dir="/tmp/repro_quickstart",
        ),
        log_every=5,
    )
    losses = [h["loss"] for h in out["history"]]
    print(f"\nquickstart: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'LEARNING' if losses[-1] < losses[0] else 'NOT LEARNING'})")


if __name__ == "__main__":
    main()
