"""The sharded lock table in action: checkpoint-writer leases with fencing.

Four hosts run training shards over one sharded asymmetric lock table — each
host is the zero-fabric "local class" for its shard of the keyspace.  Every
epoch the hosts race for the writer lease; the holder **keepalives the lease
through the renewal fast path** while "writing" (a fencing-token-checked CAS
on the expiry register — zero RDMA ops when the writer is local to the key's
shard, exactly one rCAS when remote), then writes the checkpoint with its
fencing token.  At epoch 3 the winning writer *crashes* while holding the
lease: the lease expires instead of wedging the table, a new writer is
granted a larger fencing token, and the store rejects the zombie's late
write.  A batched multi-key acquire then updates several manifest entries
atomically, in the table's deadlock-free global key order — holding each
shard's ALock once per shard group.

A second act demos the **mode-aware stack**: a fleet of home-host readers
share one manifest key through SHARED leases — every join is a single
machine CAS, zero RDMA ops — while a remote writer periodically takes the
key EXCLUSIVE (the writer-intent barrier drains the cohort, bounding its
wait), printing the per-mode per-class operation costs at the end.

    PYTHONPATH=src python examples/lock_service.py
"""

import threading
import time
import traceback

from repro.coord import CoordinationService, LeaseMode

EPOCHS = 5
CRASH_EPOCH = 3
TTL = 0.15  # writer lease TTL: a crashed writer delays the job at most this
KEEPALIVES = 3  # fast-path renewals per checkpoint write


class CheckpointStore:
    """A fenced store: rejects writes whose token is older than the best seen
    (how a real block store survives a zombie writer, Lamport/Burrows style)."""

    def __init__(self):
        self.best_token = {}  # per checkpoint object: tokens are per-key
        self.writes = []
        self.rejected = []
        self._mu = threading.Lock()

    def write(self, epoch, host, token):
        with self._mu:
            if token < self.best_token.get(epoch, -1):
                self.rejected.append((epoch, host, token))
                return False
            self.best_token[epoch] = token
            self.writes.append((epoch, host, token))
            return True


def reader_fleet_demo():
    """N home-host readers at 0 RDMA ops alongside one remote writer.

    The readers live on the key's home host and join its reader cohort with
    single machine CASes (the paper's local class: the fabric is never
    touched).  The remote writer pays a bounded number of one-sided ops per
    exclusive grant, and its wait is bounded by the drain barrier no matter
    how hot the reader loop runs.
    """
    READERS = 3
    READS_EACH = 40
    WRITES = 3
    svc = CoordinationService(num_hosts=2, init_budget=3, num_shards=4)
    # A key homed on host 0: readers there are the zero-RDMA local class.
    key = next(f"manifest/hot/{i}" for i in range(10_000)
               if svc.home_of(f"manifest/hot/{i}") == 0)
    stats = {"reads": 0, "writes": 0, "writer_waits": []}
    mu = threading.Lock()
    stop = threading.Event()
    failures = []

    def reader(i):
        p = svc.host_process(0)  # home host: local class for `key`
        snap = p.counts.snapshot()
        n = 0
        while n < READS_EACH and not stop.is_set():
            lease = svc.try_acquire(p, key, ttl=0.5, mode=LeaseMode.SHARED)
            if lease is None:
                time.sleep(0.001)  # a writer holds (or drains) the key
                continue
            n += 1
            svc.release(p, lease)
        d = p.counts.delta(snap)
        assert d.rdma_ops == 0, f"home reader paid fabric ops: {vars(d)}"
        with mu:
            stats["reads"] += n

    def writer():
        p = svc.host_process(1)  # remote to the key's home shard
        for _ in range(WRITES):
            if stop.is_set():
                return
            t0 = time.monotonic()
            lease = svc.acquire(p, key, ttl=0.5, timeout=10.0)
            with mu:
                stats["writer_waits"].append(time.monotonic() - t0)
                stats["writes"] += 1
            time.sleep(0.002)  # "write" under the exclusive lease
            svc.release(p, lease)
            time.sleep(0.004)  # let the readers flood back in

    def run(fn, *args):
        try:
            fn(*args)
        except Exception:
            failures.append(traceback.format_exc())
            stop.set()

    ts = [threading.Thread(target=run, args=(reader, i))
          for i in range(READERS)] + [threading.Thread(target=run, args=(writer,))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not failures, "\n".join(failures)
    assert stats["reads"] == READERS * READS_EACH
    assert stats["writes"] == WRITES
    max_wait = max(stats["writer_waits"])
    assert max_wait < 5.0, f"writer starved by the reader flood: {max_wait}s"

    print("\nreader fleet (shared leases) vs one remote writer (exclusive):")
    print(f"  {stats['reads']} shared reads by {READERS} home readers, "
          f"{stats['writes']} exclusive writes; "
          f"writer max wait {max_wait * 1e3:.1f} ms (drain-bounded)")
    mode_totals = svc.table.mode_class_totals()
    print(f"  {'mode':>10} {'class':>6} {'rdma ops':>8} {'local ops':>9} "
          f"{'doorbells':>9}")
    for mode in LeaseMode:
        for cls, cname in ((0, "LOCAL"), (1, "REMOTE")):
            c = mode_totals[mode][cls]
            print(f"  {mode.label:>10} {cname:>6} {c.rdma_ops:>8} "
                  f"{c.local_ops:>9} {c.remote_doorbell:>9}")
    assert mode_totals[LeaseMode.SHARED][0].rdma_ops == 0
    assert mode_totals[LeaseMode.EXCLUSIVE][0].rdma_ops == 0
    rows = svc.telemetry()
    print(f"  shared joins: {sum(r['shared_joins'] for r in rows)}, "
          f"intent blocks (drain): {sum(r['intent_blocks'] for r in rows)}, "
          f"exclusive grants: {sum(r['grants_exclusive'] for r in rows)}")
    print("OK: home readers paid 0 RDMA ops; the remote writer drained the "
          "cohort within its bounded wait.")


def main():
    svc = CoordinationService(num_hosts=4, init_budget=3, num_shards=8)
    store = CheckpointStore()
    gate = threading.Barrier(4)  # epoch alignment between simulated hosts
    zombie = {}
    failures = []
    keepalives = []  # (host, key_home, renewals, rdma_delta, local_delta)
    keep_mu = threading.Lock()

    def writer_keepalive(p, h, epoch, lease):
        """Hold the writer lease alive through the renewal fast path while
        the checkpoint is 'written', and account its per-class cost."""
        snap = p.counts.snapshot()
        for _ in range(KEEPALIVES):
            lease = svc.renew(p, lease)
            assert lease is not None, "live writer lost its own lease"
        d = p.counts.delta(snap)
        home = svc.home_of(lease.key)
        if h == home:  # the paper's local class: renewals must be fabric-free
            assert d.rdma_ops == 0, vars(d)
        else:
            # Remote class: one rCAS per fast-path renewal.  A renewal can
            # legitimately fall to the (bounded) ALock slow path if a
            # scheduler stall eats the short demo TTL, so bound rather than
            # pin — the table prints the realised fast-path count below.
            assert KEEPALIVES <= d.rdma_ops <= 12 * KEEPALIVES, vars(d)
        with keep_mu:
            keepalives.append((h, home, KEEPALIVES, d.rdma_ops, d.local_ops))
        return lease

    def gate_wait():
        # Timeout so a dead peer breaks the barrier (BrokenBarrierError in
        # the survivors) instead of hanging the demo forever.
        gate.wait(timeout=15)

    def host(h):
        p = svc.host_process(h)
        for epoch in range(1, EPOCHS + 1):
            gate_wait()
            lease = svc.try_acquire(p, f"ckpt-writer/{epoch}", ttl=TTL)
            if lease is not None:
                if epoch == CRASH_EPOCH and not zombie:
                    # Crash while holding the lease: no release, write later.
                    zombie[epoch] = (h, lease)
                else:
                    # Keepalive while "writing": the renewal fast path keeps
                    # the slot alive without ever taking the shard ALock.
                    lease = writer_keepalive(p, h, epoch, lease)
                    assert store.write(epoch, h, lease.token)
            gate_wait()
            if epoch == CRASH_EPOCH and zombie.get(epoch, (None,))[0] == h:
                # The rest of the fleet waits out the TTL, re-elects, and a
                # new writer (larger fencing token) covers the epoch...
                time.sleep(TTL)
            gate_wait()
            if epoch == CRASH_EPOCH:
                zh, zlease = zombie[epoch]
                if h != zh:
                    retry = svc.try_acquire(p, f"ckpt-writer/{epoch}", ttl=TTL)
                    if retry is not None:
                        store.write(epoch, h, retry.token)
                elif h == zh:
                    time.sleep(TTL / 2)  # stay dead while others re-elect
            gate_wait()
            if epoch == CRASH_EPOCH and zombie.get(epoch, (None,))[0] == h:
                # ...and the zombie's late write must bounce off the fence.
                zh, zlease = zombie[epoch]
                assert not store.write(epoch, zh, zlease.token), "fencing failed"

        # Batched manifest update: every host updates its own 3 entries in
        # one all-or-nothing multi-key acquisition (deadlock-free order).
        keys = [f"manifest/host{h}/part{i}" for i in range(3)] + ["manifest/epoch"]
        leases = svc.acquire_batch(p, keys, ttl=5.0, timeout=30.0)
        assert svc.release_batch(p, leases) == len(leases)

    def run_host(h):
        try:
            host(h)
        except Exception:  # surface instead of hanging peers at the barrier
            failures.append((h, traceback.format_exc()))
            gate.abort()

    ts = [threading.Thread(target=run_host, args=(h,)) for h in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not failures, "host thread failed:\n" + "\n".join(tb for _, tb in failures)

    print("fenced checkpoint writes (epoch, host, token):")
    for row in store.writes:
        print("   ", row)
    print("rejected zombie writes:", store.rejected)
    epochs_written = sorted({e for e, _, _ in store.writes})
    assert epochs_written == list(range(1, EPOCHS + 1)), epochs_written
    assert store.rejected, "the crashed writer's stale token was not exercised"

    print("\nwriter keepalives (renewal fast path; per-class op cost):")
    print(f"  {'host':>4} {'key home':>8} {'renewals':>8} {'rdma ops':>8} "
          f"{'local ops':>9}  class")
    for h, home, n, rdma, local in sorted(keepalives):
        cls = "LOCAL (0 RDMA)" if h == home else "REMOTE (1 rCAS each)"
        print(f"  {h:>4} {home:>8} {n:>8} {rdma:>8} {local:>9}  {cls}")
    assert keepalives, "no writer exercised the keepalive loop"
    fast = sum(r["fast_renews"] for r in svc.telemetry())
    assert fast > 0, "no renewal rode the fast path"
    print(f"  table fast-path renewals: {fast} (no shard ALock taken)")

    print("\nper-shard telemetry (home host is the zero-RDMA local class):")
    print(f"  {'shard':>5} {'home':>4} {'keys':>4} {'grants':>6} "
          f"{'local rdma':>10} {'remote rdma':>11}")
    for row in svc.telemetry():
        print(f"  {row['shard']:>5} {row['home_host']:>4} {row['keys']:>4} "
              f"{row['grants']:>6} {row['local'].rdma_ops:>10} "
              f"{row['remote'].rdma_ops:>11}")
        assert row["local"].rdma_ops == 0, "local class must never touch the fabric"
    print("\nOK: one fenced writer per epoch; a crashed holder's lease expired "
          "instead of wedging the shard; local classes used 0 RDMA ops.")

    reader_fleet_demo()


if __name__ == "__main__":
    main()
