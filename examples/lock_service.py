"""The paper's primitive in action: an asymmetric lock service coordinating
checkpoint writers across simulated hosts.

Four hosts run training shards; host 0 owns the checkpoint store (the
"local class" — zero fabric operations), hosts 1-3 are remote.  Every epoch
each host tries to become the writer; the ALock + election guarantee exactly
one writer with the per-class optimal cost the paper proves.

    PYTHONPATH=src python examples/lock_service.py
"""

import threading
import time

from repro.coord import CoordinationService


def main():
    svc = CoordinationService(num_hosts=4, init_budget=3)
    results = {}
    lock_stats = {}

    def host(h):
        p = svc.host_process(h)
        wins = []
        for epoch in range(1, 6):
            # simulate a training epoch
            time.sleep(0.01 * (1 + h % 2))
            if svc.elect("ckpt-writer", p, epoch=epoch, home_host=0):
                wins.append(epoch)
                time.sleep(0.005)  # "write the checkpoint"
        results[h] = wins
        lock_stats[h] = (p.counts.rdma_ops, p.counts.local_ops)

    ts = [threading.Thread(target=host, args=(h,)) for h in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()

    print("epoch winners per host:", results)
    all_wins = sorted(w for ws in results.values() for w in ws)
    assert all_wins == [1, 2, 3, 4, 5], "exactly one writer per epoch"
    print("\nper-host fabric cost (RDMA ops, local ops):")
    for h in range(4):
        r, l = lock_stats[h]
        cls = "LOCAL " if h == 0 else "remote"
        print(f"  host {h} [{cls}]: rdma={r:4d} local={l:4d}")
    assert lock_stats[0][0] == 0, "local host must never touch the fabric"
    print("\nOK: one writer/epoch; the store-owning host used 0 RDMA ops.")


if __name__ == "__main__":
    main()
