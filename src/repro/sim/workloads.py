"""Sim workloads: cooperative lock-table clients at 100× threaded scale.

Each client is a generator task on a :class:`~repro.sim.SimEngine`, driving a
:class:`~repro.coord.ShardedLockTable` built over a
:class:`~repro.sim.SimFabricMemory`.  Everything — key choice, backoff,
think time, the fabric's latency charges, the scheduler's tie-breaks — is
derived from the run's seed, so a config produces **byte-identical** results
every time: exact per-class RDMA/doorbell counts, exact grant/reject/expiry
tallies, and a virtual-time throughput with zero run-to-run dispersion.

Clients use the table's **non-blocking** operations (``try_acquire`` /
``renew`` / ``release``) and express waiting as generator yields, which is
the contract the engine's atomic-step model requires (see
``repro.sim.engine``); contention shows up as rejects + seeded exponential
backoff rather than thread preemption.

Workloads (mirroring, then extending, the threaded bench):

* ``home``     — each client draws only keys homed on its own host: the
  placement-aware layout.  Every operation is local-class; the run asserts
  the whole REMOTE class stays at zero ops.
* ``uniform``  — placement-oblivious uniform draws over the global keyspace.
* ``zipfian``  — Zipf(s)-skewed draws over the global keyspace: a handful of
  hot keys absorb most traffic.  Only feasible at simulated scale — at
  64×16 clients the hot keys see the contention regime the RDMA
  lock-service literature actually studies.
* ``failover`` — a hot key set with short TTLs where ``crash_prob`` of
  holders silently die mid-lease and later wake as zombies: leases expire,
  hundreds of contenders storm the freed keys, and the woken zombies try to
  renew with stale leases.  The run asserts every zombie renewal is fenced
  off and grant tokens never regress.
"""

from __future__ import annotations

import bisect
import random
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.coord import ShardedLockTable
from repro.coord.table import LOCAL, REMOTE

from .engine import SimEngine
from .fabric import FabricLatency, SimFabricMemory

__all__ = ["SIM_WORKLOADS", "KEYS_PER_HOST", "SimResult", "jain",
           "keys_by_home", "run_lock_table_sim"]

SIM_WORKLOADS = ("home", "uniform", "zipfian", "failover")

KEYS_PER_HOST = 8   # keyspace density; shared with the threaded bench
HOLD = 10e-6        # virtual seconds a lease is held
THINK = 5e-6        # virtual think time between transactions
BACKOFF = 20e-6     # initial reject backoff (doubles, capped)
BACKOFF_CAP = 2e-3


def jain(xs: List[int]) -> float:
    """Jain fairness index over per-client op counts (threaded + sim)."""
    xs = [x for x in xs if x >= 0]
    total = sum(xs)
    if total == 0:
        return 0.0
    return total * total / (len(xs) * sum(x * x for x in xs))


class _RunState:
    """Shared counters + safety invariants (steps are atomic: no locking)."""

    __slots__ = ("per_client", "total", "target", "last_token",
                 "token_regressions", "zombie_renews")

    def __init__(self, nclients: int, target: int):
        self.per_client = [0] * nclients
        self.total = 0
        self.target = target
        self.last_token: Dict[str, int] = {}
        self.token_regressions = 0
        self.zombie_renews = 0

    def done(self) -> bool:
        return self.total >= self.target

    def granted(self, idx: int, lease) -> None:
        self.per_client[idx] += 1
        self.total += 1
        prev = self.last_token.get(lease.key, 0)
        if lease.token <= prev:
            self.token_regressions += 1
        else:
            self.last_token[lease.key] = lease.token


# ------------------------------------------------------------- key pickers
def _zipf_picker(keys: List[str], s: float) -> Callable:
    """Zipf(s) over ``keys``: rank r drawn with weight 1/r^s (r = 1-based)."""
    cum, acc = [], 0.0
    for r in range(1, len(keys) + 1):
        acc += 1.0 / r ** s
        cum.append(acc)
    total = cum[-1]

    def pick(rng: random.Random) -> str:
        return keys[bisect.bisect_right(cum, rng.random() * total)]

    return pick


def keys_by_home(table: ShardedLockTable, num_hosts: int, per_host: int,
                 prefix: str = "home/",
                 strict: bool = True) -> Dict[int, List[str]]:
    """``per_host`` keys homed on each host, by stable-hash placement scan.

    Shared by the sim workloads and the threaded bench (one scanner, so the
    two modes cannot drift).  ``strict=True`` raises when a host owns no
    shard (the sim's home workload is meaningless then); ``strict=False``
    pads under-filled hosts with keys homed elsewhere — the threaded
    bench's shards<hosts baseline, where locality is impossible for them
    and that *is* the cost story being measured.
    """
    out: Dict[int, List[str]] = {h: [] for h in range(num_hosts)}
    pool: List[str] = []
    need = num_hosts * per_host
    for i in range(200 * need):
        if all(len(ks) >= per_host for ks in out.values()):
            break
        k = f"{prefix}{i}"
        pool.append(k)
        ks = out[table.home_of(k)]
        if len(ks) < per_host:
            ks.append(k)
    short = [h for h, ks in out.items() if len(ks) < per_host]
    if short and strict:
        raise ValueError(
            f"hosts {short} own no (or too few) shards — the home workload "
            f"needs num_shards >= num_hosts (got {table.num_shards} shards "
            f"for {num_hosts} hosts)"
        )
    for h in short:
        j = 0
        while len(out[h]) < per_host:
            out[h].append(pool[(h * per_host + j) % len(pool)])
            j += 1
    return out


# ------------------------------------------------------------ client tasks
def _acquire_release_client(table, p, rng, pick, st, idx, ttl):
    backoff = BACKOFF
    while not st.done():
        lease = table.try_acquire(p, pick(rng), ttl)
        if lease is None:
            yield backoff * (0.5 + rng.random())
            backoff = min(backoff * 2, BACKOFF_CAP)
            continue
        backoff = BACKOFF
        st.granted(idx, lease)
        yield HOLD
        table.release(p, lease)
        yield THINK


def _failover_client(table, p, rng, pick, st, idx, ttl, crash_prob):
    hold = min(HOLD, ttl / 8)
    backoff = ttl / 4
    while not st.done():
        lease = table.try_acquire(p, pick(rng), ttl)
        if lease is None:
            yield backoff * (0.5 + rng.random())
            backoff = min(backoff * 2, 8 * ttl)
            continue
        backoff = ttl / 4
        st.granted(idx, lease)
        if rng.random() < crash_prob:
            # Crash mid-lease: hold silently past expiry, then wake as a
            # zombie and try to renew the stale lease.  Fencing must reject
            # it — by then the expiry register is past-due (or re-granted
            # with a larger token), so the renewal can never stick.
            yield ttl * (1.5 + rng.random())
            if table.renew(p, lease) is not None:
                st.zombie_renews += 1
            yield ttl * rng.random()  # recovery pause before rejoining
            continue
        yield hold
        renewed = table.renew(p, lease)
        if renewed is not None:
            yield hold
            table.release(p, renewed)
        yield THINK


# ------------------------------------------------------------------ runner
@dataclass
class SimResult:
    """One deterministic sim run.  ``row()`` is the byte-stable record: it
    excludes wall-clock fields (and the live table), so two same-seed runs
    compare equal — the CI determinism gate diffs exactly these rows."""

    workload: str
    num_hosts: int
    clients_per_host: int
    num_shards: int
    seed: int
    target_ops: int
    ops: int
    virtual_seconds: float
    virtual_throughput: float
    jain: float
    grants: int
    rejects: int
    expirations: int
    fast_renews: int
    fast_releases: int
    repairs: int
    zombie_renews: int
    token_regressions: int
    cost: Dict[str, Dict[str, int]]
    events: int
    spins: int
    wall_seconds: float
    per_client: List[int] = field(repr=False)
    table: ShardedLockTable = field(repr=False)

    def row(self) -> Dict:
        drop = {"wall_seconds", "per_client", "table"}
        return {k: v for k, v in vars(self).items() if k not in drop}


def run_lock_table_sim(
    workload: str,
    num_hosts: int = 64,
    clients_per_host: int = 16,
    num_shards: Optional[int] = None,
    total_ops: int = 100_000,
    seed: int = 0,
    ttl: Optional[float] = None,
    latency: Optional[FabricLatency] = None,
    zipf_s: float = 0.99,
    keys_per_host: int = KEYS_PER_HOST,
    crash_prob: float = 0.1,
    max_events: Optional[int] = None,
) -> SimResult:
    """Run one workload to ``total_ops`` granted leases; fully deterministic.

    Returns exact per-class operation counts (``cost``) plus virtual-time
    throughput and fairness.  Raises if any safety invariant breaks: the
    LOCAL class must never issue an RDMA op, grant tokens must be strictly
    monotonic per key, and no zombie renewal may survive fencing.
    """
    if workload not in SIM_WORKLOADS:
        raise ValueError(f"unknown sim workload {workload!r}")
    wall0 = time.perf_counter()
    engine = SimEngine(seed)
    mem = SimFabricMemory(num_hosts, engine, latency or FabricLatency())
    table = ShardedLockTable(
        mem, num_shards=num_shards or 2 * num_hosts,
        clock=engine.clock, sleep=engine.sleep_inline, name=f"sim{seed}",
    )
    if ttl is None:
        ttl = 300e-6 if workload == "failover" else 1.0

    universe = [f"k/{i}" for i in range(num_hosts * keys_per_host)]
    if workload == "home":
        per_host = keys_by_home(table, num_hosts, keys_per_host)
        pick_for = lambda h: lambda rng: rng.choice(per_host[h])  # noqa: E731
    elif workload == "uniform":
        pick_for = lambda h: lambda rng: rng.choice(universe)  # noqa: E731
    elif workload == "zipfian":
        zipf = _zipf_picker(universe, zipf_s)
        pick_for = lambda h: zipf  # noqa: E731
    else:  # failover: everyone storms a small hot set
        hot = universe[: max(4, num_hosts)]
        pick_for = lambda h: lambda rng: rng.choice(hot)  # noqa: E731

    nclients = num_hosts * clients_per_host
    st = _RunState(nclients, total_ops)
    for idx in range(nclients):
        host = idx // clients_per_host
        p = mem.spawn(host)
        rng = random.Random(1_000_003 * seed + idx)
        pick = pick_for(host)
        if workload == "failover":
            task = _failover_client(table, p, rng, pick, st, idx, ttl,
                                    crash_prob)
        else:
            task = _acquire_release_client(table, p, rng, pick, st, idx, ttl)
        engine.spawn(task, delay=idx * 1e-7)  # deterministic arrival stagger

    engine.run(stop=st.done,
               max_events=max_events or (200 * total_ops + 500_000))
    wall = time.perf_counter() - wall0

    totals = table.class_totals()
    if totals[LOCAL].rdma_ops:
        raise AssertionError(
            f"{workload}: LOCAL class issued {totals[LOCAL].rdma_ops} RDMA ops"
        )
    if workload == "home" and totals[REMOTE].rdma_ops:
        raise AssertionError(
            f"home: placement-aware clients issued "
            f"{totals[REMOTE].rdma_ops} remote ops"
        )
    if st.token_regressions:
        raise AssertionError(
            f"{workload}: {st.token_regressions} fencing-token regressions"
        )
    if st.zombie_renews:
        raise AssertionError(
            f"{workload}: {st.zombie_renews} zombie renewals survived fencing"
        )

    rows = table.telemetry()
    vsec = engine.clock.now
    return SimResult(
        workload=workload,
        num_hosts=num_hosts,
        clients_per_host=clients_per_host,
        num_shards=table.num_shards,
        seed=seed,
        target_ops=total_ops,
        ops=st.total,
        virtual_seconds=vsec,
        virtual_throughput=st.total / max(vsec, 1e-12),
        jain=jain(st.per_client),
        grants=sum(r["grants"] for r in rows),
        rejects=sum(r["rejects"] for r in rows),
        expirations=sum(r["expirations"] for r in rows),
        fast_renews=sum(r["fast_renews"] for r in rows),
        fast_releases=sum(r["fast_releases"] for r in rows),
        repairs=sum(r["repairs"] for r in rows),
        zombie_renews=st.zombie_renews,
        token_regressions=st.token_regressions,
        cost={"local": vars(totals[LOCAL]).copy(),
              "remote": vars(totals[REMOTE]).copy()},
        events=engine.events,
        spins=engine.spins,
        wall_seconds=wall,
        per_client=st.per_client,
        table=table,
    )
