"""Sim workloads: cooperative lock-table clients at 100× threaded scale.

Each client is a generator task on a :class:`~repro.sim.SimEngine`, driving a
:class:`~repro.coord.ShardedLockTable` built over a
:class:`~repro.sim.SimFabricMemory`.  Everything — key choice, backoff,
think time, the fabric's latency charges, the scheduler's tie-breaks — is
derived from the run's seed, so a config produces **byte-identical** results
every time: exact per-class (and per-mode) RDMA/doorbell counts, exact
grant/reject/expiry tallies, and a virtual-time throughput with zero
run-to-run dispersion.

Clients use the table's **non-blocking** operations (``try_acquire`` /
``renew`` / ``release``) and express waiting as generator yields, which is
the contract the engine's atomic-step model requires (see
``repro.sim.engine``); contention shows up as rejects + seeded exponential
backoff rather than thread preemption.

Workloads (mirroring, then extending, the threaded bench):

* ``home``     — each client draws only keys homed on its own host: the
  placement-aware layout.  Every operation is local-class; the run asserts
  the whole REMOTE class stays at zero ops.
* ``uniform``  — placement-oblivious uniform draws over the global keyspace.
* ``zipfian``  — Zipf(s)-skewed draws over the global keyspace: a handful of
  hot keys absorb most traffic.  Only feasible at simulated scale — at
  64×16 clients the hot keys see the contention regime the RDMA
  lock-service literature actually studies.
* ``failover`` — a hot key set with short TTLs where ``crash_prob`` of
  holders silently die mid-lease and later wake as zombies: leases expire,
  hundreds of contenders storm the freed keys, and the woken zombies try to
  renew with stale leases.  The run asserts every zombie renewal is fenced
  off and grant tokens never regress.
* ``read_heavy`` — the mode-aware workload: a ``1 - write_frac`` fraction of
  transactions take SHARED leases (reader cohorts on the packed S/X word),
  the rest take EXCLUSIVE.  ``home_frac`` of each client's draws come from
  its own host's keys (zipfian within them — home readers are the paper's
  zero-RDMA class), the rest from a global zipfian (remote shared traffic,
  priced at one rCAS per join).  ``shared_reads=False`` degrades every
  reader to EXCLUSIVE — the before/after baseline for the read:write sweep.
* ``reader_flood`` — the writer-progress scenario: every client but one
  hammers ONE key with shared leases; the lone writer periodically needs an
  exclusive grant.  The run records each writer wait in virtual time and
  asserts the drain protocol bounds it (a saturating reader flood cannot
  starve a queued writer past ~a TTL).
* ``crash_restart`` — the recovery workload: ledger-writing clients
  (:class:`~repro.coord.RecoverableClient`) run a seeded mix of single-key,
  batch, and shared/upgrade transactions over a hot key set while a
  **crash reaper** kills every client task on a seeded schedule of hosts
  (:meth:`~repro.sim.SimEngine.kill` delivers :class:`ClientCrash` at the
  victims' next dispatch).  Each victim restarts after ``restart_delay``
  and — with ``reclaim=True`` — replays its ledger and reclaims its
  still-valid leases via the fencing-checked CAS; with ``reclaim=False``
  it rejoins amnesiac and the run measures the full-TTL wedge instead
  (the before/after pair the recovery benchmark reports).  Per-lease
  recovery latencies and per-restart recovery events are recorded in
  virtual time; fencing-token monotonicity is asserted throughout.
* ``home_death`` — the self-healing workload: every host runs a
  :class:`~repro.coord.HostMembership` heartbeat + monitor pair alongside
  its ledgered clients, and at a seeded instant one host **dies for good**
  — its memory drops off the fabric (``FabricFaults.fail_host``) and every
  one of its tasks is killed.  Surviving clients burn op-timeout retry
  budgets against the corpse (:class:`RemoteTimeout`), the suspicion
  estimators walk it ALIVE→SUSPECT→DEAD, and the rank-order successor runs
  the epoch-fenced takeover of every shard homed there.  The run then
  re-acquires every key of the dead home from the successor and asserts
  all of them re-homed with monotonic fencing tokens, and that the
  crash→takeover latency p99 stays under 5× the membership TTL.
* ``partition`` — the split-brain workload: a minority island of hosts is
  cut from the rest for a scheduled window.  Minority clients draw only
  majority-homed keys, so every acquire must cross the cut; the partition
  guard (quorum attestation with ``guard_ttl`` undercutting the detection
  floor) degrades the island before the majority can declare it dead.  The
  run asserts **zero grants landed on the minority side inside the
  window**, and that the guard actually blocked takeovers
  (``takeover_refusals``) rather than the window just being quiet.
* ``overload_storm`` — the overload workload: an **open-loop** paced
  arrival stream (mean interarrival ``STORM_INTERARRIVAL / offered_load``
  per client) over a zipfian keyspace, against a fabric whose per-host
  congestion model (``congest_capacity`` postings per window) makes excess
  load *cost latency*.  Every transaction carries an absolute deadline
  (``deadline_budget`` past its arrival) through the table's **blocking**
  ``acquire``: backoff sleeps are clamped to the remaining budget, a passed
  deadline raises the typed :class:`~repro.core.DeadlineExceeded`, and —
  with ``shedding=True`` — a deadline-infeasible retry is **shed**
  (:class:`~repro.core.Overloaded`) before it burns another posting.
  Three of four clients are EXCLUSIVE writers at priority 0 (sheddable);
  the fourth is a SHARED reader at priority 1 — the brownout contract the
  run records: reader goodput keeps flowing while writer load sheds.
  **Goodput** is the grants that landed inside their deadline; the bench
  sweeps ``offered_load`` 1x→10x and gates goodput retention, the non-shed
  acquire p99, and the shedding-ON vs shedding-OFF collapse.
"""

from __future__ import annotations

import bisect
import random
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.coord import (DEAD, AsyncClient, ClientCrash, FaultInjector,
                         HostMembership, InflationPolicy, LedgerStore,
                         OverloadPolicy, RecoverableClient, ShardedLockTable,
                         SuspicionPolicy)
from repro.coord.table import EXCLUSIVE, LOCAL, REMOTE, SHARED, LeaseMode
from repro.core import DeadlineExceeded, Overloaded, RemoteTimeout

from .engine import SimEngine
from .fabric import FabricFaults, FabricLatency, SimFabricMemory

__all__ = ["SIM_WORKLOADS", "KEYS_PER_HOST", "STORM_INTERARRIVAL",
           "SimResult", "jain", "keys_by_home", "run_lock_table_sim"]

SIM_WORKLOADS = ("home", "uniform", "zipfian", "failover", "read_heavy",
                 "reader_flood", "crash_restart", "home_death", "partition",
                 "overload_storm", "pipelined_read")

KEYS_PER_HOST = 8   # keyspace density; shared with the threaded bench
# overload_storm base (1x) mean interarrival.  A remote EXCLUSIVE
# transaction costs ~134us of virtual time end-to-end (4 acquire doorbells
# + release), so 450us paces 1x at ~30% per-client utilization — loaded
# enough to measure, far enough from saturation that queueing is benign.
STORM_INTERARRIVAL = 450e-6
HOLD = 10e-6        # virtual seconds a lease is held
THINK = 5e-6        # virtual think time between transactions
BACKOFF = 20e-6     # initial reject backoff (doubles, capped)
BACKOFF_CAP = 2e-3


def _pct(xs: List[float], q: float) -> float:
    """The q-quantile (nearest-rank) of ``xs``; 0.0 for an empty list."""
    if not xs:
        return 0.0
    ys = sorted(xs)
    return ys[min(len(ys) - 1, int(q * len(ys)))]


def jain(xs: List[int]) -> float:
    """Jain fairness index over per-client op counts (threaded + sim)."""
    xs = [x for x in xs if x >= 0]
    total = sum(xs)
    if total == 0:
        return 0.0
    return total * total / (len(xs) * sum(x * x for x in xs))


class _RunState:
    """Shared counters + safety invariants (steps are atomic: no locking).

    Token monotonicity is checked **per mode**: an EXCLUSIVE grant must
    carry a token strictly larger than every token seen for the key (the
    CS allocator never reuses one), while a SHARED grant carries its reader
    generation's token — the last CS-allocated one — so equality with the
    running maximum is legal but a *smaller* token is a regression.
    """

    __slots__ = ("per_client", "total", "target", "last_token",
                 "token_regressions", "zombie_renews", "reads",
                 "grants_by_mode", "writer_waits",
                 "crashes", "reclaims", "recovery_latencies",
                 "recovery_events", "hot_latencies", "hot_rcas",
                 "remote_timeouts", "crash_times", "detect_latencies",
                 "takeover_latencies", "failover_events",
                 "minority_grants", "minority", "window",
                 "offered", "goodput", "goodput_shared", "late_grants",
                 "shed_ops", "deadline_misses", "storm_latencies")

    def __init__(self, nclients: int, target: int):
        self.per_client = [0] * nclients
        self.total = 0
        self.target = target
        self.last_token: Dict[str, int] = {}
        self.token_regressions = 0
        self.zombie_renews = 0
        # Lease-free optimistic reads completed (PR 10).  They count
        # toward the ops target and fairness like grants do — each is one
        # client-visible operation — but carry no lease, so they must not
        # feed the per-key token-monotonicity check.
        self.reads = 0
        self.grants_by_mode = {SHARED: 0, EXCLUSIVE: 0}
        self.writer_waits: List[float] = []
        # Crash-recovery accounting (crash_restart workload).
        self.crashes = 0
        self.reclaims = 0
        self.recovery_latencies: List[float] = []
        # One entry per completed restart: [client idx, leases recovered].
        self.recovery_events: List[List[int]] = []
        # Failover accounting (home_death / partition workloads).
        self.remote_timeouts = 0            # client-visible retry exhaustions
        self.crash_times: Dict[int, float] = {}   # host -> scheduled death
        self.detect_latencies: List[float] = []   # death -> DEAD verdict
        self.takeover_latencies: List[float] = []  # death -> shard re-homed
        # One entry per committed takeover:
        # [t, dead host, shard, new epoch, leases intact, leases reset].
        self.failover_events: List[List] = []
        self.minority_grants = 0            # in-window grants on the island
        self.minority: Optional[frozenset] = None
        self.window: Optional[tuple] = None  # the partition (start, end)
        # Tracked-hot-key probes (zipfian workload): per-grant acquire
        # latency in virtual time, and the rCAS each REMOTE client paid
        # from first attempt to grant — the quantity inflation bounds.
        self.hot_latencies: List[float] = []
        self.hot_rcas: List[int] = []
        # Overload accounting (overload_storm workload).  ``offered`` is
        # arrivals, ``goodput`` the grants that landed inside their
        # deadline; sheds / deadline misses are the *client-observed*
        # refusals (the table keeps its own per-shard tallies).
        self.offered = 0
        self.goodput = 0
        self.goodput_shared = 0
        self.late_grants = 0        # granted, but past the caller deadline
        self.shed_ops = 0
        self.deadline_misses = 0
        self.storm_latencies: List[float] = []  # every grant's acquire time

    def done(self) -> bool:
        return self.total >= self.target

    def granted(self, idx: int, lease) -> None:
        self.per_client[idx] += 1
        self.total += 1
        self.grants_by_mode[lease.mode] += 1
        prev = self.last_token.get(lease.key, 0)
        if lease.token < prev or (lease.mode == EXCLUSIVE
                                  and lease.token == prev):
            self.token_regressions += 1
        else:
            self.last_token[lease.key] = lease.token

    def read_done(self, idx: int) -> None:
        """One optimistic read completed (lease-free: no token to check)."""
        self.per_client[idx] += 1
        self.total += 1
        self.reads += 1

    def recovered(self, idx: int, latency: float) -> None:
        """One lease recovered after a restart (reclaimed, or re-acquired
        past the wedge in the amnesiac baseline) — NOT a grant: a reclaim
        keeps its token, so it must not feed the monotonicity check."""
        self.reclaims += 1
        self.recovery_latencies.append(latency)


# ------------------------------------------------------------- key pickers
def _zipf_picker(keys: List[str], s: float) -> Callable:
    """Zipf(s) over ``keys``: rank r drawn with weight 1/r^s (r = 1-based)."""
    cum, acc = [], 0.0
    for r in range(1, len(keys) + 1):
        acc += 1.0 / r ** s
        cum.append(acc)
    total = cum[-1]

    def pick(rng: random.Random) -> str:
        return keys[bisect.bisect_right(cum, rng.random() * total)]

    return pick


def keys_by_home(table: ShardedLockTable, num_hosts: int, per_host: int,
                 prefix: str = "home/",
                 strict: bool = True) -> Dict[int, List[str]]:
    """``per_host`` keys homed on each host, by stable-hash placement scan.

    Shared by the sim workloads and the threaded bench (one scanner, so the
    two modes cannot drift).  ``strict=True`` raises when a host owns no
    shard (the sim's home workload is meaningless then); ``strict=False``
    pads under-filled hosts with keys homed elsewhere — the threaded
    bench's shards<hosts baseline, where locality is impossible for them
    and that *is* the cost story being measured.
    """
    out: Dict[int, List[str]] = {h: [] for h in range(num_hosts)}
    pool: List[str] = []
    need = num_hosts * per_host
    for i in range(200 * need):
        if all(len(ks) >= per_host for ks in out.values()):
            break
        k = f"{prefix}{i}"
        pool.append(k)
        ks = out[table.home_of(k)]
        if len(ks) < per_host:
            ks.append(k)
    short = [h for h, ks in out.items() if len(ks) < per_host]
    if short and strict:
        raise ValueError(
            f"hosts {short} own no (or too few) shards — the home workload "
            f"needs num_shards >= num_hosts (got {table.num_shards} shards "
            f"for {num_hosts} hosts)"
        )
    for h in short:
        j = 0
        while len(out[h]) < per_host:
            out[h].append(pool[(h * per_host + j) % len(pool)])
            j += 1
    return out


# ------------------------------------------------------------ client tasks
def _acquire_release_client(table, p, rng, pick, st, idx, ttl):
    backoff = BACKOFF
    while not st.done():
        lease = table.try_acquire(p, pick(rng), ttl)
        if lease is None:
            yield backoff * (0.5 + rng.random())
            backoff = min(backoff * 2, BACKOFF_CAP)
            continue
        backoff = BACKOFF
        st.granted(idx, lease)
        yield HOLD
        table.release(p, lease)
        yield THINK


def _sticky_hot_client(table, p, rng, pick, st, idx, ttl, track):
    """The zipfian client: sticky key choice + tracked hot-key probes.

    The plain client re-picks a fresh key after every reject, which lets a
    loser walk away from the hottest key — diluting exactly the contention
    regime the zipfian workload exists to measure, and making per-key
    acquire latency unattributable.  Real callers want THE key they asked
    for, so this client retries the same key (seeded exponential backoff)
    until granted, and for keys in ``track`` records the virtual-time
    acquire latency (first attempt -> grant) and, for remote clients, the
    rCAS the grant cost — the two quantities the inflation gates bound.
    """
    clock = table.clock
    home = {k: table.home_of(k) for k in track}
    while not st.done():
        key = pick(rng)
        tracked = key in home
        remote = tracked and p.node != home[key]
        t0 = clock()
        rcas0 = p.counts.remote_cas
        backoff = BACKOFF
        lease = None
        while lease is None:
            lease = table.try_acquire(p, key, ttl)
            if lease is None:
                if st.done():
                    return
                if table.queued(p, key):
                    # Inflated mode: parked in the key's MCS queue, where a
                    # poll is ONE local read (the local spin).  Fine-grained
                    # constant cadence — exponential backoff here would gate
                    # every FIFO handoff on the head's (huge) poll period.
                    yield HOLD * (0.5 + rng.random())
                    backoff = BACKOFF
                else:
                    yield backoff * (0.5 + rng.random())
                    backoff = min(backoff * 2, BACKOFF_CAP)
        if tracked:
            st.hot_latencies.append(clock() - t0)
            if remote:
                st.hot_rcas.append(p.counts.remote_cas - rcas0)
        st.granted(idx, lease)
        yield HOLD
        table.release(p, lease)
        yield THINK


def _mode_mix_client(table, p, rng, pick, st, idx, ttl, write_frac,
                     shared_reads, hold):
    """The read_heavy client: a seeded S/X mix over the picked keys.

    ``hold`` is the lease-hold time — the work done under the lease (a scan
    for readers, a mutation for writers).  It is the quantity S/X sharing
    monetises: exclusive-only serialises every hot key's holds end-to-end,
    shared mode overlaps the read holds.
    """
    backoff = BACKOFF
    while not st.done():
        is_write = rng.random() < write_frac
        mode = EXCLUSIVE if (is_write or not shared_reads) else SHARED
        lease = table.try_acquire(p, pick(rng), ttl, mode=mode)
        if lease is None:
            yield backoff * (0.5 + rng.random())
            backoff = min(backoff * 2, BACKOFF_CAP)
            continue
        backoff = BACKOFF
        st.granted(idx, lease)
        yield hold
        table.release(p, lease)
        yield THINK


def _opt_mix_client(table, p, rng, pick, st, idx, ttl, write_frac, hold):
    """The read_heavy client on the optimistic read path (PR 10).

    Same seeded R/W mix as :func:`_mode_mix_client`, but readers go
    lease-free through ``read_optimistic`` (0 RDMA at home, one doorbell
    remote, never blocking a writer) and writers publish the payload
    ``(token, key)`` their readers verify — a returned snapshot whose
    token or key disagrees is a torn/stale read and fails the run.
    """
    backoff = BACKOFF
    while not st.done():
        key = pick(rng)
        if rng.random() < write_frac:
            lease = table.try_acquire(p, key, ttl)
            if lease is None:
                yield backoff * (0.5 + rng.random())
                backoff = min(backoff * 2, BACKOFF_CAP)
                continue
            backoff = BACKOFF
            st.granted(idx, lease)
            table.publish(p, lease, (lease.token, key))
            yield hold
            table.release(p, lease)
        else:
            # poll=BACKOFF: the retry backoff must be on the same scale as
            # the writers' hold time, or a reader that catches a live
            # writer oversleeps the whole grant window.  None means a
            # live writer holds the key right now: back off HERE (the
            # client may yield; the table may not) and re-issue.
            got = table.read_optimistic(p, key, poll=BACKOFF)
            while got is None:
                yield backoff * (0.5 + rng.random())
                backoff = min(backoff * 2, BACKOFF_CAP)
                got = table.read_optimistic(p, key, poll=BACKOFF)
            backoff = BACKOFF
            val, tok = got
            if val is not None and (val[0] != tok or val[1] != key):
                raise AssertionError(
                    f"read_heavy/optimistic: torn or stale payload "
                    f"{val!r} (token {tok}) for key {key!r}")
            st.read_done(idx)
            yield hold  # the scan runs on the snapshot, outside any lease
        yield THINK


def _pipelined_read_client(table, pl, rng, st, idx, ttl, per_host, host,
                           num_hosts, writer, burst):
    """The pipelined_read client: bursty remote reads through an
    :class:`~repro.coord.AsyncClient`.

    Readers aim each burst at ONE remote host — ``burst`` keys homed
    there enqueue as futures and flush as a single mixed posting, so the
    whole burst costs one doorbell (the aggregate doorbells-per-op < 1
    gate).  One client per host is the writer: it mutates its OWN host's
    keys (home class, zero RDMA) and publishes ``(token, key)`` so the
    readers' torn-read check has live writes to race against; its
    releases ride the pipeline too.
    """
    p = pl.p
    if writer:
        keys = per_host[host]
        backoff = BACKOFF
        while not st.done():
            key = rng.choice(keys)
            lease = table.try_acquire(p, key, ttl)
            if lease is None:
                yield backoff * (0.5 + rng.random())
                backoff = min(backoff * 2, BACKOFF_CAP)
                continue
            backoff = BACKOFF
            st.granted(idx, lease)
            table.publish(p, lease, (lease.token, key))
            yield HOLD
            pl.sync(pl.release(lease))
            yield THINK
        return
    others = [h for h in range(num_hosts) if h != host] or [host]
    while not st.done():
        target = rng.choice(others)
        keys = [rng.choice(per_host[target]) for _ in range(burst)]
        futs = [[k, pl.read_optimistic(k)] for k in keys]
        pl.flush()
        while futs:
            still = []
            for ent in futs:
                key, fut = ent
                if not fut.done():
                    still.append(ent)
                    continue
                got = fut.result()
                if got is None:
                    # A live writer held the key at flush time: re-issue
                    # the read; it rides the next flush posting.
                    ent[1] = pl.read_optimistic(key)
                    still.append(ent)
                    continue
                val, tok = got
                if val is not None and (val[0] != tok or val[1] != key):
                    raise AssertionError(
                        f"pipelined_read: torn or stale payload {val!r} "
                        f"(token {tok}) for key {key!r}")
                st.read_done(idx)
            futs = still
            if futs:
                # Unstable snapshots re-enqueued a retry (or a re-issue
                # is queued): give the writer a beat, then flush the
                # retry posting.
                yield BACKOFF * (0.5 + rng.random())
                pl.flush()
        yield THINK


def _flood_reader(table, p, rng, st, idx, key, ttl):
    """A reader hammering one key with shared joins, as fast as it can."""
    while not st.done():
        lease = table.try_acquire(p, key, ttl, mode=SHARED)
        if lease is None:
            yield BACKOFF * (0.5 + rng.random())
            continue
        st.granted(idx, lease)
        yield HOLD
        table.release(p, lease)
        yield THINK


def _flood_writer(table, p, rng, st, idx, key, ttl):
    """The queued writer: periodically needs EXCLUSIVE through the flood.

    Each wait is recorded in virtual time; the drain barrier (armed by the
    writer's first blocked critical section) must bound it near one TTL no
    matter how saturating the reader flood is.
    """
    clock = table.clock
    while not st.done():
        yield 20 * HOLD  # between writes the readers own the key
        t0 = clock()
        while True:
            lease = table.try_acquire(p, key, ttl, mode=EXCLUSIVE)
            if lease is not None:
                break
            if st.done():
                return
            yield (ttl / 8) * (0.5 + rng.random())
        st.writer_waits.append(clock() - t0)
        st.granted(idx, lease)
        yield HOLD
        table.release(p, lease)


def _storm_client(table, p, rng, pick, st, idx, ttl, budget, interarrival,
                  reader, shedding, run_until):
    """The overload_storm client: open-loop paced arrivals with deadlines.

    Unlike every closed-loop client above, this one does NOT wait for the
    previous transaction before generating the next arrival tick — offered
    load is set by ``interarrival``, not by service capacity, which is what
    makes overload *possible*.  Each transaction runs the table's blocking
    ``acquire`` with an absolute deadline ``budget`` past its arrival;
    writers at priority 0 are sheddable, readers ride at priority 1 in
    SHARED mode (the brownout half: reads keep flowing while writes shed).
    A shed (:class:`Overloaded`), a burned deadline
    (:class:`DeadlineExceeded`) or an exhausted fabric retry budget
    (:class:`RemoteTimeout`) each fail fast into a counter and the client
    simply waits for its next arrival — no retry amplification beyond what
    the acquire loop itself decided was feasible.
    """
    clock = table.clock
    # A contended word frees by expiry, and the acquire loop's backoff
    # DOUBLES from ``poll`` — a coarse poll overshoots the expiry instant
    # by whole multiples of the TTL.  ttl/16 keeps the whole doubling
    # ladder (p, 2p, 4p, ...) inside roughly one quantum.
    poll = ttl / 16
    hold = min(HOLD, ttl / 8)
    mode = SHARED if reader else EXCLUSIVE
    priority = 1 if (reader or not shedding) else 0
    next_at = clock() + interarrival * (0.5 + rng.random())
    while True:
        now = clock()
        if next_at > now:
            yield next_at - now
        t_sched = next_at
        next_at = t_sched + interarrival * (0.5 + rng.random())
        if t_sched >= run_until:
            return
        st.offered += 1
        deadline = t_sched + budget
        if shedding and clock() >= deadline:
            # Admission shed: the arrival expired in this client's own
            # backlog, so attempting it cannot possibly help — drop it for
            # free and catch up to arrivals that can still be served.  The
            # OFF control leg is exactly this line withheld: a doomed
            # arrival still burns a (congested) posting before its
            # DeadlineExceeded, which is how a backlog snowballs into the
            # goodput collapse the sweep measures.
            st.shed_ops += 1
            continue
        try:
            lease = table.acquire(p, pick(rng), ttl, poll=poll, mode=mode,
                                  deadline=deadline, priority=priority)
        except Overloaded:
            st.shed_ops += 1
            continue
        except RemoteTimeout:
            st.remote_timeouts += 1
            continue
        except DeadlineExceeded:
            st.deadline_misses += 1
            continue
        lat = clock() - t_sched
        st.storm_latencies.append(lat)
        st.granted(idx, lease)
        if lat <= budget:
            st.goodput += 1
            if reader:
                st.goodput_shared += 1
        else:
            # Granted, but only after the caller's deadline had already
            # passed (the last pre-deadline poll can land late by one
            # congested attempt) — useless to the caller, not goodput.
            st.late_grants += 1
        yield hold
        try:
            table.release(p, lease)
        except RemoteTimeout:
            pass


def _failover_client(table, p, rng, pick, st, idx, ttl, crash_prob):
    hold = min(HOLD, ttl / 8)
    backoff = ttl / 4
    while not st.done():
        lease = table.try_acquire(p, pick(rng), ttl)
        if lease is None:
            yield backoff * (0.5 + rng.random())
            backoff = min(backoff * 2, 8 * ttl)
            continue
        backoff = ttl / 4
        st.granted(idx, lease)
        if rng.random() < crash_prob:
            # Crash mid-lease: hold silently past expiry, then wake as a
            # zombie and try to renew the stale lease.  Fencing must reject
            # it — by then the expiry register is past-due (or re-granted
            # with a larger token), so the renewal can never stick.
            yield ttl * (1.5 + rng.random())
            if table.renew(p, lease) is not None:
                st.zombie_renews += 1
            yield ttl * rng.random()  # recovery pause before rejoining
            continue
        yield hold
        renewed = table.renew(p, lease)
        if renewed is not None:
            yield hold
            table.release(p, renewed)
        yield THINK


def _recoverable_client(mem, table, store, host, idx, rng, pick, st, ttl,
                        restart_delay, reclaim):
    """The crash_restart client: a ledger-writing mix of single-key, batch
    and shared/upgrade transactions, structured as a state machine whose
    every ``yield`` sits inside the ``try`` — a :class:`ClientCrash` can
    land at ANY parked yield (the reaper) or synchronously inside a table
    call (a FaultInjector crash point) and is always funneled into the
    crashed state.  Restart either replays-and-reclaims (``reclaim=True``)
    or rejoins amnesiac and measures the wedge (``reclaim=False``)."""
    clock = table.clock
    p = mem.spawn(host)
    rc = RecoverableClient(table, p, store.ledger(f"client/{idx}"))
    hold = min(HOLD, ttl / 8)
    backoff = ttl / 4
    state = "run"   # "run" | "down" | ("wedge", t0, keys)
    while True:
        try:
            if st.done():
                return
            if state == "down":
                yield restart_delay  # the host is dark
                p = mem.spawn(host)  # a fresh incarnation (new pid)
                if reclaim:
                    t0 = clock()
                    got = rc.restart(p)
                    now = clock()
                    for lease in got:
                        st.recovered(idx, now - t0)
                        rc.release(lease)  # resume with a clean slate
                    st.recovery_events.append([idx, len(got)])
                    state = "run"
                else:
                    # Amnesiac baseline: the restarted client must wait
                    # out its dead incarnation's leases like a stranger.
                    # The ledger is used only to MEASURE (which keys the
                    # corpse still holds), never to recover.
                    t0 = clock()
                    view = rc.ledger.replay()
                    keys = sorted(
                        k for k, r in view.live.items()
                        if r.mode == int(EXCLUSIVE) and r.expires_at > t0)
                    rc.adopt_process(p)
                    if keys:
                        state = ("wedge", t0, keys)
                    else:
                        st.recovery_events.append([idx, 0])
                        state = "run"
                continue
            if isinstance(state, tuple):
                _tag, t0, keys = state
                lease = table.try_acquire(p, keys[0], ttl)
                if lease is not None:
                    st.recovered(idx, clock() - t0)
                    table.release(p, lease)
                    keys.pop(0)
                    if not keys:
                        st.recovery_events.append([idx, 0])
                        state = "run"
                else:
                    yield (ttl / 16) * (0.5 + rng.random())
                continue
            # ----- normal operation: a mix that exercises every window
            r = rng.random()
            if r < 0.15:  # multi-key batch (mid-batch crash window)
                keys = sorted({pick(rng) for _ in range(3)})
                try:
                    # The timeout must stay well inside the TTL: a batch
                    # that polls past it returns leases already aging out,
                    # and nothing valid would be left to crash-recover.
                    leases = rc.acquire_batch(keys, ttl, timeout=ttl / 2)
                except TimeoutError:
                    yield backoff * (0.5 + rng.random())
                    continue
                for lease in leases:
                    st.granted(idx, lease)
                yield hold
                for lease in leases:
                    rc.release(lease)
                yield THINK
            elif r < 0.40:  # shared join, sometimes upgraded
                lease = rc.try_acquire(pick(rng), ttl, mode=SHARED)
                if lease is None:
                    yield backoff * (0.5 + rng.random())
                    continue
                st.granted(idx, lease)
                yield hold
                if rng.random() < 0.25:
                    up = rc.upgrade(lease)
                    if up is not None:
                        st.granted(idx, up)
                        lease = up
                        yield hold
                rc.release(lease)
                yield THINK
            else:  # single exclusive with a renewal (the failover shape)
                lease = rc.try_acquire(pick(rng), ttl)
                if lease is None:
                    yield backoff * (0.5 + rng.random())
                    continue
                st.granted(idx, lease)
                yield hold
                renewed = rc.renew(lease)
                if renewed is not None:
                    yield hold
                    rc.release(renewed)
                yield THINK
        except ClientCrash:
            st.crashes += 1
            state = "down"


def _crash_reaper(engine, schedule, tasks_by_host):
    """Kills every client task of each scheduled host at its crash time.
    The schedule is seeded data, so two same-seed runs kill the same tasks
    at the same instants — the determinism the CI crash gate diffs."""
    for t, host in schedule:
        dt = t - engine.clock.now
        if dt > 0:
            yield dt
        for task in tasks_by_host[host]:
            engine.kill(task, ClientCrash("host.crash", pid=host))


def _ha_client(mem, table, store, host, idx, rng, pick, st, ttl,
               member=None, run_until=0.0):
    """The failover-aware ledgered client (home_death / partition).

    Every table call sits inside the ``try``: a :class:`RemoteTimeout`
    (the key's home is unreachable and the op burned its retry budget)
    backs off and retries — after the takeover the key resolves to its
    new home and the same loop just works.  With a ``member`` attached
    the client consults the partition guard first and stops *acquiring*
    while its island has no quorum attestation (existing leases could
    still be validated; nothing new is granted).  ``run_until`` keeps
    the client generating traffic past the ops target, so a partition
    window is never quietly empty."""
    clock = table.clock
    p = mem.spawn(host)
    rc = RecoverableClient(table, p, store.ledger(f"client/{idx}"))
    hold = min(HOLD, ttl / 8)
    backoff = ttl / 4
    while not st.done() or clock() < run_until:
        try:
            if member is not None and not member.can_serve():
                yield member.policy.guard_ttl / 4
                continue
            t_att = clock()
            lease = rc.try_acquire(pick(rng), ttl)
            if lease is None:
                yield backoff * (0.5 + rng.random())
                backoff = min(backoff * 2, 8 * ttl)
                continue
            backoff = ttl / 4
            st.granted(idx, lease)
            # An in-window grant is one whose ATTEMPT started inside the
            # cut: an acquire decided entirely pre-cut may still have its
            # completion timestamp drift past the boundary on latency
            # charges, and that is a pre-cut grant, not a violation.
            if (st.window is not None and st.minority is not None
                    and host in st.minority
                    and st.window[0] <= t_att and clock() < st.window[1]):
                st.minority_grants += 1
            yield hold
            rc.release(lease)
            yield THINK
        except RemoteTimeout:
            st.remote_timeouts += 1
            yield backoff * (0.5 + rng.random())
            backoff = min(backoff * 2, 8 * ttl)
        except ClientCrash:
            return  # died with its host; this workload has no restarts


def _heartbeat_agent(m):
    """Wraps :meth:`HostMembership.heartbeat_task` so a host death
    (:class:`ClientCrash` from the killer) retires the loop cleanly."""
    try:
        yield from m.heartbeat_task()
    except ClientCrash:
        m.stop()


def _membership_agent(table, store, m, st):
    """One host's monitor *and* successor duties: sweep the member words
    every ``sweep_every``, and when a host this monitor is the rank-order
    successor of goes DEAD, run the epoch-fenced takeover of every shard
    still homed on the corpse.  Detection and crash→re-homed latencies
    land in the run state (dead hosts with no scheduled crash time — a
    partition mirage — are recorded as verdicts only)."""
    clock = table.clock
    detected: set = set()
    try:
        while not m.stopped:
            m.sweep_once()
            for h in range(m.num_hosts):
                if h == m.host or m.estimator.verdict(h) != DEAD:
                    continue
                t0 = st.crash_times.get(h)
                died = m.estimator.died_at(h)
                if t0 is not None and died is not None and h not in detected:
                    detected.add(h)
                    st.detect_latencies.append(died - t0)
                if not m.is_successor(h):
                    continue
                for shard in table.shards:
                    if shard.home_host != h:
                        continue
                    try:
                        rep = table.takeover_shard(
                            m.p, shard.index, store.all_records(),
                            membership=m)
                    except RemoteTimeout:
                        rep = None  # the witness is unreachable too: retry
                    if rep is None:
                        continue
                    now = clock()
                    if t0 is not None:
                        st.takeover_latencies.append(now - t0)
                    st.failover_events.append(
                        [round(now, 9), h, shard.index, rep["epoch"],
                         rep["intact"], rep["reset"]])
            yield m.policy.sweep_every
    except ClientCrash:
        m.stop()


def _host_killer(engine, faults, schedule, tasks_by_host):
    """home_death's reaper: at each instant the host's memory drops off
    the fabric for good (``fail_host``) and every one of its tasks —
    clients, heartbeat, monitor — dies at its next dispatch."""
    for t, host in schedule:
        dt = t - engine.clock.now
        if dt > 0:
            yield dt
        faults.fail_host(host, t)
        for task in tasks_by_host[host]:
            engine.kill(task, ClientCrash("host.death", pid=host))


def _rehome_verifier(mem, table, st, host, keys, ttl, out):
    """The post-run prover: from the successor host, acquire every key the
    dead home used to own.  A key that cannot be granted, or that hands
    out a token at or below the pre-crash maximum, is a failed takeover —
    both feed the run's hard asserts."""
    p = mem.spawn(host)
    for key in keys:
        backoff = ttl / 8
        while True:
            lease = table.try_acquire(p, key, ttl)
            if lease is not None:
                break
            yield backoff  # a pre-crash survivor lease drains within a TTL
            backoff = min(backoff * 2, 4 * ttl)
        if lease.token <= st.last_token.get(key, 0):
            st.token_regressions += 1
        table.release(p, lease)
        out.append(key)
        yield THINK


# ------------------------------------------------------------------ runner
@dataclass
class SimResult:
    """One deterministic sim run.  ``row()`` is the byte-stable record: it
    excludes wall-clock fields (and the live table), so two same-seed runs
    compare equal — the CI determinism gate diffs exactly these rows,
    including every per-mode counter and per-mode per-class cost."""

    workload: str
    num_hosts: int
    clients_per_host: int
    num_shards: int
    seed: int
    target_ops: int
    ops: int
    virtual_seconds: float
    virtual_throughput: float
    jain: float
    grants: int
    rejects: int
    grants_shared: int
    grants_exclusive: int
    rejects_shared: int
    rejects_exclusive: int
    expirations: int
    fast_renews: int
    fast_releases: int
    shared_joins: int
    shared_renews: int
    shared_releases: int
    shared_remote_grants: int
    shared_acquire_rcas: int
    upgrades: int
    downgrades: int
    intent_blocks: int
    repairs: int
    zombie_renews: int
    token_regressions: int
    writer_grants: int
    writer_max_wait: float
    writer_mean_wait: float
    crashes: int
    kills: int
    reclaims: int
    recovery_p50: float
    recovery_p99: float
    recovery_max: float
    recovery_events: List[List[int]]
    reclaim_fast: int
    reclaim_slow: int
    reclaim_shared: int
    reclaim_rejects: int
    orphan_probes: int
    orphan_adopts: int
    reconstructs: int
    reconstruct_resets: int
    takeovers: int
    takeover_refusals: int
    takeover_aborts: int
    epoch_aborts: int
    rehomed_keys: int
    remote_timeouts: int
    guard_blocks: int
    quorum_losses: int
    minority_grants: int
    detect_p99: float
    failover_p50: float
    failover_p99: float
    failover_max: float
    failover_events: List[List]
    fabric: Dict[str, int]
    inflations: int
    deflations: int
    queue_enqueues: int
    queue_grants: int
    queue_handoffs: int
    queue_bypasses: int
    hot_key_report: List[List]
    inflation_events: List[List]
    hot_grants: int
    hot_acquire_p50: float
    hot_acquire_p99: float
    hot_acquire_max: float
    hot_remote_acquires: int
    hot_rcas_mean: float
    hot_rcas_max: int
    sheds: int
    hedges: int
    deadline_exceeded: int
    op_timeouts: int
    fabric_retries: int
    breaker_trips: int
    breaker_refusals: int
    budget_refusals: int
    offered_load: float
    storm_offered: int
    storm_goodput: int
    storm_goodput_shared: int
    storm_shed: int
    storm_deadline_misses: int
    storm_late_grants: int
    storm_acquire_p50: float
    storm_acquire_p99: float
    opt_reads: int
    opt_read_retries: int
    opt_read_fallbacks: int
    opt_read_fwd: int
    publishes: int
    reads: int
    pipeline_flushes: int
    pipeline_flushed_ops: int
    pipeline_hedge_rides: int
    doorbells_per_op: float
    cost: Dict[str, Dict[str, int]]
    mode_cost: Dict[str, Dict[str, int]]
    events: int
    spins: int
    wall_seconds: float
    per_client: List[int] = field(repr=False)
    table: ShardedLockTable = field(repr=False)

    def row(self) -> Dict:
        drop = {"wall_seconds", "per_client", "table"}
        return {k: v for k, v in vars(self).items() if k not in drop}


def run_lock_table_sim(
    workload: str,
    num_hosts: int = 64,
    clients_per_host: int = 16,
    num_shards: Optional[int] = None,
    total_ops: int = 100_000,
    seed: int = 0,
    ttl: Optional[float] = None,
    latency: Optional[FabricLatency] = None,
    zipf_s: float = 0.99,
    keys_per_host: int = KEYS_PER_HOST,
    crash_prob: float = 0.1,
    write_frac: float = 0.05,
    home_frac: float = 0.8,
    shared_reads: bool = True,
    read_path: str = "lease",
    pipeline_flush_ops: int = 8,
    hold: float = HOLD,
    hot_keys: Optional[int] = None,
    failover_ttl: float = 300e-6,
    fault: Optional[FaultInjector] = None,
    crash_hosts: int = 8,
    crash_warmup: Optional[float] = None,
    crash_spacing: Optional[float] = None,
    restart_delay: Optional[float] = None,
    reclaim: bool = True,
    inflation: Optional[InflationPolicy] = None,
    member_ttl: Optional[float] = None,
    partition_frac: float = 0.25,
    partition_at: Optional[float] = None,
    partition_for: Optional[float] = None,
    offered_load: float = 1.0,
    deadline_budget: Optional[float] = None,
    storm_interarrival: float = STORM_INTERARRIVAL,
    overload: Optional[OverloadPolicy] = None,
    shedding: bool = True,
    congest_capacity: Optional[int] = None,
    congest_delay: float = 12e-6,
    drop_prob: float = 0.0,
    max_events: Optional[int] = None,
) -> SimResult:
    """Run one workload to ``total_ops`` granted leases; fully deterministic.

    Returns exact per-class and per-mode operation counts (``cost`` /
    ``mode_cost``) plus virtual-time throughput and fairness.  Raises if any
    safety invariant breaks: the LOCAL class must never issue an RDMA op,
    writer grant tokens must be strictly monotonic per key (reader
    generations may only equal the running maximum, never regress), no
    zombie renewal may survive fencing, and in ``reader_flood`` the queued
    writer's grant latency must stay bounded by the drain protocol.
    """
    if workload not in SIM_WORKLOADS:
        raise ValueError(f"unknown sim workload {workload!r}")
    if read_path not in ("lease", "optimistic"):
        raise ValueError(f"unknown read_path {read_path!r}")
    wall0 = time.perf_counter()
    engine = SimEngine(seed)
    if ttl is None:
        # The short-lease workloads share one tunable TTL (``failover_ttl``)
        # instead of a hardcoded constant, so the recovery sweeps can scale
        # lease lifetime without forking the workload.
        short = ("failover", "reader_flood", "crash_restart",
                 "home_death", "partition", "overload_storm")
        ttl = failover_ttl if workload in short else 1.0
        if workload == "overload_storm":
            # The storm's TTL is its *contention quantum*: inside one
            # atomic blocking acquire a contended word can only free by
            # expiry (the holder's release step cannot interleave), so
            # the TTL prices each contended retry round, not lease
            # safety.  Keep it well under the deadline budget.
            ttl = failover_ttl / 5
    # Membership TTL: long enough that one monitor sweep (num_hosts-1
    # charged probes) fits well inside a sweep period — the detector's
    # cadence must not be slower than its own probe loop.
    if member_ttl is None:
        member_ttl = max(10 * ttl, num_hosts * 100e-6)

    # The fault plan: home_death needs `fail_host`, partition needs the
    # scheduled cut, and ANY workload with a FaultInjector gets the fabric
    # points armed (the crash matrix crosses host-crash cells with
    # message-loss cells through exactly this wiring).  Everything else
    # keeps faults=None and the legacy loss-free timelines byte-identical.
    minority: Optional[frozenset] = None
    window = None
    faults: Optional[FabricFaults] = None
    if workload == "partition":
        q = max(1, int(num_hosts * partition_frac))
        minority = frozenset(range(q))
        t0 = partition_at if partition_at is not None else 2 * member_ttl
        t1 = t0 + (partition_for if partition_for is not None
                   else 4 * member_ttl)
        window = (t0, t1)
        faults = FabricFaults(seed=seed, injector=fault,
                              partitions=((minority, t0, t1),))
    elif workload == "overload_storm":
        # The storm *requires* a fault plan: congestion is what makes
        # overload cost latency.  One remote acquire+release lands ~11
        # postings on the key's home, so at the base interarrival each
        # host sees ~20 postings per 200us window per 4 clients; 12 per
        # client leaves 1x at ~40% of capacity and 10x several times over.
        if congest_capacity is None:
            congest_capacity = 12 * clients_per_host
        faults = FabricFaults(seed=seed, injector=fault,
                              drop_prob=drop_prob,
                              congest_capacity=congest_capacity,
                              congest_delay=congest_delay)
    elif (workload == "home_death" or fault is not None
          or congest_capacity is not None or drop_prob > 0.0):
        faults = FabricFaults(seed=seed, injector=fault,
                              drop_prob=drop_prob,
                              congest_capacity=congest_capacity,
                              congest_delay=congest_delay)
    mem = SimFabricMemory(num_hosts, engine, latency or FabricLatency(),
                          faults=faults)
    table = ShardedLockTable(
        mem, num_shards=num_shards or 2 * num_hosts,
        clock=engine.clock, sleep=engine.sleep_inline, name=f"sim{seed}",
        fault=fault, inflation=inflation, seed=seed, overload=overload,
    )

    universe = [f"k/{i}" for i in range(num_hosts * keys_per_host)]
    if workload == "home":
        per_host = keys_by_home(table, num_hosts, keys_per_host)
        pick_for = lambda h: lambda rng: rng.choice(per_host[h])  # noqa: E731
    elif workload == "uniform":
        pick_for = lambda h: lambda rng: rng.choice(universe)  # noqa: E731
    elif workload == "zipfian":
        zipf = _zipf_picker(universe, zipf_s)
        pick_for = lambda h: zipf  # noqa: E731
    elif workload == "read_heavy":
        # home_frac of each client's draws are zipfian over its OWN host's
        # keys (the zero-RDMA class), the rest zipfian over the universe
        # (remote shared traffic — the one-rCAS joins the sweep prices).
        per_host = keys_by_home(table, num_hosts, keys_per_host)
        home_zipf = {h: _zipf_picker(ks, zipf_s)
                     for h, ks in per_host.items()}
        global_zipf = _zipf_picker(universe, zipf_s)

        def pick_for(h):  # noqa: E306
            hz = home_zipf[h]

            def pick(rng: random.Random) -> str:
                return hz(rng) if rng.random() < home_frac else global_zipf(rng)

            return pick
    elif workload == "pipelined_read":
        # Burst targets: each reader aims a whole burst at one remote
        # host's keys, so the AsyncClient can coalesce it into a single
        # posting; the per-host writer mutates its own (home-class) keys.
        per_host = keys_by_home(table, num_hosts, keys_per_host)
        pick_for = None  # clients draw from per_host directly
    elif workload == "reader_flood":
        pick_for = None  # flood clients share one literal key
    elif workload == "home_death":
        # Uniform over the whole keyspace: the dead home's keys must keep
        # seeing traffic, or the takeover would never be exercised.
        pick_for = lambda h: lambda rng: rng.choice(universe)  # noqa: E731
    elif workload == "partition":
        # Every draw is majority-homed, so a minority client's acquire
        # must cross the cut — the zero-in-window-grants assert is about
        # the guard and the fabric, not about idle clients.
        majority_keys = [k for k in universe
                         if table.home_of(k) not in minority]
        pick_for = lambda h: lambda rng: rng.choice(majority_keys)  # noqa: E731
    elif workload == "overload_storm":
        # Uniform over the universe: at 1x each key is nearly idle and
        # each host well under its posting capacity; at 10x the SAME
        # keyspace is contended and the SAME hosts congested — overload
        # emerges from load alone, not from a skew knob.
        pick_for = lambda h: lambda rng: rng.choice(universe)  # noqa: E731
    else:  # failover / crash_restart: everyone storms a small hot set
        # The hot-set size is a workload parameter (``hot_keys``), not a
        # baked-in constant — the recovery sweep narrows it to sharpen
        # contention on the crashed holders' keys.
        hot = universe[: (hot_keys or max(4, num_hosts))]
        pick_for = lambda h: lambda rng: rng.choice(hot)  # noqa: E731

    nclients = num_hosts * clients_per_host
    st = _RunState(nclients, total_ops)
    pipes: List[AsyncClient] = []
    st.minority = minority
    st.window = window
    flood_key = universe[0]
    store = LedgerStore()
    if restart_delay is None:
        restart_delay = ttl / 4
    tasks_by_host: Dict[int, List] = {h: [] for h in range(num_hosts)}

    # Storm pacing: the *base* (1x) interarrival sets the measurement
    # window (so every offered-load point observes the same virtual-time
    # span), and the actual per-client interarrival divides by the load —
    # 10x offered load is 10x the arrivals into the SAME window.
    storm_until = 0.0
    storm_ia = storm_interarrival
    if workload == "overload_storm":
        if deadline_budget is None:
            deadline_budget = 10 * ttl
        storm_ia = storm_interarrival / max(offered_load, 1e-9)
        storm_until = total_ops * storm_interarrival / max(nclients, 1)

    memberships: List[HostMembership] = []
    run_until = 0.0
    if workload in ("home_death", "partition"):
        if window is not None:
            run_until = window[1] + 4 * member_ttl
        # One heartbeat + one monitor per host.  The heartbeats ride the
        # RecoverableClient ledger path (a member shard that gets taken
        # over keeps its fencing history); the monitors start half a
        # membership TTL late so first beats land before first sweeps.
        mpol = SuspicionPolicy(ttl=member_ttl)
        for h in range(num_hosts):
            m = HostMembership(table, mem, h, num_hosts, policy=mpol,
                               ledger=store.ledger(f"member.h{h}"))
            memberships.append(m)
            hb = _heartbeat_agent(m)
            mon = _membership_agent(table, store, m, st)
            tasks_by_host[h] += [hb, mon]
            engine.spawn(hb, delay=h * 1e-7)
            engine.spawn(mon, delay=member_ttl / 2 + h * 1e-7)

    for idx in range(nclients):
        host = idx // clients_per_host
        rng = random.Random(1_000_003 * seed + idx)
        if workload in ("home_death", "partition"):
            member = memberships[host]
            task = _ha_client(mem, table, store, host, idx, rng,
                              pick_for(host), st, ttl, member=member,
                              run_until=run_until)
            tasks_by_host[host].append(task)
            engine.spawn(task, delay=idx * 1e-7)
            continue
        if workload == "crash_restart":
            # The recoverable client spawns its own Process (and respawns
            # one per restart); the reaper needs the task handle to kill.
            task = _recoverable_client(mem, table, store, host, idx, rng,
                                       pick_for(host), st, ttl,
                                       restart_delay, reclaim)
            tasks_by_host[host].append(task)
            engine.spawn(task, delay=idx * 1e-7)
            continue
        p = mem.spawn(host)
        if workload == "failover":
            task = _failover_client(table, p, rng, pick_for(host), st, idx,
                                    ttl, crash_prob)
        elif workload == "read_heavy":
            if read_path == "optimistic":
                task = _opt_mix_client(table, p, rng, pick_for(host), st,
                                       idx, ttl, write_frac, hold)
            else:
                task = _mode_mix_client(table, p, rng, pick_for(host), st,
                                        idx, ttl, write_frac, shared_reads,
                                        hold)
        elif workload == "pipelined_read":
            pl = AsyncClient(table, p, flush_ops=pipeline_flush_ops)
            pipes.append(pl)
            task = _pipelined_read_client(
                table, pl, rng, st, idx, ttl, per_host, host, num_hosts,
                idx % clients_per_host == 0, pipeline_flush_ops)
        elif workload == "reader_flood":
            if idx == 0:
                task = _flood_writer(table, p, rng, st, idx, flood_key, ttl)
            else:
                task = _flood_reader(table, p, rng, st, idx, flood_key, ttl)
        elif workload == "zipfian":
            # universe[0] is zipf rank 1: the hottest key, the one whose
            # acquire-latency tail and per-acquire rCAS the gates bound.
            task = _sticky_hot_client(table, p, rng, pick_for(host), st,
                                      idx, ttl, (universe[0],))
        elif workload == "overload_storm":
            # Every 4th client is the SHARED reader at priority 1 — the
            # brownout witness.  Writers shed at priority 0 (or never,
            # in the shedding-OFF control leg).
            task = _storm_client(table, p, rng, pick_for(host), st, idx,
                                 ttl, deadline_budget, storm_ia,
                                 idx % 4 == 3, shedding, storm_until)
        else:
            task = _acquire_release_client(table, p, rng, pick_for(host), st,
                                           idx, ttl)
        engine.spawn(task, delay=idx * 1e-7)  # deterministic arrival stagger

    if workload == "crash_restart":
        # The crash schedule is seeded data, independent of the engine RNG:
        # host choice and crash instants depend only on the run seed.
        crash_rng = random.Random(0xC0FFEE * (seed + 1))
        victims = crash_rng.sample(range(num_hosts),
                                   min(crash_hosts, num_hosts))
        warmup = crash_warmup if crash_warmup is not None else 20 * ttl
        spacing = crash_spacing if crash_spacing is not None else ttl / 2
        schedule = [(warmup + i * spacing, h) for i, h in enumerate(victims)]
        engine.spawn(_crash_reaper(engine, schedule, tasks_by_host))

    dead_host = None
    dead_shard_idxs: set = set()
    if workload == "home_death":
        # Seeded like the crash_restart schedule: same seed, same corpse,
        # same instant.  The successor of the dead host must survive to
        # run the takeover, so exactly one host dies.
        crash_rng = random.Random(0xC0FFEE * (seed + 1))
        dead_host = crash_rng.randrange(num_hosts)
        crash_at = (crash_warmup if crash_warmup is not None
                    else 2 * member_ttl)
        st.crash_times[dead_host] = crash_at
        dead_shard_idxs = {s.index for s in table.shards
                           if s.home_host == dead_host}
        engine.spawn(_host_killer(engine, faults, [(crash_at, dead_host)],
                                  tasks_by_host))

    if workload == "home_death":
        # The ops target alone must not end the run mid-funeral: hold it
        # open until every shard of the dead home has a new one.
        stop = lambda: (st.done() and all(  # noqa: E731
            s.home_host != dead_host for s in table.shards))
    elif workload == "partition":
        t_end = window[1] + 2 * member_ttl
        stop = lambda: st.done() and engine.clock.now > t_end  # noqa: E731
    elif workload == "overload_storm":
        # Open loop: the run ends when the window does (clients retire at
        # their first arrival past it), never on an ops target.  The
        # clock bound is a backstop for stragglers draining their last
        # transaction.
        stop = lambda: engine.clock.now > storm_until + 8 * ttl  # noqa: E731
    else:
        stop = st.done
    engine.run(stop=stop,
               max_events=max_events or (200 * total_ops + 500_000))

    if workload in ("home_death", "partition"):
        for m in memberships:
            m.stop()
    if workload == "home_death":
        # Second phase: prove the takeover from the outside.  Every key
        # the dead home used to own must be grantable from the successor,
        # with a token above the pre-crash maximum.
        dead_keys = [k for k in universe
                     if table.shard_of(k) in dead_shard_idxs]
        if dead_shard_idxs:
            succ = table.shards[min(dead_shard_idxs)].home_host
            verified: List[str] = []
            engine.spawn(_rehome_verifier(mem, table, st, succ, dead_keys,
                                          ttl, verified))
            engine.run(stop=lambda: len(verified) == len(dead_keys),
                       max_events=500_000)
            if len(verified) != len(dead_keys):
                raise AssertionError(
                    f"home_death: only {len(verified)}/{len(dead_keys)} "
                    f"keys of dead host {dead_host} re-homed")
    wall = time.perf_counter() - wall0

    totals = table.class_totals()
    mode_totals = table.mode_class_totals()
    if totals[LOCAL].rdma_ops:
        raise AssertionError(
            f"{workload}: LOCAL class issued {totals[LOCAL].rdma_ops} RDMA ops"
        )
    if workload == "home" and totals[REMOTE].rdma_ops:
        raise AssertionError(
            f"home: placement-aware clients issued "
            f"{totals[REMOTE].rdma_ops} remote ops"
        )
    if st.token_regressions:
        raise AssertionError(
            f"{workload}: {st.token_regressions} fencing-token regressions"
        )
    if st.zombie_renews:
        raise AssertionError(
            f"{workload}: {st.zombie_renews} zombie renewals survived fencing"
        )

    rows = table.telemetry()
    grants_shared = sum(r["grants_shared"] for r in rows)
    grants_exclusive = sum(r["grants_exclusive"] for r in rows)
    if grants_shared + grants_exclusive != sum(r["grants"] for r in rows):
        raise AssertionError(
            f"{workload}: per-mode grant counters do not partition the "
            f"total ({grants_shared} + {grants_exclusive} != "
            f"{sum(r['grants'] for r in rows)})"
        )
    if workload in ("home", "uniform", "zipfian", "failover",
                    "home_death", "partition") and grants_shared:
        raise AssertionError(
            f"{workload}: exclusive-only workload produced {grants_shared} "
            "shared grants"
        )
    takeovers = sum(r["takeovers"] for r in rows)
    takeover_refusals = sum(r["takeover_refusals"] for r in rows)
    if workload == "home_death" and dead_shard_idxs:
        if takeovers != len(dead_shard_idxs):
            raise AssertionError(
                f"home_death: {takeovers} takeovers committed for "
                f"{len(dead_shard_idxs)} shards homed on dead host "
                f"{dead_host}")
        p99 = _pct(st.takeover_latencies, 0.99)
        if p99 > 5 * member_ttl:
            raise AssertionError(
                f"home_death: crash->re-homed p99 {p99:.6f}s exceeds 5x "
                f"membership TTL ({5 * member_ttl:.6f}s)")
    if workload == "partition":
        if st.minority_grants:
            raise AssertionError(
                f"partition: {st.minority_grants} grants landed on the "
                f"minority side inside the cut window")
        if not takeover_refusals:
            raise AssertionError(
                "partition: the guard never refused a takeover — the "
                "window was too quiet to test anything")
        if not any(m.quorum_losses for m in memberships):
            raise AssertionError(
                "partition: no monitor ever lost quorum — the cut "
                "never bit")
    inflations = sum(r["inflations"] for r in rows)
    deflations = sum(r["deflations"] for r in rows)
    if inflation is None and (inflations or deflations):
        raise AssertionError(
            f"{workload}: inflation disabled but the table recorded "
            f"{inflations} inflations / {deflations} deflations"
        )
    writer_waits = st.writer_waits
    if workload == "reader_flood":
        if not writer_waits:
            raise AssertionError("reader_flood: the writer never got a grant")
        # The drain protocol's bound: intent is armed at the writer's first
        # blocked CS, the cohort stops extending, and the writer wins within
        # ~one TTL (+ polling slack).  10x is a loud failure margin, not a
        # tight model.
        if max(writer_waits) > 10 * ttl:
            raise AssertionError(
                f"reader_flood: writer starved — max wait "
                f"{max(writer_waits):.6f}s vs ttl {ttl}"
            )

    doorbells = (totals[LOCAL].remote_doorbell
                 + totals[REMOTE].remote_doorbell)
    doorbells_per_op = doorbells / max(st.total, 1)
    opt_reads = sum(r["opt_reads"] for r in rows)
    if workload == "pipelined_read":
        if not opt_reads:
            raise AssertionError(
                "pipelined_read: no optimistic read ever completed")
        # flush_ops=1 posts every op the moment it is enqueued — that is
        # the bench's unbatched control leg, exempt from the coalescing
        # bound it exists to contrast against.
        if pipeline_flush_ops > 1 and doorbells_per_op >= 1.0:
            raise AssertionError(
                f"pipelined_read: {doorbells_per_op:.2f} doorbells/op — "
                "the pipeline failed to coalesce below one per operation")

    orep = table.overload.report() if table.overload is not None else {}
    vsec = engine.clock.now
    return SimResult(
        workload=workload,
        num_hosts=num_hosts,
        clients_per_host=clients_per_host,
        num_shards=table.num_shards,
        seed=seed,
        target_ops=total_ops,
        ops=st.total,
        virtual_seconds=vsec,
        virtual_throughput=st.total / max(vsec, 1e-12),
        jain=jain(st.per_client),
        grants=sum(r["grants"] for r in rows),
        rejects=sum(r["rejects"] for r in rows),
        grants_shared=grants_shared,
        grants_exclusive=grants_exclusive,
        rejects_shared=sum(r["rejects_shared"] for r in rows),
        rejects_exclusive=sum(r["rejects_exclusive"] for r in rows),
        expirations=sum(r["expirations"] for r in rows),
        fast_renews=sum(r["fast_renews"] for r in rows),
        fast_releases=sum(r["fast_releases"] for r in rows),
        shared_joins=sum(r["shared_joins"] for r in rows),
        shared_renews=sum(r["shared_renews"] for r in rows),
        shared_releases=sum(r["shared_releases"] for r in rows),
        shared_remote_grants=sum(r["shared_remote_grants"] for r in rows),
        shared_acquire_rcas=sum(r["shared_acquire_rcas"] for r in rows),
        upgrades=sum(r["upgrades"] for r in rows),
        downgrades=sum(r["downgrades"] for r in rows),
        intent_blocks=sum(r["intent_blocks"] for r in rows),
        repairs=sum(r["repairs"] for r in rows),
        zombie_renews=st.zombie_renews,
        token_regressions=st.token_regressions,
        writer_grants=len(writer_waits),
        writer_max_wait=max(writer_waits) if writer_waits else 0.0,
        writer_mean_wait=(sum(writer_waits) / len(writer_waits)
                          if writer_waits else 0.0),
        crashes=st.crashes,
        kills=engine.kills,
        reclaims=st.reclaims,
        recovery_p50=_pct(st.recovery_latencies, 0.50),
        recovery_p99=_pct(st.recovery_latencies, 0.99),
        recovery_max=(max(st.recovery_latencies)
                      if st.recovery_latencies else 0.0),
        recovery_events=st.recovery_events,
        reclaim_fast=sum(r["reclaim_fast"] for r in rows),
        reclaim_slow=sum(r["reclaim_slow"] for r in rows),
        reclaim_shared=sum(r["reclaim_shared"] for r in rows),
        reclaim_rejects=sum(r["reclaim_rejects"] for r in rows),
        orphan_probes=sum(r["orphan_probes"] for r in rows),
        orphan_adopts=sum(r["orphan_adopts"] for r in rows),
        reconstructs=sum(r["reconstructions"] for r in rows),
        reconstruct_resets=sum(r["reconstruct_resets"] for r in rows),
        takeovers=takeovers,
        takeover_refusals=takeover_refusals,
        takeover_aborts=sum(r["takeover_aborts"] for r in rows),
        epoch_aborts=sum(r["epoch_aborts"] for r in rows),
        rehomed_keys=sum(r["rehomed_keys"] for r in rows),
        remote_timeouts=st.remote_timeouts,
        guard_blocks=sum(m.guard_blocks for m in memberships),
        quorum_losses=sum(m.quorum_losses for m in memberships),
        minority_grants=st.minority_grants,
        detect_p99=_pct(st.detect_latencies, 0.99),
        failover_p50=_pct(st.takeover_latencies, 0.50),
        failover_p99=_pct(st.takeover_latencies, 0.99),
        failover_max=(max(st.takeover_latencies)
                      if st.takeover_latencies else 0.0),
        failover_events=st.failover_events,
        fabric=dict(faults.stats) if faults is not None else {},
        inflations=inflations,
        deflations=deflations,
        queue_enqueues=sum(r["queue_enqueues"] for r in rows),
        queue_grants=sum(r["queue_grants"] for r in rows),
        queue_handoffs=sum(r["queue_handoffs"] for r in rows),
        queue_bypasses=sum(r["queue_bypasses"] for r in rows),
        hot_key_report=table.hot_keys(10),
        inflation_events=table.inflation_log(),
        hot_grants=len(st.hot_latencies),
        hot_acquire_p50=_pct(st.hot_latencies, 0.50),
        hot_acquire_p99=_pct(st.hot_latencies, 0.99),
        hot_acquire_max=(max(st.hot_latencies)
                         if st.hot_latencies else 0.0),
        hot_remote_acquires=len(st.hot_rcas),
        hot_rcas_mean=(sum(st.hot_rcas) / len(st.hot_rcas)
                       if st.hot_rcas else 0.0),
        hot_rcas_max=max(st.hot_rcas) if st.hot_rcas else 0,
        sheds=sum(r["sheds"] for r in rows),
        hedges=sum(r["hedges"] for r in rows),
        deadline_exceeded=sum(r["deadline_exceeded"] for r in rows),
        op_timeouts=sum(r["timeouts"] for r in rows),
        fabric_retries=sum(r["fabric_retries"] for r in rows),
        breaker_trips=orep.get("breaker_trips", 0),
        breaker_refusals=orep.get("breaker_refusals", 0),
        budget_refusals=orep.get("budget_refusals", 0),
        offered_load=offered_load,
        storm_offered=st.offered,
        storm_goodput=st.goodput,
        storm_goodput_shared=st.goodput_shared,
        storm_shed=st.shed_ops,
        storm_deadline_misses=st.deadline_misses,
        storm_late_grants=st.late_grants,
        storm_acquire_p50=_pct(st.storm_latencies, 0.50),
        storm_acquire_p99=_pct(st.storm_latencies, 0.99),
        opt_reads=opt_reads,
        opt_read_retries=sum(r["opt_read_retries"] for r in rows),
        opt_read_fallbacks=sum(r["opt_read_fallbacks"] for r in rows),
        opt_read_fwd=sum(r["opt_read_fwd"] for r in rows),
        publishes=sum(r["publishes"] for r in rows),
        reads=st.reads,
        pipeline_flushes=sum(pl.stats["flushes"] for pl in pipes),
        pipeline_flushed_ops=sum(pl.stats["flushed_ops"] for pl in pipes),
        pipeline_hedge_rides=sum(pl.stats["hedge_rides"] for pl in pipes),
        doorbells_per_op=doorbells_per_op,
        cost={"local": vars(totals[LOCAL]).copy(),
              "remote": vars(totals[REMOTE]).copy()},
        mode_cost={
            f"{mode.label}_{cls_name}": vars(mode_totals[mode][cls]).copy()
            for mode in LeaseMode
            for cls_name, cls in (("local", LOCAL), ("remote", REMOTE))
        },
        events=engine.events,
        spins=engine.spins,
        wall_seconds=wall,
        per_client=st.per_client,
        table=table,
    )
