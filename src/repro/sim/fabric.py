"""Virtual-time RDMA fabric: the asymmetric memory with latency as charges.

The threaded benchmark injects fabric latency with ``time.sleep`` per remote
posting; here every operation instead **advances the virtual clock** by a
modeled cost, so a sweep's timeline is exact, deterministic, and free — the
wall clock never enters the simulated history.

The cost model prices what the hardware prices:

* a **local** register op costs ``local_op`` (cache-coherent access);
* an individually-posted remote op costs ``doorbell + wr`` (MMIO doorbell +
  one work request through the NIC);
* a :meth:`~repro.core.AsymmetricMemory.post_batch` of N work requests costs
  ``doorbell + N*wr`` — the doorbell amortises, which is exactly what WR-list
  coalescing buys and what the threaded bench's per-posting sleep modeled.

The defaults keep the paper's ~10× local/remote asymmetry at the same 20 µs
remote-posting figure the threaded bench uses, so virtual throughputs land in
a comparable regime.

Faulty fabric
-------------

:class:`FabricFaults` turns the loss-free fabric into a lossy one, still
deterministic per seed.  Every remote *posting* passes a gate that can

* **drop** it (seeded Bernoulli, an armed ``fabric.drop`` injector point, a
  link **flap** window, a partition **cut**, or a **dead host**) — the poster
  discovers the loss at the op-level timeout (``op_timeout`` virtual seconds
  charged, ``OpCounts.timeouts`` incremented) and reposts on a seeded
  exponential-backoff schedule (``OpCounts.retries``);
* **delay** it (extra latency, nothing lost);
* **duplicate** it (the work request executes twice — reads and writes are
  idempotent, a duplicated CAS observes its own swap and no-ops, which is
  exactly why the lease word is CAS-only).

Loss classes differ in how they end:

* *random drops* end on a retry draw; past ``max_retries`` the op raises
  :class:`~repro.core.RemoteTimeout` (the QP-retry-exhausted error);
* *flaps and partitions* have a scheduled heal time: the poster blocks,
  charging timeout+backoff rounds, until the window closes — an op in flight
  across a transient cut is late, not failed;
* *dead hosts* never heal: after ``max_retries`` rounds the op raises
  :class:`~repro.core.RemoteTimeout`, which is how a home-host death becomes
  visible to its remote clients.

``probe`` (the failure-detector read) never blocks and never raises: one
timeout charge, then :data:`~repro.core.memory.TIMEOUT`.
"""

from __future__ import annotations

import bisect
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core import AsymmetricMemory
from repro.core.memory import TIMEOUT, RemoteTimeout

from .engine import SimEngine

__all__ = ["FabricFaults", "FabricLatency", "SimFabricMemory"]

_INF = float("inf")


@dataclass(frozen=True)
class FabricLatency:
    """Virtual seconds charged per operation component."""

    local_op: float = 2e-6    # machine-local register access
    doorbell: float = 20e-6   # one posting: MMIO write + NIC WR fetch
    wr: float = 1e-6          # per work request executed by the RNIC


class FabricFaults:
    """A seeded fault plan for :class:`SimFabricMemory`.

    All randomness comes from a dedicated stream keyed on ``seed`` (the run
    seed), so the same seed loses the same postings at the same arrivals —
    CI diffs two runs byte-for-byte.  ``injector`` optionally wires a
    :class:`~repro.coord.FaultInjector` in, so one-shot ``fabric.drop`` /
    ``fabric.dup`` / ``fabric.delay`` triggers (and explicitly-labeled
    seeded storms) land on exact postings — that is how the crash matrix
    crosses host-crash cells with message-loss cells.

    ``flaps`` is a schedule of ``(host, start, end)`` windows during which
    every remote posting to or from ``host`` is lost; ``partitions`` is a
    schedule of ``(hosts, start, end)`` cuts during which postings crossing
    the ``hosts`` / non-``hosts`` boundary are lost.  Both heal at ``end``.
    ``fail_host`` marks a host's memory permanently unreachable from ``at``
    onward (home-host death).
    """

    def __init__(self, seed: int = 0, drop_prob: float = 0.0,
                 dup_prob: float = 0.0, delay_prob: float = 0.0,
                 extra_delay: float = 60e-6, op_timeout: float = 150e-6,
                 max_retries: int = 6, retry_base: float = 25e-6,
                 retry_cap: float = 400e-6,
                 flaps: Tuple[Tuple[int, float, float], ...] = (),
                 partitions: Tuple[Tuple[frozenset, float, float], ...] = (),
                 congest_capacity: Optional[int] = None,
                 congest_delay: float = 12e-6,
                 congest_window: float = 200e-6,
                 congest_cap: float = 800e-6,
                 injector=None):
        if op_timeout <= 0:
            raise ValueError("op_timeout must be positive")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        self.drop_prob = float(drop_prob)
        self.dup_prob = float(dup_prob)
        self.delay_prob = float(delay_prob)
        self.extra_delay = float(extra_delay)
        self.op_timeout = float(op_timeout)
        self.max_retries = int(max_retries)
        self.retry_base = float(retry_base)
        self.retry_cap = float(retry_cap)
        self.flaps = tuple(flaps)
        self.partitions = tuple(
            (frozenset(g), float(s), float(e)) for g, s, e in partitions)
        # Congestion model (off unless congest_capacity is set): each host
        # serves up to ``congest_capacity`` postings per ``congest_window``
        # for free; every posting beyond that queues ``congest_delay``
        # virtual seconds per excess op (capped at ``congest_cap``) — the
        # convex service-time curve that makes retry storms metastable.
        self.congest_capacity = (None if congest_capacity is None
                                 else int(congest_capacity))
        self.congest_delay = float(congest_delay)
        self.congest_window = float(congest_window)
        self.congest_cap = float(congest_cap)
        self.injector = injector
        self.dead: Dict[int, float] = {}  # host -> unreachable-from time
        self.stats = {"drops": 0, "dups": 0, "delays": 0, "probe_losses": 0,
                      "congested": 0}
        self._rng = random.Random(0x0FAB51C * (seed + 1))

    # ------------------------------------------------------------- schedule
    def fail_host(self, host: int, at: float) -> None:
        """Mark ``host``'s memory partition unreachable from ``at`` on."""
        self.dead[host] = min(float(at), self.dead.get(host, _INF))

    def cut_until(self, src: int, dst: int, now: float) -> Optional[float]:
        """Heal time of the widest cut between ``src`` and ``dst`` active at
        ``now`` — ``inf`` for a dead target, ``None`` when the path is up."""
        end = None
        if self.dead.get(dst, _INF) <= now:
            return _INF
        for host, s, e in self.flaps:
            if host in (src, dst) and s <= now < e:
                end = e if end is None else max(end, e)
        for group, s, e in self.partitions:
            if s <= now < e and (src in group) != (dst in group):
                end = e if end is None else max(end, e)
        return end

    # -------------------------------------------------------------- drawing
    def _point(self, label: str, pid: int) -> bool:
        inj = self.injector
        return inj is not None and inj.fabric_point(label, pid)

    def draw_drop(self, p, dst: int, now: float) -> Optional[float]:
        """None = delivered; else the heal time bound for this loss
        (``inf`` when only bounded retries apply)."""
        end = self.cut_until(p.node, dst, now)
        if end is not None:
            return end
        if self._point("fabric.drop", p.pid):
            return _INF
        if self.drop_prob and self._rng.random() < self.drop_prob:
            return _INF
        return None

    def draw_delay(self, p) -> bool:
        if self._point("fabric.delay", p.pid):
            return True
        return bool(self.delay_prob) and self._rng.random() < self.delay_prob

    def draw_dup(self, p) -> bool:
        if self._point("fabric.dup", p.pid):
            return True
        return bool(self.dup_prob) and self._rng.random() < self.dup_prob

    def backoff(self, attempt: int) -> float:
        """PR 7's seeded expo-backoff shape: doubling base, jitter, cap."""
        base = min(self.retry_base * (2.0 ** max(attempt - 1, 0)),
                   self.retry_cap)
        return base * (0.5 + self._rng.random())


class SimFabricMemory(AsymmetricMemory):
    """``AsymmetricMemory`` whose operation latencies charge a virtual clock.

    Plug the owning :class:`~repro.sim.SimEngine` in and every register
    operation advances ``engine.clock`` by its modeled cost before executing.
    Semantics (Table-1 atomicity, per-class accounting, doorbell counting)
    are inherited unchanged — only *when* things happen becomes simulated.
    The engine's ``yield_point`` is installed as the spin hook so stray
    cross-task spins fail deterministically instead of hanging.

    Pass ``faults=FabricFaults(...)`` to make the fabric lossy (see the
    module docstring); without it every posting is delivered first try and
    the legacy timelines are byte-identical.
    """

    def __init__(self, num_nodes: int, engine: SimEngine,
                 latency: FabricLatency = FabricLatency(),
                 faults: Optional[FabricFaults] = None):
        super().__init__(
            num_nodes,
            sched=None,
            clock=engine.clock,
            yield_point=engine.yield_point,
        )
        self.engine = engine
        self.latency = latency
        self.faults = faults
        self._advance = engine.clock.advance
        # Per-host recent-posting times for the congestion model (sorted;
        # sim steps are atomic so no locking).  Only populated when the
        # fault plan prices congestion.
        self._load: Dict[int, List[float]] = {}

    # ----------------------------------------------------------- congestion
    def _congest(self, p, node: int) -> None:
        """Charge queueing delay for one delivered posting to ``node``.

        The host's observed load is the count of postings that reached it in
        the trailing ``congest_window``; every posting past
        ``congest_capacity`` queues ``congest_delay`` per excess op (capped).
        Purely a function of the event history, so two same-seed runs charge
        identical delays.  An armed ``fabric.congest`` injector point forces
        one congestion quantum onto a specific posting regardless of load.
        """
        f = self.faults
        if f is None or (f.congest_capacity is None and f.injector is None):
            return
        excess = 0
        if f.congest_capacity is not None:
            now = self.engine.clock.now
            q = self._load.setdefault(node, [])
            cutoff = now - f.congest_window
            drop = bisect.bisect_left(q, cutoff)
            if drop:
                del q[:drop]
            bisect.insort(q, now)
            excess = len(q) - f.congest_capacity
        if f._point("fabric.congest", p.pid):
            excess = max(excess, 1)
        if excess > 0:
            self._advance(min(excess * f.congest_delay, f.congest_cap))
            f.stats["congested"] += 1

    # ------------------------------------------------------------ fault gate
    def _remote_gate(self, p, node: int) -> bool:
        """Admit one remote posting from ``p`` to ``node``.

        Burns timeout+backoff rounds for every lost transmission (transient
        cuts block until their heal time; random losses and dead hosts raise
        :class:`RemoteTimeout` past ``max_retries``).  Returns whether the
        delivered posting is also duplicated.
        """
        f = self.faults
        if f is None:
            return False
        attempts = 0
        while True:
            heal = f.draw_drop(p, node, self.engine.clock.now)
            if heal is None:
                break
            # The posting is lost; the poster only learns at the op timeout.
            self._advance(f.op_timeout)
            p.counts.timeouts += 1
            f.stats["drops"] += 1
            attempts += 1
            if heal == _INF and attempts > f.max_retries:
                raise RemoteTimeout(
                    f"p{p.pid}@n{p.node} -> n{node}: remote posting lost "
                    f"{attempts} times (max_retries={f.max_retries})")
            self._advance(f.backoff(attempts))
            p.counts.retries += 1
        if f.draw_delay(p):
            self._advance(f.extra_delay)
            f.stats["delays"] += 1
        if f.draw_dup(p):
            f.stats["dups"] += 1
            return True
        return False

    # ---------------------------------------------------------- local charges
    def read(self, p, reg):
        self._advance(self.latency.local_op)
        return super().read(p, reg)

    def write(self, p, reg, value):
        self._advance(self.latency.local_op)
        super().write(p, reg, value)

    def cas(self, p, reg, expected, swap):
        self._advance(self.latency.local_op)
        return super().cas(p, reg, expected, swap)

    # --------------------------------------------------------- remote charges
    def rread(self, p, reg):
        dup = self._remote_gate(p, reg.node)
        self._congest(p, reg.node)
        self._advance(self.latency.doorbell + self.latency.wr)
        v = super().rread(p, reg)
        if dup:  # the retransmitted read executes again; same value, in-step
            self._advance(self.latency.wr)
        return v

    def rwrite(self, p, reg, value):
        dup = self._remote_gate(p, reg.node)
        self._congest(p, reg.node)
        self._advance(self.latency.doorbell + self.latency.wr)
        super().rwrite(p, reg, value)
        if dup:  # duplicated write re-applies the same value: idempotent
            self._advance(self.latency.wr)
            with reg._lock:
                reg._value = value

    def rcas(self, p, reg, expected, swap):
        dup = self._remote_gate(p, reg.node)
        self._congest(p, reg.node)
        self._advance(self.latency.doorbell + self.latency.wr)
        v = super().rcas(p, reg, expected, swap)
        if dup:
            # Duplicate delivery re-executes the compare-and-swap.  If the
            # first application succeeded, the duplicate observes the swap
            # and no-ops — the reason the lease word tolerates at-least-once
            # delivery is that every mutation is a CAS.
            self._advance(self.latency.wr)
            self._rcas_execute(reg, expected, swap)
        return v

    def post_batch(self, p, wrs):
        wrs = list(wrs)
        if not wrs:  # an empty posting rings no doorbell (and costs nothing)
            return super().post_batch(p, wrs)
        dup = self._remote_gate(p, wrs[0][1].node)
        self._congest(p, wrs[0][1].node)
        self._advance(self.latency.doorbell + self.latency.wr * len(wrs))
        out = super().post_batch(p, wrs)
        if dup:  # the WR list redelivers whole: reads/writes idempotent,
            self._advance(self.latency.wr * len(wrs))  # CASes observe swaps
            for wr in wrs:
                if wr[0] == "write":
                    with wr[1]._lock:
                        wr[1]._value = wr[2]
                elif wr[0] == "cas":
                    self._rcas_execute(wr[1], wr[2], wr[3])
        return out

    # ------------------------------------------------------------- probing
    def probe(self, p, reg):
        """Failure-detector read: give up after ONE op timeout, never block.

        A membership monitor must stay live while the probed host is not;
        a lost probe charges one timeout and returns
        :data:`~repro.core.memory.TIMEOUT` for the suspicion estimator to
        count, instead of riding the retry schedule.
        """
        if p.is_local_to(reg):
            self._advance(self.latency.local_op)
            return super().read(p, reg)
        f = self.faults
        if f is not None:
            heal = f.cut_until(p.node, reg.node, self.engine.clock.now)
            if heal is None and f.drop_prob \
                    and f._rng.random() < f.drop_prob:
                heal = _INF
            if heal is not None:
                self._advance(f.op_timeout)
                p.counts.timeouts += 1
                f.stats["probe_losses"] += 1
                return TIMEOUT
        # Delivered first try: bypass the retry gate (a probe never reposts)
        # — but a congested host queues probes like any other posting, which
        # is exactly the latency signal the hedging threshold tracks.
        self._congest(p, reg.node)
        self._advance(self.latency.doorbell + self.latency.wr)
        return super().rread(p, reg)
