"""Virtual-time RDMA fabric: the asymmetric memory with latency as charges.

The threaded benchmark injects fabric latency with ``time.sleep`` per remote
posting; here every operation instead **advances the virtual clock** by a
modeled cost, so a sweep's timeline is exact, deterministic, and free — the
wall clock never enters the simulated history.

The cost model prices what the hardware prices:

* a **local** register op costs ``local_op`` (cache-coherent access);
* an individually-posted remote op costs ``doorbell + wr`` (MMIO doorbell +
  one work request through the NIC);
* a :meth:`~repro.core.AsymmetricMemory.post_batch` of N work requests costs
  ``doorbell + N*wr`` — the doorbell amortises, which is exactly what WR-list
  coalescing buys and what the threaded bench's per-posting sleep modeled.

The defaults keep the paper's ~10× local/remote asymmetry at the same 20 µs
remote-posting figure the threaded bench uses, so virtual throughputs land in
a comparable regime.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core import AsymmetricMemory

from .engine import SimEngine

__all__ = ["FabricLatency", "SimFabricMemory"]


@dataclass(frozen=True)
class FabricLatency:
    """Virtual seconds charged per operation component."""

    local_op: float = 2e-6    # machine-local register access
    doorbell: float = 20e-6   # one posting: MMIO write + NIC WR fetch
    wr: float = 1e-6          # per work request executed by the RNIC


class SimFabricMemory(AsymmetricMemory):
    """``AsymmetricMemory`` whose operation latencies charge a virtual clock.

    Plug the owning :class:`~repro.sim.SimEngine` in and every register
    operation advances ``engine.clock`` by its modeled cost before executing.
    Semantics (Table-1 atomicity, per-class accounting, doorbell counting)
    are inherited unchanged — only *when* things happen becomes simulated.
    The engine's ``yield_point`` is installed as the spin hook so stray
    cross-task spins fail deterministically instead of hanging.
    """

    def __init__(self, num_nodes: int, engine: SimEngine,
                 latency: FabricLatency = FabricLatency()):
        super().__init__(
            num_nodes,
            sched=None,
            clock=engine.clock,
            yield_point=engine.yield_point,
        )
        self.engine = engine
        self.latency = latency
        self._advance = engine.clock.advance

    # ---------------------------------------------------------- local charges
    def read(self, p, reg):
        self._advance(self.latency.local_op)
        return super().read(p, reg)

    def write(self, p, reg, value):
        self._advance(self.latency.local_op)
        super().write(p, reg, value)

    def cas(self, p, reg, expected, swap):
        self._advance(self.latency.local_op)
        return super().cas(p, reg, expected, swap)

    # --------------------------------------------------------- remote charges
    def rread(self, p, reg):
        self._advance(self.latency.doorbell + self.latency.wr)
        return super().rread(p, reg)

    def rwrite(self, p, reg, value):
        self._advance(self.latency.doorbell + self.latency.wr)
        super().rwrite(p, reg, value)

    def rcas(self, p, reg, expected, swap):
        self._advance(self.latency.doorbell + self.latency.wr)
        return super().rcas(p, reg, expected, swap)

    def post_batch(self, p, wrs):
        wrs = list(wrs)
        if wrs:  # an empty posting rings no doorbell (and costs nothing)
            self._advance(self.latency.doorbell + self.latency.wr * len(wrs))
        return super().post_batch(p, wrs)
