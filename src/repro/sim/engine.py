"""Deterministic discrete-event engine: virtual time for the lock stack.

The threaded benchmarks run clients as OS threads over wall-clock
``time.sleep`` — which caps a run at a handful of hosts and makes identical
configs scatter ±30 % across seeds.  This engine replaces both: a **virtual
clock** that only moves when the simulation says so, and a **seeded
scheduler** that runs cooperative client *tasks* (plain Python generators)
one at a time in a fully reproducible order.  Two runs with the same seed
execute the same events in the same order and produce byte-identical
telemetry; 64 hosts × 16 clients is just 1024 generators on one thread.

Execution model
---------------

* A task is a generator.  Each ``next()`` runs one **step**; the value it
  yields is how long (in virtual seconds) to park before the next step
  (``None``/``0`` ⇒ reschedule at the current instant, behind any event
  already due).  A step runs **atomically**: no other task interleaves with
  it, so everything a step does (a whole lock-table transaction, say) is a
  single indivisible action in the simulated history.  Interleaving
  granularity is therefore *one step* — coarser than the threaded stress
  tests' per-register preemption, and exactly the granularity the per-class
  operation counts are stated at.
* Code running inside a step charges virtual time through
  :meth:`VirtualClock.advance` (the sim fabric does this per doorbell /
  work request — see ``repro.sim.fabric``) and reads it through the clock's
  call operator, which is what ``ShardedLockTable(clock=...)`` expects.
* A step starts at its scheduled instant and its charges extend **only its
  own task's timeline**: the task's next event lands at step start + charges
  + the yielded delay.  Different tasks' charged durations therefore overlap
  in virtual time, the way parallel clients overlap on real hardware — a
  1024-client fleet is not serialised onto one virtual pipe.  The cost of
  that parallelism is bounded clock skew: a step's register effects apply
  atomically at its *start*, and the global clock rebases to each step's
  start (monotonic per task, and dispatch is globally time-sorted, but not
  monotonic across consecutive steps of different tasks).
* Events due at the same instant are ordered by a **seeded** tie-break: a
  per-scheduling draw from ``random.Random(seed)``.  Same seed ⇒ same
  order; different seeds explore different interleavings (the virtual-time
  analogue of ``make_scheduler``'s yield fuzzing).

Blocking code and the livelock guard
------------------------------------

Because steps are atomic, a *cross-task* busy-wait inside a step (e.g. an
ALock spin waiting for another client) can never be satisfied — the other
task cannot run until the step ends.  The lock stack's spin loops all route
through ``AsymmetricMemory.yield_point``; in sim mode that hook is
:meth:`SimEngine.yield_point`, which charges a small spin cost and raises
:class:`SimLivelockError` after ``spin_limit`` iterations inside one step.
In a correctly-structured sim workload (non-blocking table calls, or
blocking calls bounded by a timeout on the same virtual clock) the guard
never fires; if it does, it names a real modeling bug instead of hanging.

``SimEngine.sleep_inline`` is the matching hook for the table's injected
``sleep``: it advances the clock in place, so a *timeout-bounded* blocking
call (``acquire(..., timeout=...)``/``acquire_batch``) terminates in zero
wall time — the poll loop charges virtual time until the deadline trips.
Its guard is a per-step budget of *virtual seconds slept* (``sleep_horizon``,
default one virtual hour), so any sane timeout passes regardless of poll
granularity while an untimed blocking call still fails deterministically.
"""

from __future__ import annotations

import heapq
import itertools
import random
from typing import Callable, Dict, Generator, List, Optional, Tuple

__all__ = ["SimEngine", "SimLivelockError", "VirtualClock"]


class SimLivelockError(RuntimeError):
    """A spin loop inside one atomic task step exceeded the spin limit.

    With atomic steps, a condition another task must establish cannot change
    mid-step — the spin would run forever.  Raising (deterministically, at a
    fixed iteration count) converts the hang into a diagnosable failure.
    """


class VirtualClock:
    """A monotonic virtual clock: ``clock()`` reads, ``advance(dt)`` moves.

    Drop-in for the ``clock`` hooks throughout the stack
    (``ShardedLockTable``, ``CoordinationService``, ``AsymmetricMemory``):
    a zero-argument callable returning seconds as a float.
    """

    __slots__ = ("now",)

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance the virtual clock by {dt}")
        self.now += dt
        return self.now


class SimEngine:
    """Seeded discrete-event scheduler over cooperative generator tasks."""

    def __init__(self, seed: int = 0, spin_cost: float = 0.5e-6,
                 spin_limit: int = 100_000, sleep_horizon: float = 3600.0):
        self.seed = seed
        self.clock = VirtualClock()
        self.spin_cost = spin_cost
        self.spin_limit = spin_limit
        self.sleep_horizon = sleep_horizon
        self._rng = random.Random(seed)
        self._seq = itertools.count()  # FIFO among equal (time, tiebreak)
        self._heap: List[Tuple[float, float, int, Generator]] = []
        self._live = 0
        self.events = 0   # task steps dispatched
        self.spins = 0    # total yield_point invocations
        self.kills = 0    # exceptions delivered via kill()
        self._step_spins = 0
        self._step_slept = 0.0
        # Pending crash deliveries: task -> exception, thrown into the task
        # at its next dispatch (see kill()).
        self._interrupts: Dict[Generator, BaseException] = {}

    # ------------------------------------------------------------- scheduling
    def spawn(self, task: Generator, delay: float = 0.0) -> Generator:
        """Register a generator task; its first step runs at ``now+delay``."""
        if not hasattr(task, "send"):
            raise TypeError(f"task must be a generator, got {type(task)!r}")
        self._live += 1
        self._push(task, self.clock.now + float(delay))
        return task

    def _push(self, task: Generator, at: float) -> None:
        # The seeded tie-break: equal-time events run in an order drawn from
        # the engine RNG (deterministic per seed, diverse across seeds).  The
        # monotone sequence number keeps the tuple comparison from ever
        # reaching the (unorderable) generator object.
        heapq.heappush(
            self._heap, (at, self._rng.random(), next(self._seq), task)
        )

    def kill(self, task: Generator, exc: BaseException) -> None:
        """Deliver ``exc`` into ``task`` at its **next dispatch** (thrown at
        the yield where the task is parked), modeling a process crash.

        Delivery-at-dispatch keeps the crash deterministic and honest: a
        step is atomic, so a process cannot die *mid-step* from the
        outside — it dies the next time it would have acted, which is what
        a silently-dead host looks like to the rest of the cluster.  (For
        crashes *inside* a protocol window, use the synchronous
        ``FaultInjector`` crash points instead — the two compose.)  The
        task must catch the exception to survive as a restarted client;
        an uncaught delivery propagates out of :meth:`run`, turning an
        unhandled crash into a visible test failure.  Killing the same
        task again before it runs replaces the pending exception."""
        self._interrupts[task] = exc

    @property
    def live_tasks(self) -> int:
        return self._live

    @property
    def pending_events(self) -> int:
        return len(self._heap)

    # ------------------------------------------------- in-step blocking hooks
    def yield_point(self) -> None:
        """Spin-loop hook (``AsymmetricMemory.yield_point`` in sim mode)."""
        self.spins += 1
        self._step_spins += 1
        if self._step_spins > self.spin_limit:
            raise SimLivelockError(
                f"{self._step_spins} spin iterations inside one atomic task "
                "step: a cross-task wait can never be satisfied mid-step "
                "(use non-blocking table calls, or bound the wait with a "
                "timeout on the sim clock)"
            )
        self.clock.advance(self.spin_cost)

    def sleep_inline(self, dt: float) -> None:
        """Charging sleep (``ShardedLockTable(sleep=...)`` in sim mode).

        Advances virtual time in place: a timeout-bounded poll loop burns
        virtual seconds until its deadline fires, costing zero wall time.
        The budget here is *virtual time slept per step* (``sleep_horizon``),
        not iterations — a legitimate 60 s timeout at a 0.5 ms poll needs
        120 000 polls and must not trip the spin guard, while an *untimed*
        blocking call would sleep the clock toward infinity and instead
        fails deterministically at the horizon.
        """
        self.clock.advance(dt)
        self._step_slept += dt
        if self._step_slept > self.sleep_horizon:
            raise SimLivelockError(
                f"slept {self._step_slept:.1f} virtual seconds inside one "
                "atomic task step (sleep_horizon="
                f"{self.sleep_horizon}): an unbounded blocking call cannot "
                "make progress in sim mode (pass a timeout, or restructure "
                "as try/yield)"
            )

    # -------------------------------------------------------------------- run
    def run(self, until: Optional[float] = None,
            stop: Optional[Callable[[], bool]] = None,
            max_events: Optional[int] = None) -> float:
        """Dispatch events until the heap drains, ``until`` passes, ``stop()``
        turns true (checked between steps), or ``max_events`` steps ran.

        Returns the virtual time.  ``max_events`` exhaustion raises — a sim
        that needs more steps than its author budgeted is livelocked or
        mis-scaled, and silently stopping would corrupt the measurements.
        """
        heap = self._heap
        dispatched = 0
        while heap:
            if stop is not None and stop():
                break
            at = heap[0][0]
            if until is not None and at > until:
                self.clock.now = max(self.clock.now, until)
                break
            if max_events is not None and dispatched >= max_events:
                raise SimLivelockError(
                    f"simulation exceeded max_events={max_events} "
                    f"(virtual t={self.clock.now:.6f}s, "
                    f"{self._live} live tasks)"
                )
            _, _, _, task = heapq.heappop(heap)
            # The step runs on ITS task's timeline: rebase the clock to the
            # step's scheduled instant (which may be earlier than the charged
            # end-time of the previous step — tasks' work overlaps in virtual
            # time, the way parallel clients overlap on real hardware).
            # Dispatch order is still globally time-sorted, and each task's
            # own timeline is monotonic.
            self.clock.now = at
            self.events += 1
            dispatched += 1
            self._step_spins = 0
            self._step_slept = 0.0
            exc = self._interrupts.pop(task, None)
            try:
                if exc is not None:
                    self.kills += 1
                    delay = task.throw(exc)
                else:
                    delay = next(task)
            except StopIteration:
                self._live -= 1
                continue
            dt = 0.0 if delay is None else float(delay)
            if dt < 0:
                raise ValueError(f"task yielded a negative delay {dt}")
            # Reschedule relative to *post-step* time: the step may have
            # charged the clock (fabric latency), and virtual time, like real
            # time, never runs backwards.
            self._push(task, self.clock.now + dt)
        return self.clock.now
