"""Virtual-time discrete-event simulation of the RDMA lock stack.

``SimEngine`` runs cooperative generator tasks against a ``VirtualClock``
with a seeded, fully deterministic scheduler; ``SimFabricMemory`` prices
every register operation as a virtual-time charge (local op, doorbell, work
request); ``run_lock_table_sim`` drives the sharded lock table with
home/uniform/zipfian/failover client fleets at scales (64 hosts × 16
clients, 10⁵ ops) the thread-per-client benchmark cannot reach — producing
exact, byte-identical per-class operation counts per seed.

See ``docs/simulation.md`` for the execution model and how to write a
workload.
"""

from .engine import SimEngine, SimLivelockError, VirtualClock  # noqa: F401
from .fabric import FabricFaults, FabricLatency, SimFabricMemory  # noqa: F401
from .workloads import SIM_WORKLOADS, SimResult, run_lock_table_sim  # noqa: F401
