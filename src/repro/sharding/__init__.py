"""Logical-axis → mesh-axis sharding rules (DP/FSDP/TP/EP/SP)."""

from .rules import (  # noqa: F401
    ACT_RULES,
    PARAM_RULES,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    param_shardings,
)
