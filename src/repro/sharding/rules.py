"""Sharding rules for the production meshes.

Parameters are 2-D sharded: every weight matrix puts its "wide" structured
dim (vocab / heads / mlp / expert) on the ``model`` axis (TP/EP) and its
d_model dim on the ``data`` axis (FSDP / ZeRO-3 — XLA SPMD materialises the
all-gather-on-use + reduce-scatter-on-grad schedule).  Activations shard
batch on ``data`` and the head/mlp/vocab dim on ``model``.  The ``pod`` axis
never appears in parameter specs: parameters are replicated across pods and
reconciled by the cohort schedule (repro.core.cohort), which is the paper's
asymmetric design — the slow fabric only ever carries gradient fragments.

KV caches shard batch on ``data`` and heads on ``model`` (MLA latent caches
have no head dim — batch on ``data`` only).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..models.specs import pspec_tree, sharding_tree

__all__ = [
    "PARAM_RULES", "ACT_RULES", "param_pspecs", "param_shardings",
    "batch_pspec", "cache_pspecs",
]

# Logical axis name → mesh axis (parameters).
PARAM_RULES: Dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "mlp": "model",
    "expert": "model",
    "expert2d": ("data", "model"),  # pure EP: one expert per chip at E=256
    "embed": "data",     # FSDP shard of the d_model dim
    "mlp_fsdp": "data",  # FFN dim FSDP (MoE fsdp_f layout)
    "layers": None,      # scanned stack dim stays unsharded
}

# Logical activation axis → mesh axis.
ACT_RULES: Dict[str, Optional[str]] = {
    "batch": "data",
    "heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert2d": ("data", "model"),
    # d_model dim of *weights* gathered for lookup (embed table): FSDP shard.
    "embed_fsdp": "data",
}


def fit_pspec(ps: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes whose size does not divide the dim (jit in_shardings
    demand exact divisibility; internal constraints pad, input shardings
    don't).  E.g. hubert's vocab=504 on a 16-way model axis → replicated."""
    out = []
    for i, entry in enumerate(ps):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if shape[i] % size == 0 else None)
    return P(*out)


def fitted_shardings(shapes_tree, pspec_tree_, mesh: Mesh):
    """NamedShardings from parallel (ShapeDtypeStruct, PartitionSpec) trees,
    with per-leaf divisibility fitting."""
    return jax.tree.map(
        lambda s, ps: NamedSharding(mesh, fit_pspec(ps, s.shape, mesh)),
        shapes_tree,
        pspec_tree_,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


def param_pspecs(specs, rules: Optional[Dict] = None):
    return pspec_tree(specs, rules or PARAM_RULES)


def param_shardings(specs, mesh: Mesh, rules: Optional[Dict] = None):
    return sharding_tree(specs, mesh, rules or PARAM_RULES)


def batch_pspec(cfg: ModelConfig, shape: ShapeConfig, batch_axes=("data",)) -> Dict:
    """PartitionSpecs for the input batch dict (batch dim over data axes)."""
    b = P(batch_axes)
    out = {}
    if cfg.frontend == "audio":
        out["embeds"] = b
    elif cfg.frontend == "vision":
        out["embeds"] = b
        out["tokens"] = b
    else:
        out["tokens"] = b
    if shape.kind == "train":
        out["labels"] = b
    if shape.kind == "decode":
        out = {"tokens": b}
    return out


def _cache_leaf_pspec(leaf_shape, batch_axes, model_size: int = 0) -> P:
    """Caches: dim0 = batch → data. Head-ful leaves get model on the head dim.

    KVCache k/v [B, S, K, hd]: shard K over `model` when divisible, else the
    head-dim hd — GQA models with K < |model| would otherwise replicate the
    whole cache across the model axis (measured 34 GB/chip on llama3-8b
    decode_32k vs 2.2 GB sharded)."""
    if len(leaf_shape) == 4:
        if model_size and leaf_shape[2] % model_size != 0 \
                and leaf_shape[3] % model_size == 0:
            return P(batch_axes, None, None, "model")
        return P(batch_axes, None, "model", None)
    if len(leaf_shape) == 3 and model_size and leaf_shape[1] >= 1024 \
            and leaf_shape[1] % model_size == 0:
        # MLA latent caches [B, S, r] have no head dim: sequence-shard over
        # `model` (the 61-layer c_kv cache is 16 GB/chip replicated at
        # decode_32k batch 128, 1 GB sharded).
        return P(batch_axes, "model", None)
    if len(leaf_shape) == 0:
        return P()
    return P(batch_axes)


def cache_pspecs(cache_spec, batch_axes=("data",), mesh: Optional[Mesh] = None):
    """Specs for the full cache dict {lead, blocks, tail} from Model.cache."""
    msize = dict(mesh.shape).get("model", 0) if mesh is not None else 0

    def leaf_spec(leaf, stacked: bool):
        shape = leaf.shape[1:] if stacked else leaf.shape
        ps = _cache_leaf_pspec(shape, batch_axes, msize)
        if stacked:
            return P(None, *ps)
        return ps

    out = {}
    out["lead"] = jax.tree.map(
        lambda l: leaf_spec(l, False), cache_spec["lead"],
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    out["tail"] = jax.tree.map(
        lambda l: leaf_spec(l, False), cache_spec["tail"],
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    out["blocks"] = (
        jax.tree.map(
            lambda l: leaf_spec(l, True), cache_spec["blocks"],
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        if cache_spec["blocks"] is not None
        else None
    )
    return out
