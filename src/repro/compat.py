"""Version-portability shims for the jax mesh/shard_map API surface.

The codebase is written against the modern mesh-context API — ``jax.set_mesh``
as a context manager, ``jax.shard_map`` with ``axis_names``/``check_vma``,
``jax.sharding.get_abstract_mesh`` and ``AxisType``-tagged meshes.  Older jax
releases (0.4.x, which the pinned CI environment may ship) spell these
``Mesh.__enter__``, ``jax.experimental.shard_map.shard_map(..., auto=...,
check_rep=...)`` and have no abstract-mesh accessor at all.

Everything in the repo imports the four names below from here, so the version
difference lives in exactly one module:

* :func:`make_mesh`       — ``jax.make_mesh`` with/without ``axis_types``
* :func:`set_mesh`        — context manager installing the active mesh
* :func:`get_abstract_mesh` — the mesh installed by :func:`set_mesh`
* :func:`shard_map`       — keyword-compatible with the modern ``jax.shard_map``

On modern jax these are thin pass-throughs; on 0.4.x the active mesh is
tracked in a thread-local (tracing happens on the calling thread, so the
fallback agrees with jax's own scoping) and ``axis_names`` is translated to
the old API's complementary ``auto`` set.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence

import jax

_HAS_SET_MESH = hasattr(jax, "set_mesh")
_HAS_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")

_tls = threading.local()


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` that tolerates jax versions without ``axis_types``."""
    if devices is None:
        n = 1
        for s in shape:
            n *= s
        devices = jax.devices()[:n]
    if _HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
            devices=devices,
        )
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices)


if _HAS_SET_MESH:

    def set_mesh(mesh):
        """Install ``mesh`` as the ambient mesh (modern jax pass-through)."""
        return jax.set_mesh(mesh)

else:

    @contextlib.contextmanager
    def set_mesh(mesh):
        """Install ``mesh`` via the legacy ``with mesh:`` resource context."""
        prev = getattr(_tls, "mesh", None)
        _tls.mesh = mesh
        try:
            with mesh:
                yield mesh
        finally:
            _tls.mesh = prev


def get_abstract_mesh():
    """The mesh installed by :func:`set_mesh` (or ``None`` outside one).

    Keyed off ``_HAS_SET_MESH``, not the accessor's own existence: on jax
    versions that grew ``get_abstract_mesh`` before ``set_mesh``, our
    fallback ``set_mesh`` records the mesh in the thread-local, and asking
    jax instead would return an empty mesh that disagrees with it.
    """
    if _HAS_SET_MESH and _HAS_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    return getattr(_tls, "mesh", None)


def shard_map(f, *, mesh=None, in_specs, out_specs,
              axis_names: Optional[frozenset] = None, check_vma: bool = False):
    """Keyword-compatible ``jax.shard_map`` across jax versions.

    ``axis_names`` is the set of *manual* axes (modern spelling); on old jax
    it is translated to the complementary ``auto`` set.  ``mesh=None`` uses
    the mesh installed by :func:`set_mesh`.
    """
    if _HAS_SHARD_MAP:
        kwargs: dict = dict(in_specs=in_specs, out_specs=out_specs,
                            check_vma=check_vma)
        if mesh is None and not _HAS_SET_MESH:
            # Modern shard_map but legacy mesh scoping: jax's own ambient
            # mesh is unset, so supply the one our set_mesh() tracked.
            mesh = get_abstract_mesh()
        if mesh is not None:
            kwargs["mesh"] = mesh
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    m = mesh if mesh is not None else get_abstract_mesh()
    if m is None:
        raise RuntimeError(
            "shard_map without an explicit mesh requires an active set_mesh() "
            "context (legacy-jax fallback tracks the mesh there)"
        )
    manual = frozenset(axis_names) if axis_names is not None else frozenset(m.axis_names)
    auto = frozenset(m.axis_names) - manual
    return _legacy_shard_map(
        f, mesh=m, in_specs=in_specs, out_specs=out_specs,
        check_rep=bool(check_vma), auto=auto,
    )
