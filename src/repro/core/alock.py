"""ALock — the paper's asymmetric mutual-exclusion primitive, plus baselines.

``ALock`` composes the modified Peterson's lock (Algorithm 1) with one
budgeted MCS queue lock per class (Algorithm 2).  Processes on the lock's home
node form the *local* class (cid 0) and never issue an RDMA operation;
everyone else forms the *remote* class (cid 1) and pays a bounded number of
RDMA operations per acquisition (1 rCAS, +1 rWrite when queued; release
≤ 1 rCAS + 1 rWrite) with no remote spinning after enqueue.

Baselines implemented for the paper's comparisons (§1, §3, §4):

* :class:`NaiveRCASLock` — everyone (including local processes, via RDMA
  *loopback*) spins with ``rCAS`` on one word.  Correct (the RNIC serialises
  remote RMWs) but local processes pay loopback and remote processes spin over
  the network; not starvation-free.
* :class:`RPCLock` — a server thread on the home node grants the lock FIFO
  over message queues; every operation costs a round-trip message, nullifying
  one-sided RDMA's benefit.
* :class:`FilterLock` — Peterson's n-process filter generalisation using only
  read/write registers (safe under asymmetry) but with remote spinning and
  O(n) remote accesses per acquisition even without contention — the
  pathology that motivates the paper's design (§3).
* :class:`BrokenMixedCASLock` — local ``CAS`` vs remote ``rCAS`` on the same
  word.  **Deliberately incorrect** under Table-1 atomicity; exists so the
  tests can demonstrate that the simulated memory reproduces the hazard the
  paper's design avoids.
"""

from __future__ import annotations

import itertools
import queue
import threading
from typing import Any, Dict, List, Optional

from .memory import NULLPTR, AsymmetricMemory, Process, Register
from .mcs import BudgetedMCSLock
from .peterson import ModifiedPetersonLock

_uid = itertools.count()

LOCAL, REMOTE = 0, 1


class ALock:
    """The paper's primitive: modified Peterson + per-class budgeted MCS."""

    def __init__(
        self,
        mem: AsymmetricMemory,
        home_node: int,
        init_budget: int = 4,
        name: Optional[str] = None,
    ):
        self.mem = mem
        self.home_node = home_node
        self.name = name or f"alock{next(_uid)}"
        # cohort[2]: the MCS tails double as the Peterson interested flags.
        tails = [
            mem.alloc(home_node, f"{self.name}.cohort{cid}", NULLPTR)
            for cid in (LOCAL, REMOTE)
        ]
        victim = mem.alloc(home_node, f"{self.name}.victim", LOCAL)
        self.cohorts = [
            BudgetedMCSLock(mem, tails[cid], init_budget, f"{self.name}.c{cid}")
            for cid in (LOCAL, REMOTE)
        ]
        self.global_lock = ModifiedPetersonLock(mem, victim, self.cohorts)
        for cid in (LOCAL, REMOTE):
            # Embed the global lock's reacquire into the cohort lock (the
            # budget-exhaustion fairness hook, Algorithm 2 line 12).
            self.cohorts[cid].p_reacquire = self._make_reacquire(cid)

    def _make_reacquire(self, cid: int):
        def hook(p: Process) -> None:
            self.global_lock.reacquire(p, cid)

        return hook

    def class_of(self, p: Process) -> int:
        """``getCid()``: locality of the process w.r.t. the lock's registers."""
        return LOCAL if p.node == self.home_node else REMOTE

    def lock(self, p: Process, piggyback_reads=None):
        """``pLock`` (Algorithm 1 lines 1-7).

        ``piggyback_reads`` — optional registers on the home node to read in
        the same doorbell as the (remote-class) Peterson engagement.  Returns
        their values when the fast entry validated them (see
        :meth:`ModifiedPetersonLock.acquire`), else ``None`` — in which case
        the caller must (re-)read inside the critical section.  Local-class
        callers and intra-cohort hand-offs always return ``None``.
        """
        cid = self.class_of(p)
        is_leader = self.cohorts[cid].q_lock(p)
        if is_leader:
            return self.global_lock.acquire(p, cid, piggyback_reads)
        # else: the global lock was passed to us inside the cohort.
        return None

    def unlock(self, p: Process, piggyback=None) -> None:
        """``pUnlock`` (Algorithm 1 lines 9-11).

        ``piggyback`` — optional ``("write", reg, value)`` WRs flushed while
        the critical section is still held; remote releasers chain them into
        the tail-drain doorbell (see :meth:`BudgetedMCSLock.q_unlock`).
        """
        self.cohorts[self.class_of(p)].q_unlock(p, piggyback)

    # Context-manager sugar used by the coordination service.
    class _Guard:
        def __init__(self, lk: "ALock", p: Process):
            self.lk, self.p = lk, p

        def __enter__(self):
            self.lk.lock(self.p)
            return self

        def __exit__(self, *exc):
            self.lk.unlock(self.p)
            return False

    def guard(self, p: Process) -> "ALock._Guard":
        return ALock._Guard(self, p)


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------
class NaiveRCASLock:
    """All processes use ``rCAS`` (locals via loopback).  Paper §3 ¶1."""

    def __init__(self, mem: AsymmetricMemory, home_node: int, name: Optional[str] = None):
        self.mem = mem
        self.name = name or f"naive{next(_uid)}"
        self.word = mem.alloc(home_node, f"{self.name}.word", 0)

    def lock(self, p: Process) -> None:
        # Loopback: even local processes go through the RNIC so that RMWs are
        # mutually atomic — the exact overhead the paper eliminates.
        while self.mem.rcas(p, self.word, 0, 1) != 0:
            self.mem.yield_point()  # remote spinning

    def unlock(self, p: Process) -> None:
        self.mem.rwrite(p, self.word, 0)


class RPCLock:
    """A server thread on the home node serialises lock grants (FIFO).

    Message counts stand in for the RPC round-trips the paper says nullify
    one-sided RDMA's benefit.  ``shutdown()`` must be called to join the
    server thread.
    """

    def __init__(self, mem: AsymmetricMemory, home_node: int):
        self.home_node = home_node
        self.requests: "queue.Queue[tuple]" = queue.Queue()
        self.grants: Dict[int, "queue.Queue"] = {}
        self.messages_sent: Dict[int, int] = {}
        self._guard = threading.Lock()
        self._stop = object()
        self._server = threading.Thread(target=self._serve, daemon=True)
        self._server.start()

    def _mailbox(self, p: Process) -> "queue.Queue":
        with self._guard:
            if p.pid not in self.grants:
                self.grants[p.pid] = queue.Queue()
                self.messages_sent[p.pid] = 0
            return self.grants[p.pid]

    def _serve(self) -> None:
        holder: Optional[int] = None
        waiting: List[int] = []
        while True:
            msg = self.requests.get()
            if msg is self._stop:
                return
            kind, pid = msg
            if kind == "lock":
                if holder is None:
                    holder = pid
                    self.grants[pid].put("granted")
                else:
                    waiting.append(pid)
            elif kind == "unlock":
                assert holder == pid, "RPC unlock by non-holder"
                if waiting:
                    holder = waiting.pop(0)
                    self.grants[holder].put("granted")
                else:
                    holder = None

    def lock(self, p: Process) -> None:
        box = self._mailbox(p)
        self.messages_sent[p.pid] += 1  # request
        self.requests.put(("lock", p.pid))
        box.get()  # reply (blocks until granted)
        self.messages_sent[p.pid] += 1  # count the reply round-trip

    def unlock(self, p: Process) -> None:
        self.messages_sent[p.pid] += 1
        self.requests.put(("unlock", p.pid))

    def shutdown(self) -> None:
        self.requests.put(self._stop)
        self._server.join(timeout=5)


class FilterLock:
    """Peterson's filter lock for n processes over read/write registers only.

    Correct under operation asymmetry (no RMW at all) but requires remote
    spinning and O(n) remote accesses per acquisition — the paper's argument
    for why the classic generalisations don't fit RDMA (§3).
    """

    def __init__(self, mem: AsymmetricMemory, home_node: int, pids: List[int]):
        self.mem = mem
        self.n = len(pids)
        self.slot = {pid: i for i, pid in enumerate(pids)}
        uid = next(_uid)
        self.level = [
            mem.alloc(home_node, f"filter{uid}.level{i}", -1) for i in range(self.n)
        ]
        self.victim = [
            mem.alloc(home_node, f"filter{uid}.victim{j}", -1) for j in range(self.n)
        ]

    def lock(self, p: Process) -> None:
        me = self.slot[p.pid]
        for lvl in range(1, self.n):
            self.mem.auto_write(p, self.level[me], lvl)
            self.mem.auto_write(p, self.victim[lvl], me)
            while self._exists_conflict(p, me, lvl):
                self.mem.yield_point()

    def _exists_conflict(self, p: Process, me: int, lvl: int) -> bool:
        if self.mem.auto_read(p, self.victim[lvl]) != me:
            return False
        for k in range(self.n):
            if k != me and self.mem.auto_read(p, self.level[k]) >= lvl:
                return True
        return False

    def unlock(self, p: Process) -> None:
        self.mem.auto_write(p, self.level[self.slot[p.pid]], -1)


class BrokenMixedCASLock:
    """DELIBERATELY BROKEN: local ``CAS`` mixed with remote ``rCAS``.

    Table 1: local and remote RMW are not mutually atomic, so this lock can
    admit two holders.  Used by tests to prove the memory model reproduces
    the hazard; never use outside tests.
    """

    def __init__(self, mem: AsymmetricMemory, home_node: int):
        self.mem = mem
        self.word = mem.alloc(home_node, f"broken{next(_uid)}.word", 0)

    def lock(self, p: Process) -> None:
        if p.is_local_to(self.word):
            while self.mem.cas(p, self.word, 0, 1) != 0:
                self.mem.yield_point()
        else:
            while self.mem.rcas(p, self.word, 0, 1) != 0:
                self.mem.yield_point()

    def unlock(self, p: Process) -> None:
        self.mem.auto_write(p, self.word, 0)
