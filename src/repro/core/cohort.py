"""Cohort-scheduled collectives — the paper's technique on the TPU fabric.

The paper synchronises two asymmetric classes by (1) electing a leader inside
each class with a mechanism optimal for that class, (2) running a minimal
2-party protocol between leaders, and (3) bounding consecutive same-class
hand-offs with a *budget*.  On a multi-pod TPU mesh the classes are the two
fabrics — intra-pod ICI ("local") and inter-pod DCN ("remote") — and the
technique becomes a hierarchical gradient-exchange schedule:

1. **cohort election** — intra-pod reduce-scatter: each chip becomes leader
   ("queue head") of a ``1/|data|`` fragment of the gradient;
2. **global protocol** — the per-fragment exchange over the ``pod`` axis only
   (2 pods ⇔ Peterson's two parties); only leaders touch the slow fabric,
   and only with their fragment;
3. **hand-off** — intra-pod all-gather redistributes the reduced fragment
   (the MCS lock pass: a local write, never a remote one);
4. **budget** — ``sync_budget`` local steps between DCN exchanges
   (``budget=1`` ⇔ exact synchronous DP; ``budget>1`` ⇔ bounded-staleness
   local sync, the fairness guarantee that the slow fabric is served at
   least every ``budget`` steps and stragglers stall the world at most that
   often).

Two integration points:

* :func:`cohort_all_reduce` — the standalone bucketed primitive (fully manual
  ``shard_map``), numerically equal to a flat ``psum`` over both axes; used by
  the collectives benchmark and tests.
* :func:`pod_sync` / :class:`BudgetedSync` — the trainer integration, called
  inside a ``shard_map`` whose only *manual* axis is ``pod`` (data/model axes
  stay under GSPMD, which implements the intra-pod reduce-scatter/all-gather
  as part of FSDP); supports int8 error-feedback compression so the DCN hop
  carries a quarter of the bytes (paper analogy: minimise *remote* operations,
  never touch local ones).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


# --------------------------------------------------------------------------
# Standalone primitive: bucketed cohort all-reduce (fully manual shard_map)
# --------------------------------------------------------------------------
def _flatten_bucket(tree) -> Tuple[jnp.ndarray, Any, Sequence[Tuple[Tuple[int, ...], Any]]]:
    """Flatten a pytree into one fp32 bucket (DDP-style) for one fused RS/AG."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1) for l in leaves])
    return flat, treedef, shapes


def _unflatten_bucket(flat, treedef, shapes):
    out, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        out.append(flat[off : off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def _cohort_body(flat: jnp.ndarray, cohort_axis: str, global_axis: str) -> jnp.ndarray:
    """RS(cohort) → AR(global, fragment) → AG(cohort). Shapes: [n] → [n]."""
    frag = lax.psum_scatter(flat, cohort_axis, scatter_dimension=0, tiled=True)
    frag = lax.psum(frag, global_axis)          # leaders' 2-party exchange
    return lax.all_gather(frag, cohort_axis, axis=0, tiled=True)


def cohort_all_reduce(
    tree,
    mesh: Mesh,
    cohort_axis: str = "data",
    global_axis: str = "pod",
    other_axes: Sequence[str] = (),
):
    """Hierarchical all-reduce of a (replicated) pytree over cohort+global axes.

    Numerically equivalent to ``psum(tree, (cohort_axis, global_axis))`` but
    with the explicit 3-phase schedule above.  ``other_axes`` are mesh axes the
    values are replicated over (e.g. "model"); the reduction does not touch
    them.  The bucket is zero-padded to a multiple of the cohort size.
    """
    cohort = mesh.shape[cohort_axis]

    def body(tree_in):
        flat, treedef, shapes = _flatten_bucket(tree_in)
        pad = (-flat.shape[0]) % cohort
        flat = jnp.pad(flat, (0, pad))
        red = _cohort_body(flat, cohort_axis, global_axis)
        red = red[: red.shape[0] - pad] if pad else red
        return _unflatten_bucket(red, treedef, shapes)

    # All mesh axes manual: the body is a pure collective schedule and the
    # value is replicated over every axis it does not reduce.
    spec = P()  # replicated in; replicated out (a true all-reduce)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec,),
        out_specs=spec,
        axis_names=frozenset(mesh.axis_names),
        check_vma=False,
    )
    return fn(tree)


def flat_all_reduce(tree, mesh: Mesh, axes: Sequence[str] = ("pod", "data")):
    """The paper-baseline: one flat psum spanning both fabrics (the analogue
    of every process hammering the global word with rCAS)."""
    fn = shard_map(
        lambda t: jax.tree.map(lambda x: lax.psum(x, tuple(axes)), t),
        mesh=mesh,
        in_specs=(P(),),
        out_specs=P(),
        axis_names=frozenset(mesh.axis_names),
        check_vma=False,
    )
    return fn(tree)


# --------------------------------------------------------------------------
# Trainer integration: pod-axis sync with budget + compression
# --------------------------------------------------------------------------
class SyncConfig(NamedTuple):
    """How the trainer crosses the slow fabric.

    mode:
      "none"     — single-pod / no pod axis: no-op.
      "sync"     — exact: psum gradients over the pod axis every step (the
                   cohort schedule emerges from FSDP sharding + this psum
                   acting on data-sharded fragments).
      "local"    — budgeted: gradients stay intra-pod; parameters are
                   pod-averaged every ``budget`` steps (bounded staleness,
                   straggler mitigation; exactness is traded for DCN quiet).
    compress_int8: apply int8 error-feedback compression to the DCN payload.
    budget: local steps between DCN syncs (must be ≥ 1).
    """

    mode: str = "sync"
    budget: int = 1
    compress_int8: bool = False
    pod_axis: str = "pod"


def _ef_quantize(x: jnp.ndarray, err: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """int8 quantisation with error feedback. Returns (q, scale, new_err)."""
    y = x + err
    scale = jnp.maximum(jnp.max(jnp.abs(y)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(x.dtype) * scale.astype(x.dtype)
    return q, scale, y - deq


def _pod_mean_int8_ef(x: jnp.ndarray, err: jnp.ndarray, axis_name: str):
    """Pod-mean of ``x`` where the wire carries int8 + one fp32 scale.

    all_gather of the quantised payload (P·n int8 bytes on the wire instead of
    2(P-1)/P·4n for an fp32 psum — 4× less for P=2) then a local dequant-sum.
    """
    q, scale, new_err = _ef_quantize(x, err)
    qs = lax.all_gather(q, axis_name, axis=0)          # [P, ...] int8
    ss = lax.all_gather(scale, axis_name, axis=0)      # [P]
    npods = qs.shape[0]
    deq = (qs.astype(x.dtype) * ss.reshape((npods,) + (1,) * x.ndim).astype(x.dtype))
    return jnp.sum(deq, axis=0) / npods, new_err


def pod_sync_grads(grads, cfg: SyncConfig, ef_state=None):
    """Cross-pod gradient exchange (call inside a manual-``pod`` shard_map).

    Returns (synced_grads, new_ef_state).  Gradients are *averaged* over the
    pod axis.  With ``compress_int8`` the DCN hop carries int8 payloads with
    per-leaf error-feedback residuals (``ef_state``).
    """
    if cfg.mode != "sync":
        return grads, ef_state
    if not cfg.compress_int8:
        return jax.tree.map(lambda g: lax.pmean(g, cfg.pod_axis), grads), ef_state
    if ef_state is None:
        ef_state = jax.tree.map(jnp.zeros_like, grads)
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e, _ = jax.tree.flatten(ef_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = _pod_mean_int8_ef(g, e, cfg.pod_axis)
        out_g.append(m)
        out_e.append(ne)
    return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)


def pod_average_params(params, cfg: SyncConfig, step: jnp.ndarray):
    """Budgeted parameter averaging ("local" mode): every ``budget`` steps the
    pods reconcile (the paper's ``pReacquire`` — the slow fabric is served on
    a bound, never starved)."""
    if cfg.mode != "local":
        return params
    do_sync = (step % cfg.budget) == (cfg.budget - 1)

    def avg(p):
        return jax.tree.map(lambda x: lax.pmean(x, cfg.pod_axis), p)

    return lax.cond(do_sync, avg, lambda p: p, params)


def wrap_step_with_pod_sync(
    step_fn: Callable,
    mesh: Mesh,
    cfg: SyncConfig,
    batch_spec,
    state_pod_spec=P(),
):
    """Lift a single-pod train step to the multi-pod mesh.

    ``step_fn(state, batch) -> (state, metrics)`` is written for the
    (data, model) axes under GSPMD.  This wrapper shard_maps it with ``pod``
    as the only manual axis: the batch splits across pods, gradients/params
    cross the DCN only through :func:`pod_sync_grads` /
    :func:`pod_average_params` calls that ``step_fn`` performs via the
    injected ``cfg``.  Metrics are pod-averaged.
    """
    if cfg.pod_axis not in mesh.shape:
        return step_fn  # single-pod: nothing to lift

    def lifted(state, batch):
        new_state, metrics = step_fn(state, batch)
        metrics = jax.tree.map(lambda m: lax.pmean(m, cfg.pod_axis), metrics)
        return new_state, metrics

    return shard_map(
        lifted,
        mesh=mesh,
        in_specs=(state_pod_spec, batch_spec),
        out_specs=(state_pod_spec, P()),
        axis_names=frozenset({cfg.pod_axis}),
        check_vma=False,
    )
