"""Operation-asymmetry cost model for the TPU fabric (paper §2 → TPU).

The paper's local/remote asymmetry maps onto the TPU interconnect hierarchy:
intra-pod ICI (the "local" class — fast, wraparound torus) vs inter-pod DCN
(the "remote" class — roughly an order of magnitude slower per chip, exactly
the local:RDMA cost ratio the paper cites for RDMA vs local memory access).

These constants and formulas feed the roofline analysis (launch/roofline.py)
and the napkin math recorded in EXPERIMENTS.md §Perf.  Collective cost uses
the standard bandwidth-optimal algorithm factors:

* all-reduce over axis of size ``a``: ``2 (a-1)/a × bytes`` on the wire
* reduce-scatter / all-gather:        ``(a-1)/a × bytes``
* all-to-all:                          ``(a-1)/a × bytes`` (each chip keeps 1/a)
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TPUv5e:
    """Per-chip hardware constants (the assignment's grading targets)."""

    peak_flops_bf16: float = 197e12     # FLOP/s
    hbm_bw: float = 819e9               # B/s
    ici_bw_per_link: float = 50e9       # B/s per ICI link (~unidirectional)
    ici_links_per_axis: int = 1         # links usable per mesh axis direction
    dcn_bw_per_chip: float = 6.25e9     # B/s per chip across pods (~ICI/8)
    hbm_bytes: float = 16e9             # HBM capacity

    # ------------------------------------------------------------- rooflines
    def compute_time(self, flops: float, chips: int = 1) -> float:
        return flops / (chips * self.peak_flops_bf16)

    def memory_time(self, bytes_: float, chips: int = 1) -> float:
        return bytes_ / (chips * self.hbm_bw)

    def collective_time(self, wire_bytes_per_chip: float, *, inter_pod: bool = False) -> float:
        """Time for ``wire_bytes_per_chip`` already adjusted by algo factors."""
        bw = self.dcn_bw_per_chip if inter_pod else (
            self.ici_bw_per_link * self.ici_links_per_axis
        )
        return wire_bytes_per_chip / bw


def allreduce_wire_bytes(payload_bytes: float, axis: int) -> float:
    """Per-chip wire bytes for a bandwidth-optimal all-reduce (RS+AG)."""
    return 2.0 * (axis - 1) / axis * payload_bytes


def reduce_scatter_wire_bytes(payload_bytes: float, axis: int) -> float:
    return (axis - 1) / axis * payload_bytes


def all_gather_wire_bytes(payload_bytes: float, axis: int) -> float:
    """payload_bytes = the *gathered* (full) size; each chip holds 1/axis."""
    return (axis - 1) / axis * payload_bytes


def all_to_all_wire_bytes(payload_bytes: float, axis: int) -> float:
    return (axis - 1) / axis * payload_bytes


def cohort_vs_flat_dcn_bytes(
    grad_bytes: float, pods: int, chips_per_pod: int
) -> dict:
    """Napkin math for the paper's headline effect, TPU-adapted.

    Flat all-reduce over ``pods × chips_per_pod`` chips treats DCN and ICI
    uniformly: every chip's full gradient participates in a ring spanning the
    DCN, so the slow fabric carries ``2 (n-1)/n × grad_bytes`` per chip.

    The cohort schedule (this framework): intra-pod reduce-scatter elects each
    chip "leader" of a ``1/chips_per_pod`` fragment; only fragments cross the
    DCN (all-reduce over the pod axis); an intra-pod all-gather redistributes.
    DCN traffic per chip drops by ``chips_per_pod``× — the analogue of the
    paper's local processes never touching the RNIC.
    """
    n = pods * chips_per_pod
    flat_dcn = allreduce_wire_bytes(grad_bytes, n)  # worst-case: ring over DCN
    cohort_dcn = allreduce_wire_bytes(grad_bytes / chips_per_pod, pods)
    return {
        "flat_dcn_bytes_per_chip": flat_dcn,
        "cohort_dcn_bytes_per_chip": cohort_dcn,
        "reduction": flat_dcn / cohort_dcn,
    }
