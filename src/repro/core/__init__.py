"""Core library: the paper's asymmetric mutual exclusion, faithfully, plus its
TPU-fabric adaptation (cohort-scheduled collectives and budgeted sync).

Control plane (simulated RDMA, host-level):
    AsymmetricMemory, Process, OpCounts — operation-asymmetric registers
    ALock                              — the paper's primitive (Alg. 1 + 2)
    NaiveRCASLock / RPCLock / FilterLock — the paper's comparison points
    modelcheck.check                    — explicit-state check of the PlusCal spec

Data plane (JAX, multi-pod):
    cohort_all_reduce / flat_all_reduce — hierarchical vs flat schedules
    SyncConfig, pod_sync_grads, pod_average_params, wrap_step_with_pod_sync
    TPUv5e and the asymmetry cost model
"""

from .memory import (  # noqa: F401
    NULLPTR,
    TIMEOUT,
    AsymmetricMemory,
    DeadlineExceeded,
    OpCounts,
    OperationNotEnabled,
    Overloaded,
    Process,
    Register,
    RemoteTimeout,
    make_scheduler,
)
from .mcs import BudgetedMCSLock, InflatedKeyQueue  # noqa: F401
from .peterson import ModifiedPetersonLock  # noqa: F401
from .alock import (  # noqa: F401
    ALock,
    BrokenMixedCASLock,
    FilterLock,
    NaiveRCASLock,
    RPCLock,
)
from .asymmetry import (  # noqa: F401
    TPUv5e,
    all_gather_wire_bytes,
    all_to_all_wire_bytes,
    allreduce_wire_bytes,
    cohort_vs_flat_dcn_bytes,
    reduce_scatter_wire_bytes,
)
from .cohort import (  # noqa: F401
    SyncConfig,
    cohort_all_reduce,
    flat_all_reduce,
    pod_average_params,
    pod_sync_grads,
    wrap_step_with_pod_sync,
)
from . import modelcheck  # noqa: F401
