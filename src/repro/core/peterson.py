"""Modified Peterson's lock (paper Algorithm 1).

A two-party starvation-free mutual-exclusion protocol between the *local*
class (cid 0) and the *remote* class (cid 1), built only from read/write
registers — the greatest common denominator under operation asymmetry, since
local and remote RMW are not mutually atomic (Table 1).

Differences from textbook Peterson:

* the "interested" flags ARE the embedded cohort locks' tail registers
  (``cohort[id].qIsLocked()`` replaces ``flag[other]``) — acquiring the cohort
  lock *is* the announcement of interest;
* ``p_reacquire`` (Algorithm 1 line 12) releases-and-reacquires by setting
  ``victim := self`` and re-waiting, used by the budget mechanism to bound
  consecutive same-class hand-offs (fairness).
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from .memory import NULLPTR, AsymmetricMemory, Process, Register
from .mcs import BudgetedMCSLock


class ModifiedPetersonLock:
    """Paper Algorithm 1, parameterised over the two cohort locks."""

    def __init__(
        self,
        mem: AsymmetricMemory,
        victim: Register,
        cohorts: Sequence[BudgetedMCSLock],
    ):
        assert len(cohorts) == 2
        self.mem = mem
        self.victim = victim
        self.cohorts = cohorts

    def acquire(self, p: Process, cid: int,
                piggyback_reads: Optional[Sequence[Register]] = None,
                ) -> Optional[List]:
        """Algorithm 1 lines 6-7 (the ``isLeader`` branch of ``pLock``).

        ``piggyback_reads`` (remote callers only; registers on the victim's
        node) are chained into the same doorbell as the Peterson engagement:
        ``[write victim, read other-tail, read r0, read r1, ...]``.  WR lists
        execute in order, so if the other cohort's tail reads ``NULLPTR`` the
        caller enters the critical section *immediately* — and the
        piggybacked values are then valid CS reads: an MCS holder keeps its
        cohort tail non-null for its whole critical section (including
        intra-cohort hand-offs), so a null tail proves no opposite-class
        holder was in (or could linearize into) the CS before our victim
        write, which any later-arriving leader must lose to.  Returns the
        read values on that uncontended fast entry, else ``None`` — the
        caller must re-read inside the critical section (the values may have
        been read while an opposite-class holder was still active).
        """
        other = 1 - cid
        tail = self.cohorts[other].tail
        extra = [("read", r) for r in piggyback_reads or ()]
        if not p.is_local_to(self.victim):
            # Remote leader: engage with ONE posting — victim write, the
            # other cohort's interested flag, and any piggybacked reads.
            out = self.mem.post_batch(p, [
                ("write", self.victim, cid), ("read", tail), *extra,
            ])
            if out[1] is NULLPTR:
                return out[2:] if piggyback_reads else None  # fast entry
            # Contended: wait, re-reading flag+victim (and the piggyback) in
            # one posting per spin.  Whichever exit fires, the *same*
            # posting's piggybacked reads are valid CS reads: a null tail
            # proves the opposite cohort fully drained (a holder keeps its
            # tail non-null for its whole CS, writes flushed before the
            # drain), and ``victim != cid`` proves a fresh opposite-class
            # leader wrote victim after us — a leader only engages on an
            # *empty* cohort (no holder inside) and now parks until we
            # release.  Same-class holders are excluded by our own cohort
            # MCS throughout.
            while True:
                out = self.mem.post_batch(p, [
                    ("read", tail), ("read", self.victim), *extra,
                ])
                if out[0] is NULLPTR or out[1] != cid:
                    return out[2:] if piggyback_reads else None
                self.mem.yield_point()
        self.mem.auto_write(p, self.victim, cid)
        self.mem.fence(p)
        while (
            self.cohorts[other].q_is_locked(p)
            and self.mem.auto_read(p, self.victim) == cid
        ):
            self.mem.yield_point()
        return None

    def reacquire(self, p: Process, cid: int) -> None:
        """``pReacquire`` (Algorithm 1 lines 12-16): yield then re-wait.

        Setting ``victim := cid`` lets a waiting opposite-class leader through;
        if none is waiting the caller re-enters immediately.  Identical wait
        condition to :meth:`acquire` — the paper folds both into one routine in
        the PlusCal spec (``AcquireGlobal``).
        """
        self.acquire(p, cid)
