"""Modified Peterson's lock (paper Algorithm 1).

A two-party starvation-free mutual-exclusion protocol between the *local*
class (cid 0) and the *remote* class (cid 1), built only from read/write
registers — the greatest common denominator under operation asymmetry, since
local and remote RMW are not mutually atomic (Table 1).

Differences from textbook Peterson:

* the "interested" flags ARE the embedded cohort locks' tail registers
  (``cohort[id].qIsLocked()`` replaces ``flag[other]``) — acquiring the cohort
  lock *is* the announcement of interest;
* ``p_reacquire`` (Algorithm 1 line 12) releases-and-reacquires by setting
  ``victim := self`` and re-waiting, used by the budget mechanism to bound
  consecutive same-class hand-offs (fairness).
"""

from __future__ import annotations

import time
from typing import Sequence

from .memory import AsymmetricMemory, Process, Register
from .mcs import BudgetedMCSLock


class ModifiedPetersonLock:
    """Paper Algorithm 1, parameterised over the two cohort locks."""

    def __init__(
        self,
        mem: AsymmetricMemory,
        victim: Register,
        cohorts: Sequence[BudgetedMCSLock],
    ):
        assert len(cohorts) == 2
        self.mem = mem
        self.victim = victim
        self.cohorts = cohorts

    def acquire(self, p: Process, cid: int) -> None:
        """Algorithm 1 lines 6-7 (the ``isLeader`` branch of ``pLock``)."""
        other = 1 - cid
        self.mem.auto_write(p, self.victim, cid)
        self.mem.fence(p)
        while (
            self.cohorts[other].q_is_locked(p)
            and self.mem.auto_read(p, self.victim) == cid
        ):
            time.sleep(0)

    def reacquire(self, p: Process, cid: int) -> None:
        """``pReacquire`` (Algorithm 1 lines 12-16): yield then re-wait.

        Setting ``victim := cid`` lets a waiting opposite-class leader through;
        if none is waiting the caller re-enters immediately.  Identical wait
        condition to :meth:`acquire` — the paper folds both into one routine in
        the PlusCal spec (``AcquireGlobal``).
        """
        self.acquire(p, cid)
