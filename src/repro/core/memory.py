"""Simulated RDMA shared-memory with *operation asymmetry* (paper §2, Table 1).

The paper models an RDMA system as nodes ``N``, processes ``P`` and a shared
memory ``M`` partitioned among nodes into atomic 8-byte registers.  A process
is *local* to a register iff it resides on the register's node.  Each class of
access supports ``{read, write, cas}``; atomicity *between* the classes follows
Table 1 of the paper:

==============  ======  ======  =====
local \\ remote  rRead   rWrite  rRMW
==============  ======  ======  =====
Read            atomic  atomic  atomic
Write           atomic  atomic  NOT
RMW             atomic  atomic  NOT
==============  ======  ======  =====

i.e. a remote RMW (``rCAS``) executed by the RNIC appears to the *local*
memory subsystem as an unordered read-then-write, so it can lose updates
against a concurrent local ``CAS``/``Write``.

This module reproduces those semantics exactly so the lock algorithms built on
top are exercised under the same hazards they were designed for:

* local RMW holds the register's *machine* lock for the whole read-modify-write
  (cache-coherence atomicity);
* remote RMW is serialised against other remote RMWs by a per-node *RNIC*
  lock, but its read and write phases take the machine lock separately with a
  preemption point in between — the Table-1 hazard;
* plain reads/writes (either class) are single-register atomic (8B in a cache
  line).

The memory also *accounts* every operation per process and class, which is how
the benchmarks verify the paper's cost claims (local processes: 0 RDMA ops;
lone remote acquire: 1 rCAS; queued remote acquire: +1 rWrite; unlock: at most
rCAS + rWrite).
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

NULLPTR = None  # the paper's ``nullptr`` sentinel for pointer-valued registers


class OperationNotEnabled(RuntimeError):
    """Raised when a process uses an operation not enabled for it (paper §2)."""


@dataclass
class OpCounts:
    """Per-process operation accounting (the unit of the paper's cost claims)."""

    local_read: int = 0
    local_write: int = 0
    local_cas: int = 0
    remote_read: int = 0
    remote_write: int = 0
    remote_cas: int = 0

    @property
    def rdma_ops(self) -> int:
        return self.remote_read + self.remote_write + self.remote_cas

    @property
    def local_ops(self) -> int:
        return self.local_read + self.local_write + self.local_cas

    def snapshot(self) -> "OpCounts":
        return OpCounts(**vars(self))

    def delta(self, since: "OpCounts") -> "OpCounts":
        return OpCounts(**{k: getattr(self, k) - getattr(since, k) for k in vars(self)})

    def __add__(self, other: "OpCounts") -> "OpCounts":
        return OpCounts(**{k: getattr(self, k) + getattr(other, k) for k in vars(self)})


class Register:
    """An atomic 8-byte register residing in one node's memory partition."""

    __slots__ = ("name", "node", "_value", "_lock")

    def __init__(self, name: str, node: int, value: Any):
        self.name = name
        self.node = node
        self._value = value
        # The "machine" lock: models cache-coherence atomicity on the owning
        # node.  Local RMW holds it across the full read-modify-write.
        self._lock = threading.Lock()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Register({self.name}@n{self.node}={self._value!r})"


@dataclass
class Process:
    """A process ``p_i^j`` — node id, process id and its operation counters."""

    pid: int
    node: int
    counts: OpCounts = field(default_factory=OpCounts)

    def is_local_to(self, reg: Register) -> bool:
        return self.node == reg.node


class AsymmetricMemory:
    """RDMA-accessible shared memory ``M`` partitioned among nodes.

    ``sched`` is an optional preemption hook invoked at every operation
    boundary (and *inside* the non-atomic window of ``rcas``); the stress tests
    install a randomised yield to explore interleavings.
    """

    def __init__(self, num_nodes: int, sched: Optional[Callable[[], None]] = None):
        self.num_nodes = num_nodes
        self._registers: Dict[str, Register] = {}
        self._rnic_locks = [threading.Lock() for _ in range(num_nodes)]
        self._sched = sched or (lambda: None)
        self._pid_counter = itertools.count()
        self._reg_guard = threading.Lock()

    # ------------------------------------------------------------------ setup
    def spawn(self, node: int) -> Process:
        if not (0 <= node < self.num_nodes):
            raise ValueError(f"node {node} out of range")
        return Process(pid=next(self._pid_counter), node=node)

    def alloc(self, node: int, name: str, value: Any = NULLPTR) -> Register:
        if not (0 <= node < self.num_nodes):
            raise ValueError(f"node {node} out of range")
        with self._reg_guard:
            if name in self._registers:
                raise ValueError(f"register {name!r} already allocated")
            reg = Register(name, node, value)
            self._registers[name] = reg
            return reg

    # -------------------------------------------------------------- local ops
    def read(self, p: Process, reg: Register) -> Any:
        self._require_local(p, reg, "Read")
        self._sched()
        with reg._lock:
            v = reg._value
        p.counts.local_read += 1
        return v

    def write(self, p: Process, reg: Register, value: Any) -> None:
        self._require_local(p, reg, "Write")
        self._sched()
        with reg._lock:
            reg._value = value
        p.counts.local_write += 1

    def cas(self, p: Process, reg: Register, expected: Any, swap: Any) -> Any:
        """Local CAS: atomic read-modify-write under the machine lock."""
        self._require_local(p, reg, "CAS")
        self._sched()
        with reg._lock:
            observed = reg._value
            if observed == expected:
                reg._value = swap
        p.counts.local_cas += 1
        return observed

    # ------------------------------------------------------------- remote ops
    def rread(self, p: Process, reg: Register) -> Any:
        self._sched()
        with reg._lock:  # 8B remote read is atomic w.r.t. local ops (Table 1)
            v = reg._value
        p.counts.remote_read += 1
        return v

    def rwrite(self, p: Process, reg: Register, value: Any) -> None:
        self._sched()
        with reg._lock:  # 8B remote write is atomic w.r.t. local read/write
            reg._value = value
        p.counts.remote_write += 1

    def rcas(self, p: Process, reg: Register, expected: Any, swap: Any) -> Any:
        """Remote CAS, executed by the target node's RNIC.

        Serialised against *other remote RMWs* by the RNIC lock, but its read
        and write phases acquire the machine lock separately with a
        preemption point in between — i.e. **not** atomic w.r.t. local
        ``CAS``/``Write`` (the Table-1 hazard: to a local process an ``rCAS``
        appears as a Read then a Write).
        """
        self._sched()
        with self._rnic_locks[reg.node]:
            with reg._lock:
                observed = reg._value
            # RNIC compare happens outside the machine's coherence domain: a
            # local CAS/Write can slip in right here.  The tagged hook lets
            # tests interleave this window deterministically.
            try:
                self._sched("rcas_window")
            except TypeError:
                self._sched()
            if observed == expected:
                with reg._lock:
                    reg._value = swap
        p.counts.remote_cas += 1
        return observed

    # ------------------------------------------------------ dispatch helpers
    def auto_read(self, p: Process, reg: Register) -> Any:
        """Read with the cheapest *enabled* operation (paper §2 locality)."""
        return self.read(p, reg) if p.is_local_to(reg) else self.rread(p, reg)

    def auto_write(self, p: Process, reg: Register, value: Any) -> None:
        if p.is_local_to(reg):
            self.write(p, reg, value)
        else:
            self.rwrite(p, reg, value)

    def auto_cas(self, p: Process, reg: Register, expected: Any, swap: Any) -> Any:
        if p.is_local_to(reg):
            return self.cas(p, reg, expected, swap)
        return self.rcas(p, reg, expected, swap)

    def fence(self, p: Process) -> None:
        """RDMA + local memory fence.

        The per-op locking above already yields sequentially-consistent
        register operations (every op is an acquire/release pair on the
        machine lock), matching the paper's assumption that programmers insert
        the required fences; this is the explicit no-op hook for symmetry.
        """
        self._sched()

    # --------------------------------------------------------------- internal
    def _require_local(self, p: Process, reg: Register, op: str) -> None:
        if not p.is_local_to(reg):
            raise OperationNotEnabled(
                f"process p{p.pid}@n{p.node} attempted local {op} on remote "
                f"register {reg.name!r}@n{reg.node}; remote processes are "
                "constrained to remote accesses (operation asymmetry, paper §2)"
            )


def make_scheduler(rng, p_yield: float = 0.3) -> Callable[[], None]:
    """A randomised preemption hook for stress tests.

    With probability ``p_yield`` the calling thread sleeps 0 seconds, which
    releases the GIL and lets the OS scheduler pick another runnable thread —
    cheap, wall-clock-free interleaving diversity.
    """
    import time

    def sched() -> None:
        if rng.random() < p_yield:
            time.sleep(0)

    return sched
