"""Simulated RDMA shared-memory with *operation asymmetry* (paper §2, Table 1).

The paper models an RDMA system as nodes ``N``, processes ``P`` and a shared
memory ``M`` partitioned among nodes into atomic 8-byte registers.  A process
is *local* to a register iff it resides on the register's node.  Each class of
access supports ``{read, write, cas}``; atomicity *between* the classes follows
Table 1 of the paper:

==============  ======  ======  =====
local \\ remote  rRead   rWrite  rRMW
==============  ======  ======  =====
Read            atomic  atomic  atomic
Write           atomic  atomic  NOT
RMW             atomic  atomic  NOT
==============  ======  ======  =====

i.e. a remote RMW (``rCAS``) executed by the RNIC appears to the *local*
memory subsystem as an unordered read-then-write, so it can lose updates
against a concurrent local ``CAS``/``Write``.

This module reproduces those semantics exactly so the lock algorithms built on
top are exercised under the same hazards they were designed for:

* local RMW holds the register's *machine* lock for the whole read-modify-write
  (cache-coherence atomicity);
* remote RMW is serialised against other remote RMWs by a per-node *RNIC*
  lock, but its read and write phases take the machine lock separately with a
  preemption point in between — the Table-1 hazard;
* plain reads/writes (either class) are single-register atomic (8B in a cache
  line).

The memory also *accounts* every operation per process and class, which is how
the benchmarks verify the paper's cost claims (local processes: 0 RDMA ops;
lone remote acquire: 1 rCAS; queued remote acquire: +1 rWrite; unlock: at most
rCAS + rWrite).
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

NULLPTR = None  # the paper's ``nullptr`` sentinel for pointer-valued registers


class OperationNotEnabled(RuntimeError):
    """Raised when a process uses an operation not enabled for it (paper §2)."""


class RemoteTimeout(RuntimeError):
    """A remote posting exceeded its op-level timeout budget.

    Raised by fabrics that model message loss (``repro.sim.fabric``) once the
    bounded retransmit schedule is exhausted — the RDMA analogue of a QP
    transitioning to error after ``retry_cnt`` retries.  The plain in-memory
    fabric never raises it.
    """


class DeadlineExceeded(TimeoutError):
    """An operation's caller-supplied deadline expired before completion.

    Deadlines are absolute instants on the stack's injected clock: every
    public lock-table operation accepts one, threads it through its retry
    loops, and clamps each backoff sleep to the remaining budget — so an op
    fails *fast* at its deadline instead of sleeping past the point where
    the answer is useless.  Subclasses :class:`TimeoutError` so callers that
    treat all patience exhaustion alike (e.g. the batch suffix-rollback
    path) need no new handler.
    """


class Overloaded(RuntimeError):
    """A fast **local** refusal from the overload-protection layer.

    Raised before any remote posting when proceeding would be wasted work:
    the destination host's circuit breaker is open, its retry budget is
    exhausted, or the shard's observed service time makes the caller's
    deadline infeasible (a shed).  Costs zero RDMA operations — the whole
    point is that refusing locally removes retry traffic from a fabric that
    is already drowning.  ``reason`` is one of ``"breaker"``, ``"budget"``,
    ``"shed"``.
    """

    def __init__(self, msg: str, reason: str = "shed", host: int = -1):
        super().__init__(msg)
        self.reason = reason
        self.host = host


class _TimeoutSentinel:
    """Falsy singleton returned by :meth:`AsymmetricMemory.probe` on loss."""

    __slots__ = ()

    def __repr__(self) -> str:
        return "TIMEOUT"

    def __bool__(self) -> bool:
        return False


TIMEOUT = _TimeoutSentinel()


@dataclass
class OpCounts:
    """Per-process operation accounting (the unit of the paper's cost claims).

    ``remote_*`` count RDMA *completions* (one per work request, the unit of
    the paper's cost claims); ``remote_doorbell`` counts *postings* — a
    :meth:`AsymmetricMemory.post_batch` of N work requests rings the doorbell
    once and completes N times, which is how doorbell coalescing shows up in
    the telemetry (completions unchanged, postings collapsed).
    """

    local_read: int = 0
    local_write: int = 0
    local_cas: int = 0
    remote_read: int = 0
    remote_write: int = 0
    remote_cas: int = 0
    remote_doorbell: int = 0
    # Faulty-fabric accounting: a ``timeout`` is one lost posting discovered
    # at its op-level deadline; a ``retry`` is one backoff-scheduled repost.
    # Both are zero on a loss-free fabric (the failure-free path costs
    # nothing, per Dhoked & Mittal's adaptive-recovery bar).
    timeouts: int = 0
    retries: int = 0

    @property
    def rdma_ops(self) -> int:
        return self.remote_read + self.remote_write + self.remote_cas

    @property
    def local_ops(self) -> int:
        return self.local_read + self.local_write + self.local_cas

    def as_tuple(self) -> tuple:
        """O(1) allocation-light snapshot for per-op accounting hot paths."""
        return (
            self.local_read, self.local_write, self.local_cas,
            self.remote_read, self.remote_write, self.remote_cas,
            self.remote_doorbell, self.timeouts, self.retries,
        )

    def add_since(self, current: "OpCounts", since: tuple) -> None:
        """Accumulate ``current - since`` into self, in place (no allocs).

        ``since`` is an :meth:`as_tuple` snapshot taken before the operation;
        this is the O(1) telemetry-accounting path (the old per-op
        ``snapshot()``/``delta()`` pair built two dicts and two dataclass
        instances per table operation).
        """
        self.local_read += current.local_read - since[0]
        self.local_write += current.local_write - since[1]
        self.local_cas += current.local_cas - since[2]
        self.remote_read += current.remote_read - since[3]
        self.remote_write += current.remote_write - since[4]
        self.remote_cas += current.remote_cas - since[5]
        self.remote_doorbell += current.remote_doorbell - since[6]
        self.timeouts += current.timeouts - since[7]
        self.retries += current.retries - since[8]

    def snapshot(self) -> "OpCounts":
        return OpCounts(**vars(self))

    def delta(self, since: "OpCounts") -> "OpCounts":
        return OpCounts(**{k: getattr(self, k) - getattr(since, k) for k in vars(self)})

    def __add__(self, other: "OpCounts") -> "OpCounts":
        return OpCounts(**{k: getattr(self, k) + getattr(other, k) for k in vars(self)})


class Register:
    """An atomic 8-byte register residing in one node's memory partition."""

    __slots__ = ("name", "node", "_value", "_lock")

    def __init__(self, name: str, node: int, value: Any):
        self.name = name
        self.node = node
        self._value = value
        # The "machine" lock: models cache-coherence atomicity on the owning
        # node.  Local RMW holds it across the full read-modify-write.
        self._lock = threading.Lock()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Register({self.name}@n{self.node}={self._value!r})"


@dataclass
class Process:
    """A process ``p_i^j`` — node id, process id and its operation counters."""

    pid: int
    node: int
    counts: OpCounts = field(default_factory=OpCounts)

    def is_local_to(self, reg: Register) -> bool:
        return self.node == reg.node


def _thread_yield() -> None:
    """Default ``yield_point``: release the GIL so another thread can run."""
    time.sleep(0)


class AsymmetricMemory:
    """RDMA-accessible shared memory ``M`` partitioned among nodes.

    ``sched`` is an optional preemption hook invoked at every operation
    boundary (and *inside* the non-atomic window of ``rcas``); the stress tests
    install a randomised yield to explore interleavings.

    ``clock``/``yield_point`` are the virtual-time hooks: every piece of the
    stack that waits (lock spin loops, the Peterson wait, the baselines)
    routes its wait step through ``yield_point`` instead of calling
    ``time.sleep(0)`` directly, and time-based logic reads ``clock``.  The
    defaults preserve threaded behavior exactly (a GIL-releasing yield and
    ``time.monotonic``); the discrete-event engine (``repro.sim``) installs a
    virtual clock and a spin hook that charges simulated time, which is how
    the same lock code runs unmodified under simulation.
    """

    def __init__(
        self,
        num_nodes: int,
        sched: Optional[Callable[[], None]] = None,
        clock: Optional[Callable[[], float]] = None,
        yield_point: Optional[Callable[[], None]] = None,
    ):
        self.num_nodes = num_nodes
        self._registers: Dict[str, Register] = {}
        self._rnic_locks = [threading.Lock() for _ in range(num_nodes)]
        self._sched = sched or (lambda: None)
        self.clock = clock or time.monotonic
        self.yield_point = yield_point or _thread_yield
        self._pid_counter = itertools.count()
        self._reg_guard = threading.Lock()

    # ------------------------------------------------------------------ setup
    def spawn(self, node: int) -> Process:
        if not (0 <= node < self.num_nodes):
            raise ValueError(f"node {node} out of range")
        return Process(pid=next(self._pid_counter), node=node)

    def alloc(self, node: int, name: str, value: Any = NULLPTR) -> Register:
        if not (0 <= node < self.num_nodes):
            raise ValueError(f"node {node} out of range")
        with self._reg_guard:
            if name in self._registers:
                raise ValueError(f"register {name!r} already allocated")
            reg = Register(name, node, value)
            self._registers[name] = reg
            return reg

    # -------------------------------------------------------------- local ops
    def read(self, p: Process, reg: Register) -> Any:
        self._require_local(p, reg, "Read")
        self._sched()
        with reg._lock:
            v = reg._value
        p.counts.local_read += 1
        return v

    def write(self, p: Process, reg: Register, value: Any) -> None:
        self._require_local(p, reg, "Write")
        self._sched()
        with reg._lock:
            reg._value = value
        p.counts.local_write += 1

    def cas(self, p: Process, reg: Register, expected: Any, swap: Any) -> Any:
        """Local CAS: atomic read-modify-write under the machine lock."""
        self._require_local(p, reg, "CAS")
        self._sched()
        with reg._lock:
            observed = reg._value
            if observed == expected:
                reg._value = swap
        p.counts.local_cas += 1
        return observed

    # ------------------------------------------------------------- remote ops
    # Each individually-posted remote op rings its own doorbell (one WR, one
    # posting); ``post_batch`` is the coalesced path (one doorbell, N WRs).
    def rread(self, p: Process, reg: Register) -> Any:
        self._sched()
        with reg._lock:  # 8B remote read is atomic w.r.t. local ops (Table 1)
            v = reg._value
        p.counts.remote_read += 1
        p.counts.remote_doorbell += 1
        return v

    def rwrite(self, p: Process, reg: Register, value: Any) -> None:
        self._sched()
        with reg._lock:  # 8B remote write is atomic w.r.t. local read/write
            reg._value = value
        p.counts.remote_write += 1
        p.counts.remote_doorbell += 1

    def _rcas_execute(self, reg: Register, expected: Any, swap: Any) -> Any:
        """The RNIC's compare-and-swap, shared by ``rcas`` and ``post_batch``.

        Serialised against *other remote RMWs* by the RNIC lock, but its read
        and write phases acquire the machine lock separately with a
        preemption point in between — i.e. **not** atomic w.r.t. local
        ``CAS``/``Write`` (the Table-1 hazard: to a local process an ``rCAS``
        appears as a Read then a Write).
        """
        with self._rnic_locks[reg.node]:
            with reg._lock:
                observed = reg._value
            # RNIC compare happens outside the machine's coherence domain: a
            # local CAS/Write can slip in right here.  The tagged hook lets
            # tests interleave this window deterministically.
            try:
                self._sched("rcas_window")
            except TypeError:
                self._sched()
            if observed == expected:
                with reg._lock:
                    reg._value = swap
        return observed

    def rcas(self, p: Process, reg: Register, expected: Any, swap: Any) -> Any:
        """Remote CAS, executed by the target node's RNIC (see _rcas_execute)."""
        self._sched()
        observed = self._rcas_execute(reg, expected, swap)
        p.counts.remote_cas += 1
        p.counts.remote_doorbell += 1
        return observed

    # ------------------------------------------------------ doorbell batching
    def post_batch(self, p: Process, wrs) -> list:
        """Post a list of remote work requests with **one doorbell** (WR list).

        Models RDMA doorbell batching: a verbs client chains several work
        requests and rings the QP doorbell once, so N operations cost one
        posting (one MMIO/doorbell, one NIC fetch) and N completions.  The
        accounting mirrors that: ``remote_doorbell`` is incremented once,
        the per-op completion counters (``remote_read``/``remote_write``/
        ``remote_cas``) by N — the paper's per-op cost claims are stated over
        completions and are unchanged by coalescing.

        ``wrs`` is a sequence of tuples::

            ("read",  reg)                   -> result: the value read
            ("write", reg, value)            -> result: None
            ("cas",   reg, expected, swap)   -> result: the observed value

        Constraints, matching the hardware: every register must live on the
        same node (a WR list targets one queue pair), and the poster must be
        *remote* to that node — local processes touch their own memory
        directly and have no doorbell to ring (use plain ``read``/``write``/
        ``cas``).

        Atomicity is per work request, identical to posting each op alone:
        reads/writes are single-register atomic, and each CAS keeps the
        Table-1 non-atomic window w.r.t. local ``CAS``/``Write``.  The WR
        list as a whole is **not** atomic — other processes can interleave
        between its entries.
        """
        wrs = list(wrs)
        if not wrs:
            return []
        # Validate the whole list before touching any register: a malformed
        # WR must not leave earlier entries applied-but-unaccounted.  Arity
        # is checked before any element access so a short tuple surfaces as
        # the documented ValueError, not an IndexError.
        _ARITY = {"read": 2, "write": 3, "cas": 4}
        for wr in wrs:
            if not wr or _ARITY.get(wr[0]) != len(wr):
                raise ValueError(f"malformed work request {wr!r}")
        node = wrs[0][1].node
        for wr in wrs:
            if wr[1].node != node:
                raise ValueError(
                    f"post_batch spans nodes {node} and {wr[1].node}: a work-"
                    "request list targets one queue pair (one node)"
                )
        if p.node == node:
            raise OperationNotEnabled(
                f"process p{p.pid}@n{p.node} posted a doorbell batch to "
                "its own node; local processes access memory directly"
            )
        results = []
        nread = nwrite = ncas = 0
        self._sched()  # the single doorbell ring
        for i, wr in enumerate(wrs):
            op, reg = wr[0], wr[1]
            if i:  # entries execute in order but are NOT mutually atomic:
                self._sched()  # let stress schedulers interleave between WRs
            if op == "read":
                with reg._lock:
                    results.append(reg._value)
                nread += 1
            elif op == "write":
                with reg._lock:
                    reg._value = wr[2]
                results.append(None)
                nwrite += 1
            elif op == "cas":
                results.append(self._rcas_execute(reg, wr[2], wr[3]))
                ncas += 1
        p.counts.remote_read += nread
        p.counts.remote_write += nwrite
        p.counts.remote_cas += ncas
        p.counts.remote_doorbell += 1
        return results

    # ------------------------------------------------------ dispatch helpers
    def auto_read(self, p: Process, reg: Register) -> Any:
        """Read with the cheapest *enabled* operation (paper §2 locality)."""
        return self.read(p, reg) if p.is_local_to(reg) else self.rread(p, reg)

    def auto_write(self, p: Process, reg: Register, value: Any) -> None:
        if p.is_local_to(reg):
            self.write(p, reg, value)
        else:
            self.rwrite(p, reg, value)

    def auto_cas(self, p: Process, reg: Register, expected: Any, swap: Any) -> Any:
        if p.is_local_to(reg):
            return self.cas(p, reg, expected, swap)
        return self.rcas(p, reg, expected, swap)

    def probe(self, p: Process, reg: Register) -> Any:
        """Bounded-liveness read: the value, or :data:`TIMEOUT` on loss.

        Failure detectors must not block on the very host they are probing,
        so this read gives up instead of retrying.  On the plain in-memory
        fabric delivery is reliable and ``probe`` is exactly ``auto_read``;
        lossy fabrics (``repro.sim.fabric``) override it to return
        :data:`TIMEOUT` after one op-level timeout when the target is
        unreachable (dead host, link flap, partition cut).
        """
        return self.auto_read(p, reg)

    def fence(self, p: Process) -> None:
        """RDMA + local memory fence.

        The per-op locking above already yields sequentially-consistent
        register operations (every op is an acquire/release pair on the
        machine lock), matching the paper's assumption that programmers insert
        the required fences; this is the explicit no-op hook for symmetry.
        """
        self._sched()

    # --------------------------------------------------------------- internal
    def _require_local(self, p: Process, reg: Register, op: str) -> None:
        if not p.is_local_to(reg):
            raise OperationNotEnabled(
                f"process p{p.pid}@n{p.node} attempted local {op} on remote "
                f"register {reg.name!r}@n{reg.node}; remote processes are "
                "constrained to remote accesses (operation asymmetry, paper §2)"
            )


def make_scheduler(rng, p_yield: float = 0.3) -> Callable[[], None]:
    """A randomised preemption hook for stress tests.

    With probability ``p_yield`` the calling thread sleeps 0 seconds, which
    releases the GIL and lets the OS scheduler pick another runnable thread —
    cheap, wall-clock-free interleaving diversity.
    """

    def sched() -> None:
        if rng.random() < p_yield:
            time.sleep(0)

    return sched
