"""Explicit-state model checker for the paper's PlusCal spec (Appendix A).

TLA+/TLC is not available offline, so this module transcribes the appendix's
``qplock`` PlusCal algorithm into a transition system — one transition per
PlusCal *label* (the spec's atomicity grain) — and exhaustively explores the
reachable state space, checking:

* ``MutualExclusion``      — at most one process at ``cs`` in every state;
* deadlock-freedom         — every reachable state has an enabled transition;
* ``StarvationFree``       — ``(pc[i] = "enter") ~> (pc[i] = "cs")`` under
  weak fairness, checked by searching for a *fair* strongly-connected
  component in which process ``i`` remains inside the entry section forever
  while every continuously-enabled process keeps stepping.  No such SCC ⇒
  starvation-freedom holds (the SCC condition over-approximates the set of
  fair cycles, so an empty result is a proof).

The PlusCal mapping (pids 1..NP; ``Us(pid) = pid % 2 + 1``):

* ``AcquireGlobal`` is inlined twice (call sites ``c5`` and ``p2``) as the
  ``cg*`` / ``pg*`` label families;
* the ``cas`` label of ``ReleaseCohort`` branches to ``r1`` only when the
  tail CAS fails (the appendix's pretty-printer drops the ``else``; the
  C-style Algorithm 2 lines 15-18 fix the intended control flow);
* seeded-bug variants validate the checker itself:
  ``skip_global``   — leaders skip ``AcquireGlobal``  ⇒ mutual exclusion fails;
  ``no_decrement``  — hand-off keeps the budget       ⇒ starvation appears.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

# ----------------------------------------------------------------- labels ---
_LABELS = [
    "p1", "ncs", "enter",
    "c1", "swap", "cwait", "c2", "c3", "c4",
    "cg1", "cgw", "cg2", "cg3", "cg4",
    "c6", "c7", "c8", "c9", "c10",
    "p2",
    "pg1", "pgw", "pg2", "pg3", "pg4",
    "cs",
    "cas", "r1", "r2", "r3",
]
PC = {name: i for i, name in enumerate(_LABELS)}
# Entry section: from the spec's "enter" through the last pre-CS label.
_ENTRY = frozenset(range(PC["enter"], PC["cs"]))
_CS = PC["cs"]
NULL = 0


@dataclass(frozen=True)
class State:
    """One global state of the PlusCal spec (immutable, hashable)."""

    victim: int
    cohort: Tuple[int, int]          # cohort[1], cohort[2]
    pc: Tuple[int, ...]              # per process (index 0 = pid 1)
    budget: Tuple[int, ...]
    next: Tuple[int, ...]
    passed: Tuple[bool, ...]
    pred: Tuple[int, ...]


def _us(pid: int) -> int:
    return (pid % 2) + 1


def _them(pid: int) -> int:
    return ((pid + 1) % 2) + 1


class QPLockSpec:
    """The transition system for ``qplock`` with ``NP`` processes, budget ``B``."""

    def __init__(self, num_procs: int, init_budget: int, variant: str = "paper"):
        assert num_procs > 0 and init_budget > 0, "PlusCal ASSUME"
        assert variant in ("paper", "skip_global", "no_decrement")
        self.np = num_procs
        self.b = init_budget
        self.variant = variant

    # ------------------------------------------------------------- initial --
    def initial_states(self) -> List[State]:
        base = dict(
            cohort=(NULL, NULL),
            pc=tuple(PC["p1"] for _ in range(self.np)),
            budget=tuple(-1 for _ in range(self.np)),
            next=tuple(NULL for _ in range(self.np)),
            passed=tuple(False for _ in range(self.np)),
            pred=tuple(NULL for _ in range(self.np)),
        )
        # ``victim \in {1, 2}`` — both initial choices explored.
        return [State(victim=v, **base) for v in (1, 2)]

    # ---------------------------------------------------------- transitions --
    def step(self, s: State, i: int) -> Optional[State]:
        """Next state if process index ``i`` (pid ``i+1``) takes a step, or
        ``None`` when its transition is disabled (a false ``await``)."""
        pid = i + 1
        pc = s.pc[i]
        us, them = _us(pid), _them(pid)

        def upd(**kw) -> State:
            d = dict(
                victim=s.victim, cohort=s.cohort, pc=s.pc, budget=s.budget,
                next=s.next, passed=s.passed, pred=s.pred,
            )
            d.update(kw)
            return State(**d)

        def setpc(label: str, **kw) -> State:
            pcs = list(s.pc)
            pcs[i] = PC[label]
            return upd(pc=tuple(pcs), **kw)

        def set1(t: Tuple, idx: int, val) -> Tuple:
            l = list(t)
            l[idx] = val
            return tuple(l)

        coh = {1: s.cohort[0], 2: s.cohort[1]}

        name = _LABELS[pc]
        if name == "p1":
            return setpc("ncs")
        if name == "ncs":
            return setpc("enter")
        if name == "enter":
            return setpc("c1")
        if name == "c1":
            return setpc(
                "swap", budget=set1(s.budget, i, -1), next=set1(s.next, i, NULL)
            )
        if name == "swap":
            # pred := cohort[Us]; cohort[Us] := self   (atomic swap label)
            new_coh = set1(s.cohort, us - 1, pid)
            return setpc("cwait", pred=set1(s.pred, i, coh[us]), cohort=new_coh)
        if name == "cwait":
            return setpc("c2") if s.pred[i] != NULL else setpc("c8")
        if name == "c2":
            pred_idx = s.pred[i] - 1
            return setpc("c3", next=set1(s.next, pred_idx, pid))
        if name == "c3":
            if s.budget[i] < 0:
                return None  # await Budget(self) >= 0
            return setpc("c4")
        if name == "c4":
            return setpc("cg1") if s.budget[i] == 0 else setpc("c7")
        if name in ("cg1", "pg1"):
            if self.variant == "skip_global":
                return setpc("c6" if name == "cg1" else "cs")
            return setpc("cgw" if name == "cg1" else "pgw", victim=pid)
        if name in ("cgw", "pgw"):
            return setpc("cg2" if name == "cgw" else "pg2")
        if name in ("cg2", "pg2"):
            done = "cg4" if name == "cg2" else "pg4"
            nxt = "cg3" if name == "cg2" else "pg3"
            return setpc(done) if coh[them] == NULL else setpc(nxt)
        if name in ("cg3", "pg3"):
            done = "cg4" if name == "cg3" else "pg4"
            back = "cgw" if name == "cg3" else "pgw"
            return setpc(done) if s.victim != pid else setpc(back)
        if name == "cg4":
            return setpc("c6")
        if name == "c6":
            return setpc("c7", budget=set1(s.budget, i, self.b))
        if name == "c7":
            return setpc("c10", passed=set1(s.passed, i, True))
        if name == "c8":
            return setpc("c9", budget=set1(s.budget, i, self.b))
        if name == "c9":
            return setpc("c10", passed=set1(s.passed, i, False))
        if name == "c10":
            return setpc("p2")
        if name == "p2":
            if self.variant == "skip_global":
                return setpc("cs")
            return setpc("cs") if s.passed[i] else setpc("pg1")
        if name == "pg4":
            return setpc("cs")
        if name == "cs":
            return setpc("cas")
        if name == "cas":
            if coh[us] == pid:
                return setpc("r3", cohort=set1(s.cohort, us - 1, NULL))
            return setpc("r1")
        if name == "r1":
            if s.next[i] == NULL:
                return None  # await descriptor[self].next /= 0
            return setpc("r2")
        if name == "r2":
            succ_idx = s.next[i] - 1
            handoff = s.budget[i] if self.variant == "no_decrement" else s.budget[i] - 1
            return setpc("r3", budget=set1(s.budget, succ_idx, handoff))
        if name == "r3":
            return setpc("p1")
        raise AssertionError(f"unhandled label {name}")


# ------------------------------------------------------------------ checker --
@dataclass
class CheckResult:
    num_states: int
    mutual_exclusion: bool
    deadlock_free: bool
    starvation_free: bool
    violations: Dict[str, str] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return self.mutual_exclusion and self.deadlock_free and self.starvation_free


def check(
    num_procs: int = 2,
    init_budget: int = 1,
    variant: str = "paper",
    max_states: int = 2_000_000,
) -> CheckResult:
    """Exhaustively explore the spec and check all three properties."""
    spec = QPLockSpec(num_procs, init_budget, variant)
    index: Dict[State, int] = {}
    states: List[State] = []
    # edges[s] = list of (succ_index, proc_index); enabled[s] = bitmask.
    edges: List[List[Tuple[int, int]]] = []
    enabled: List[int] = []

    frontier = []
    for s0 in spec.initial_states():
        if s0 not in index:
            index[s0] = len(states)
            states.append(s0)
            frontier.append(index[s0])
            edges.append([])
            enabled.append(0)

    mutex_ok, deadlock_ok = True, True
    violations: Dict[str, str] = {}

    head = 0
    while head < len(frontier):
        si = frontier[head]
        head += 1
        s = states[si]

        in_cs = sum(1 for pc in s.pc if pc == _CS)
        if in_cs > 1 and mutex_ok:
            mutex_ok = False
            violations["mutual_exclusion"] = f"state with {in_cs} processes in cs: {s}"

        mask = 0
        succs: List[Tuple[int, int]] = []
        for i in range(spec.np):
            t = spec.step(s, i)
            if t is None:
                continue
            mask |= 1 << i
            ti = index.get(t)
            if ti is None:
                ti = len(states)
                index[t] = ti
                states.append(t)
                edges.append([])
                enabled.append(0)
                frontier.append(ti)
                if len(states) > max_states:
                    raise RuntimeError(f"state space exceeds {max_states}")
            succs.append((ti, i))
        edges[si] = succs
        enabled[si] = mask
        if mask == 0 and deadlock_ok:
            deadlock_ok = False
            violations["deadlock"] = f"no enabled transition in {s}"

    starvation_ok = True
    if mutex_ok and deadlock_ok:
        for i in range(spec.np):
            scc = _fair_entry_scc(spec, states, edges, enabled, i)
            if scc is not None:
                starvation_ok = False
                violations["starvation"] = (
                    f"process {i + 1} can remain in the entry section forever: "
                    f"fair SCC of {len(scc)} states, e.g. {states[next(iter(scc))]}"
                )
                break

    return CheckResult(
        num_states=len(states),
        mutual_exclusion=mutex_ok,
        deadlock_free=deadlock_ok,
        starvation_free=starvation_ok,
        violations=violations,
    )


def _fair_entry_scc(
    spec: QPLockSpec,
    states: Sequence[State],
    edges: Sequence[Sequence[Tuple[int, int]]],
    enabled: Sequence[int],
    i: int,
) -> Optional[FrozenSet[int]]:
    """Find a fair SCC where process ``i`` never leaves the entry section.

    Subgraph: states with ``pc[i]`` in the entry section, edges staying inside.
    An SCC ``C`` (nontrivial) is a *fair* starvation witness iff every process
    that is enabled in **all** states of ``C`` takes at least one step inside
    ``C`` (weak fairness cannot rule the loop out).
    """
    n = len(states)
    in_sub = [states[s].pc[i] in _ENTRY for s in range(n)]

    # Iterative Tarjan on the subgraph.
    index_of = [-1] * n
    low = [0] * n
    on_stack = [False] * n
    stack: List[int] = []
    counter = 0
    sccs: List[List[int]] = []

    for root in range(n):
        if not in_sub[root] or index_of[root] != -1:
            continue
        work = [(root, 0)]
        while work:
            v, ei = work[-1]
            if ei == 0:
                index_of[v] = low[v] = counter
                counter += 1
                stack.append(v)
                on_stack[v] = True
            advanced = False
            subedges = [t for (t, _p) in edges[v] if in_sub[t]]
            while ei < len(subedges):
                w = subedges[ei]
                ei += 1
                if index_of[w] == -1:
                    work[-1] = (v, ei)
                    work.append((w, 0))
                    advanced = True
                    break
                elif on_stack[w]:
                    low[v] = min(low[v], index_of[w])
            if advanced:
                continue
            work.pop()
            if low[v] == index_of[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    comp.append(w)
                    if w == v:
                        break
                if len(comp) > 1:
                    sccs.append(comp)
            if work:
                u, _ = work[-1]
                low[u] = min(low[u], low[v])

    for comp in sccs:
        comp_set = set(comp)
        # Which processes step inside C?  Which are enabled in all of C?
        steps = 0
        enabled_all = (1 << spec.np) - 1
        for s in comp:
            enabled_all &= enabled[s]
            for (t, p) in edges[s]:
                if t in comp_set:
                    steps |= 1 << p
        if enabled_all & ~steps == 0:  # every always-enabled process steps ⇒ fair
            return frozenset(comp_set)
    return None
