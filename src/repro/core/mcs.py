"""Budgeted MCS queue lock (paper Algorithm 2).

One instance per *class* (local / remote).  The queue tail register lives on
the lock's home node and **doubles as the Peterson "interested" flag** for its
class (the paper's ``cohort[2]`` array).  Each process owns a remotely
accessible descriptor ``{budget, next}`` residing in its *own* node's memory
partition, so after enqueueing a process spins **locally** — the paper's key
property that removes remote spinning and its network traffic.

Operation costs (verified by ``benchmarks/lock_ops.py``):

* lone remote acquire:   1 rCAS
* queued remote acquire: 1 rCAS + 1 rWrite (link), then local spinning only
* remote release:        ≤ 1 rCAS + 1 rWrite
* any local-class call:  0 RDMA operations (auto-dispatch resolves every
  access to the local class's registers as a machine-local op)

The ``budget`` (Dice et al.'s lock-cohorting bound) caps consecutive same-class
hand-offs: a process handed a budget of 0 must call ``p_reacquire`` on the
global (Peterson) lock before entering, yielding to the other class if it is
waiting — this is what makes the combined primitive fair.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from .memory import NULLPTR, AsymmetricMemory, Process, Register


class _Descriptor:
    """Remotely-accessible MCS descriptor: two registers on the owner's node."""

    __slots__ = ("budget", "next")

    def __init__(self, budget: Register, nxt: Register):
        self.budget = budget
        self.next = nxt


class BudgetedMCSLock:
    """Paper Algorithm 2 — budgeted MCS queue lock over asymmetric memory.

    ``p_reacquire`` is the hook into the enclosing modified Peterson's lock
    (Algorithm 1 line 12); it is injected by :class:`repro.core.alock.ALock`
    after construction to break the circular dependency, mirroring how the
    paper embeds the cohort lock *inside* the global lock.
    """

    def __init__(
        self,
        mem: AsymmetricMemory,
        tail: Register,
        init_budget: int,
        name: str,
    ):
        if init_budget <= 0:
            raise ValueError("InitialBudget must be > 0 (PlusCal ASSUME)")
        self.mem = mem
        self.tail = tail  # == cohort[cid]: non-null ⇔ class is "interested"
        self.init_budget = init_budget
        self.name = name
        self.p_reacquire: Optional[Callable[[Process], None]] = None
        self._descs: Dict[int, _Descriptor] = {}
        self._desc_guard = __import__("threading").Lock()

    # ------------------------------------------------------------ descriptors
    def _desc(self, p: Process) -> _Descriptor:
        """The calling process's own descriptor (allocated on its node)."""
        d = self._descs.get(p.pid)
        if d is None:
            with self._desc_guard:
                d = self._descs.get(p.pid)
                if d is None:
                    prefix = f"{self.name}.desc.p{p.pid}"
                    d = _Descriptor(
                        budget=self.mem.alloc(p.node, f"{prefix}.budget", -1),
                        nxt=self.mem.alloc(p.node, f"{prefix}.next", NULLPTR),
                    )
                    self._descs[p.pid] = d
        return d

    def _desc_of(self, handle: Any) -> _Descriptor:
        """Dereference a descriptor handle found in shared memory."""
        return self._descs[handle]

    # -------------------------------------------------------------------- API
    def q_lock(self, p: Process) -> bool:
        """Acquire the cohort lock.

        Returns ``True`` iff the queue was empty at the outset — the caller is
        the class *leader* and must engage the global Peterson protocol
        (Algorithm 1 line 5).  ``False`` means the global lock was passed to
        us by a cohort member (possibly after a budget-forced reacquire).
        """
        mem = self.mem
        d = self._desc(p)
        # PlusCal c1: descriptor := [budget |-> -1, next |-> 0].  Setting
        # budget=-1 *before* publishing the descriptor avoids a lost hand-off
        # (Algorithm 2 writes -1 after the CAS but before linking; equivalent
        # because the predecessor cannot find us until the link rWrite).
        mem.auto_write(p, d.budget, -1)
        mem.auto_write(p, d.next, NULLPTR)

        # Swap ourselves into the tail (RDMA offers CAS, not swap ⇒ CAS loop;
        # Algorithm 2 lines 3-7, "curr updated on rCAS").
        curr: Any = NULLPTR
        while True:
            observed = mem.auto_cas(p, self.tail, expected=curr, swap=p.pid)
            if observed == curr:
                break
            curr = observed

        if curr is NULLPTR:
            # Queue was empty: we are the leader (PlusCal c8).
            mem.auto_write(p, d.budget, self.init_budget)
            return True

        # Link behind the predecessor, then spin on OUR OWN descriptor — a
        # machine-local read; no remote spinning (Algorithm 2 lines 8-10).
        # The wait step goes through the memory's yield_point so the same
        # code runs threaded (GIL yield) or simulated (virtual-time charge).
        pred = self._desc_of(curr)
        mem.auto_write(p, pred.next, p.pid)
        while mem.auto_read(p, d.budget) == -1:
            mem.yield_point()

        if mem.auto_read(p, d.budget) == 0:
            # Budget exhausted: yield the global lock to the other class
            # before entering (Algorithm 2 lines 11-13 — the fairness hook).
            assert self.p_reacquire is not None, "cohort lock not wired to ALock"
            self.p_reacquire(p)
            mem.auto_write(p, d.budget, self.init_budget)
        return False

    def q_unlock(self, p: Process, piggyback=None) -> None:
        """Release: pass to the successor with a decremented budget, or CAS
        the tail back to null (which also releases the Peterson flag).

        ``piggyback`` — optional ``("write", reg, value)`` work requests on
        the lock's home node, executed while the critical section is still
        held: a local releaser applies them directly; a remote releaser
        chains them into the *same doorbell* as the tail-drain rCAS (WR lists
        execute in order, so the writes land before the release linearizes).
        This is how the lock table flushes a grant's register writes without
        paying a separate posting.
        """
        mem = self.mem
        d = self._desc(p)
        if piggyback and p.is_local_to(self.tail):
            for _, reg, value in piggyback:
                mem.write(p, reg, value)
            piggyback = None
        if mem.auto_read(p, d.next) is NULLPTR:
            if piggyback:
                observed = mem.post_batch(
                    p, list(piggyback) + [("cas", self.tail, p.pid, NULLPTR)]
                )[-1]
                piggyback = None
                if observed == p.pid:
                    return  # drained: writes flushed + lock released, 1 doorbell
            elif mem.auto_cas(p, self.tail, expected=p.pid, swap=NULLPTR) == p.pid:
                return  # queue drained; cohort flag now unset ⇒ global released
            # Someone is mid-enqueue: wait for the link (Algorithm 2 line 17).
            while mem.auto_read(p, d.next) is NULLPTR:
                mem.yield_point()
        if piggyback:  # successor path: flush before handing the CS over
            mem.post_batch(p, piggyback)
        nxt = self._desc_of(mem.auto_read(p, d.next))
        handoff = mem.auto_read(p, d.budget) - 1
        mem.auto_write(p, nxt.budget, handoff)  # pass the lock

    def q_is_locked(self, p: Process) -> bool:
        """Peterson "interested" test for this class (Algorithm 2 line 20)."""
        return self.mem.auto_read(p, self.tail) is not NULLPTR
