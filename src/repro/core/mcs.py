"""Budgeted MCS queue lock (paper Algorithm 2).

One instance per *class* (local / remote).  The queue tail register lives on
the lock's home node and **doubles as the Peterson "interested" flag** for its
class (the paper's ``cohort[2]`` array).  Each process owns a remotely
accessible descriptor ``{budget, next}`` residing in its *own* node's memory
partition, so after enqueueing a process spins **locally** — the paper's key
property that removes remote spinning and its network traffic.

Operation costs (verified by ``benchmarks/lock_ops.py``):

* lone remote acquire:   1 rCAS
* queued remote acquire: 1 rCAS + 1 rWrite (link), then local spinning only
* remote release:        ≤ 1 rCAS + 1 rWrite
* any local-class call:  0 RDMA operations (auto-dispatch resolves every
  access to the local class's registers as a machine-local op)

The ``budget`` (Dice et al.'s lock-cohorting bound) caps consecutive same-class
hand-offs: a process handed a budget of 0 must call ``p_reacquire`` on the
global (Peterson) lock before entering, yielding to the other class if it is
waiting — this is what makes the combined primitive fair.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from .memory import NULLPTR, AsymmetricMemory, Process, Register


class _Descriptor:
    """Remotely-accessible MCS descriptor: two registers on the owner's node."""

    __slots__ = ("budget", "next")

    def __init__(self, budget: Register, nxt: Register):
        self.budget = budget
        self.next = nxt


class BudgetedMCSLock:
    """Paper Algorithm 2 — budgeted MCS queue lock over asymmetric memory.

    ``p_reacquire`` is the hook into the enclosing modified Peterson's lock
    (Algorithm 1 line 12); it is injected by :class:`repro.core.alock.ALock`
    after construction to break the circular dependency, mirroring how the
    paper embeds the cohort lock *inside* the global lock.
    """

    def __init__(
        self,
        mem: AsymmetricMemory,
        tail: Register,
        init_budget: int,
        name: str,
    ):
        if init_budget <= 0:
            raise ValueError("InitialBudget must be > 0 (PlusCal ASSUME)")
        self.mem = mem
        self.tail = tail  # == cohort[cid]: non-null ⇔ class is "interested"
        self.init_budget = init_budget
        self.name = name
        self.p_reacquire: Optional[Callable[[Process], None]] = None
        self._descs: Dict[int, _Descriptor] = {}
        self._desc_guard = __import__("threading").Lock()

    # ------------------------------------------------------------ descriptors
    def _desc(self, p: Process) -> _Descriptor:
        """The calling process's own descriptor (allocated on its node)."""
        d = self._descs.get(p.pid)
        if d is None:
            with self._desc_guard:
                d = self._descs.get(p.pid)
                if d is None:
                    prefix = f"{self.name}.desc.p{p.pid}"
                    d = _Descriptor(
                        budget=self.mem.alloc(p.node, f"{prefix}.budget", -1),
                        nxt=self.mem.alloc(p.node, f"{prefix}.next", NULLPTR),
                    )
                    self._descs[p.pid] = d
        return d

    def _desc_of(self, handle: Any) -> _Descriptor:
        """Dereference a descriptor handle found in shared memory."""
        return self._descs[handle]

    # -------------------------------------------------------------------- API
    def q_lock(self, p: Process) -> bool:
        """Acquire the cohort lock.

        Returns ``True`` iff the queue was empty at the outset — the caller is
        the class *leader* and must engage the global Peterson protocol
        (Algorithm 1 line 5).  ``False`` means the global lock was passed to
        us by a cohort member (possibly after a budget-forced reacquire).
        """
        mem = self.mem
        d = self._desc(p)
        # PlusCal c1: descriptor := [budget |-> -1, next |-> 0].  Setting
        # budget=-1 *before* publishing the descriptor avoids a lost hand-off
        # (Algorithm 2 writes -1 after the CAS but before linking; equivalent
        # because the predecessor cannot find us until the link rWrite).
        mem.auto_write(p, d.budget, -1)
        mem.auto_write(p, d.next, NULLPTR)

        # Swap ourselves into the tail (RDMA offers CAS, not swap ⇒ CAS loop;
        # Algorithm 2 lines 3-7, "curr updated on rCAS").
        curr: Any = NULLPTR
        while True:
            observed = mem.auto_cas(p, self.tail, expected=curr, swap=p.pid)
            if observed == curr:
                break
            curr = observed

        if curr is NULLPTR:
            # Queue was empty: we are the leader (PlusCal c8).
            mem.auto_write(p, d.budget, self.init_budget)
            return True

        # Link behind the predecessor, then spin on OUR OWN descriptor — a
        # machine-local read; no remote spinning (Algorithm 2 lines 8-10).
        # The wait step goes through the memory's yield_point so the same
        # code runs threaded (GIL yield) or simulated (virtual-time charge).
        pred = self._desc_of(curr)
        mem.auto_write(p, pred.next, p.pid)
        while mem.auto_read(p, d.budget) == -1:
            mem.yield_point()

        if mem.auto_read(p, d.budget) == 0:
            # Budget exhausted: yield the global lock to the other class
            # before entering (Algorithm 2 lines 11-13 — the fairness hook).
            assert self.p_reacquire is not None, "cohort lock not wired to ALock"
            self.p_reacquire(p)
            mem.auto_write(p, d.budget, self.init_budget)
        return False

    def q_unlock(self, p: Process, piggyback=None) -> None:
        """Release: pass to the successor with a decremented budget, or CAS
        the tail back to null (which also releases the Peterson flag).

        ``piggyback`` — optional ``("write", reg, value)`` work requests on
        the lock's home node, executed while the critical section is still
        held: a local releaser applies them directly; a remote releaser
        chains them into the *same doorbell* as the tail-drain rCAS (WR lists
        execute in order, so the writes land before the release linearizes).
        This is how the lock table flushes a grant's register writes without
        paying a separate posting.
        """
        mem = self.mem
        d = self._desc(p)
        if piggyback and p.is_local_to(self.tail):
            for _, reg, value in piggyback:
                mem.write(p, reg, value)
            piggyback = None
        if mem.auto_read(p, d.next) is NULLPTR:
            if piggyback:
                observed = mem.post_batch(
                    p, list(piggyback) + [("cas", self.tail, p.pid, NULLPTR)]
                )[-1]
                piggyback = None
                if observed == p.pid:
                    return  # drained: writes flushed + lock released, 1 doorbell
            elif mem.auto_cas(p, self.tail, expected=p.pid, swap=NULLPTR) == p.pid:
                return  # queue drained; cohort flag now unset ⇒ global released
            # Someone is mid-enqueue: wait for the link (Algorithm 2 line 17).
            while mem.auto_read(p, d.next) is NULLPTR:
                mem.yield_point()
        if piggyback:  # successor path: flush before handing the CS over
            mem.post_batch(p, piggyback)
        nxt = self._desc_of(mem.auto_read(p, d.next))
        handoff = mem.auto_read(p, d.budget) - 1
        mem.auto_write(p, nxt.budget, handoff)  # pass the lock

    def q_is_locked(self, p: Process) -> bool:
        """Peterson "interested" test for this class (Algorithm 2 line 20)."""
        return self.mem.auto_read(p, self.tail) is not NULLPTR

    # ------------------------------------------------- split-phase variant
    # The blocking q_lock/q_unlock pair above is what ALock composes.  The
    # lock table's *inflated keys* need the same queue discipline but
    # cannot block (sim clients are cooperative generator tasks; a spin
    # inside one table call would wedge the engine's atomic step), so the
    # acquire is split into enqueue → poll → pass:
    #
    #   q_enqueue  — publish + swap into the tail + link; NEVER spins.
    #   q_granted  — "has the entitlement reached me?": a machine-local
    #                read of the caller's own budget register (0 RDMA per
    #                poll — the MCS local-spinning property, poll-shaped).
    #   q_pass     — hand the entitlement to the successor (budget - 1,
    #                recycling to init_budget past zero) or drain the tail.
    #
    # There is no p_reacquire hook on this path: the inflated queue has no
    # enclosing Peterson.  Inter-cohort arbitration happens at the shard
    # ALock every grant passes through; a zero budget merely tells the
    # head to defer one poll round to the other cohort (see
    # InflatedKeyQueue.poll), preserving the cohort-budget fairness shape
    # without a second global lock.

    def q_enqueue(self, p: Process) -> bool:
        """Split-phase front half of :meth:`q_lock`: returns ``True`` iff
        the queue was empty (the caller is the cohort leader and already
        entitled — its budget is set to ``init_budget``).  ``False`` means
        parked behind a predecessor: poll :meth:`q_granted`.

        Cost (same as the q_lock front half): a lone remote enqueue is
        1 rCAS; a queued one adds 1 rWrite for the link; every local-class
        call is 0 RDMA.  The tail CAS + link land in one table call, so
        under the sim engine's atomic steps the predecessor can never
        observe the swapped-but-unlinked window.
        """
        mem = self.mem
        d = self._desc(p)
        mem.auto_write(p, d.budget, -1)
        mem.auto_write(p, d.next, NULLPTR)
        curr: Any = NULLPTR
        while True:
            observed = mem.auto_cas(p, self.tail, expected=curr, swap=p.pid)
            if observed == curr:
                break
            curr = observed
        if curr is NULLPTR:
            mem.auto_write(p, d.budget, self.init_budget)
            return True
        pred = self._desc_of(curr)
        mem.auto_write(p, pred.next, p.pid)
        return False

    def q_granted(self, p: Process) -> int:
        """Non-blocking entitlement poll: the caller's own budget register
        (a machine-local read — its descriptor lives on its node).
        ``-1`` = still parked; ``>= 0`` = entitled, value is the budget."""
        return self.mem.auto_read(p, self._desc(p).budget)

    def q_set_budget(self, p: Process, value: int) -> None:
        """Reset the caller's own budget (machine-local write) — used by
        the split-phase defer round when a handed-down budget hits zero."""
        self.mem.auto_write(p, self._desc(p).budget, value)

    def q_has_successor(self, p: Process) -> bool:
        """Is someone linked behind the caller?  One machine-local read of
        the caller's own ``next`` pointer — the direct-handoff peek."""
        return self.mem.auto_read(p, self._desc(p).next) is not NULLPTR

    def q_pass(self, p: Process, payload: Optional[tuple] = None) -> bool:
        """Split-phase release: drain the tail (``True``) or hand the
        entitlement to the successor with a decremented budget (``False``).

        A budget already at zero recycles to ``init_budget - 1`` on the
        way down: with no global lock to reacquire, the zero itself is the
        fairness signal (consumed by the head's defer round), and handing
        a raw ``-1`` would read as "parked" and lose the wakeup.  The
        wait-for-link spin is reachable only threaded — under the sim's
        atomic steps an enqueue's tail CAS and link land in one step.

        ``payload`` rides the same budget write: the successor receives
        ``(budget, *payload)`` instead of the bare integer — the direct
        lock handoff (the releaser already transferred ownership via the
        word; the tuple tells the successor what it now holds).  Costs
        nothing extra: it is the one write the pass was making anyway.
        """
        mem = self.mem
        d = self._desc(p)
        if mem.auto_read(p, d.next) is NULLPTR:
            if mem.auto_cas(p, self.tail, expected=p.pid, swap=NULLPTR) == p.pid:
                return True  # cohort drained
            while mem.auto_read(p, d.next) is NULLPTR:
                mem.yield_point()
        nxt = self._desc_of(mem.auto_read(p, d.next))
        budget = mem.auto_read(p, d.budget)
        if isinstance(budget, tuple):  # an unconsumed direct grant: its
            budget = budget[0]         # budget share still counts down
        handoff = budget - 1 if budget > 0 else self.init_budget - 1
        value = (handoff,) + tuple(payload) if payload is not None else handoff
        mem.auto_write(p, nxt.budget, value)
        return False


LOCAL_COHORT, REMOTE_COHORT = 0, 1


class InflatedKeyQueue:
    """The per-key queue a hot (inflated) lock-table key escalates into.

    Two split-phase :class:`BudgetedMCSLock` cohorts — one for the key's
    home-host clients (every operation machine-local, 0 RDMA), one for
    everyone else (1 rCAS + ≤1 rWrite to enqueue, then local polling) —
    exactly ALock's asymmetric shape, minus the Peterson layer: at most
    one *leader per cohort* is entitled at a time, and the shard ALock
    that every grant transaction already passes through arbitrates
    between the (≤ 2) entitled leaders.  Mixing both classes in ONE queue
    would be unsound: the tail register would see local CAS and rCAS
    interleaved, the non-atomic combination of Table 1.

    The queue is *advisory ordering and admission throttling*: safety
    (mutual exclusion, fencing) always comes from the packed word and the
    shard critical section.  A crashed head strands its cohort only until
    the staleness deadline, after which waiters bypass the queue and probe
    the word directly (the table then deflates the key — disorderly events
    always reset queue state rather than trust it).

    One instance per inflation *epoch*: deflation discards the whole
    object (register names carry the epoch, so re-inflation cannot alias
    a dead epoch's descriptors).
    """

    def __init__(self, mem: AsymmetricMemory, home_node: int,
                 init_budget: int, name: str):
        self.mem = mem
        self.home_node = home_node
        self.cohorts = tuple(
            BudgetedMCSLock(
                mem,
                mem.alloc(home_node, f"{name}.c{cid}.tail", NULLPTR),
                init_budget,
                f"{name}.c{cid}",
            )
            for cid in (LOCAL_COHORT, REMOTE_COHORT)
        )

    def cid_of(self, p: Process) -> int:
        return LOCAL_COHORT if p.node == self.home_node else REMOTE_COHORT

    def enqueue(self, p: Process) -> bool:
        """Join the caller's class cohort; True iff immediately entitled."""
        return self.cohorts[self.cid_of(p)].q_enqueue(p)

    def poll(self, p: Process) -> str:
        """``"parked"`` (not yet head — the poll was one local read, 0
        RDMA), ``"granted"`` (the predecessor handed the lock itself over:
        consume with :meth:`take_grant`), ``"defer"`` (head, but the
        handed budget hit zero and the other cohort is waiting: yield one
        round — the cohort-budget fairness bound), or ``"entitled"``
        (head: go attempt the grant on the word)."""
        cid = self.cid_of(p)
        mine = self.cohorts[cid]
        budget = mine.q_granted(p)
        if isinstance(budget, tuple):
            return "granted"
        if budget < 0:
            return "parked"
        if budget == 0:
            mine.q_set_budget(p, mine.init_budget)
            if self.cohorts[1 - cid].q_is_locked(p):
                return "defer"
        return "entitled"

    def can_direct(self, p: Process) -> bool:
        """May the releaser hand the lock straight to its successor?

        True iff someone is linked behind it AND the cohort-budget
        fairness rule does not owe the other cohort a turn (a handoff
        that would arrive at budget ≤ 0 while the other cohort waits).
        The successor peek and budget read are machine-local; the other
        cohort's tail is read only when the budget actually runs out —
        amortised to one remote read per ``init_budget`` handoffs."""
        cid = self.cid_of(p)
        mine = self.cohorts[cid]
        if not mine.q_has_successor(p):
            return False
        budget = mine.q_granted(p)
        if isinstance(budget, tuple):
            budget = budget[0]
        if budget <= 1:  # successor would land at <= 0: other class's turn?
            return not self.cohorts[1 - cid].q_is_locked(p)
        return True

    def pass_grant(self, p: Process, token: int, expires_at: float) -> bool:
        """Direct handoff: pass the cohort entitlement AND the lock — the
        caller already CAS'd the word over to ``token``; the successor's
        budget register receives ``(budget, token, expires_at)`` and its
        next poll returns ``"granted"``.  Same single write as a plain
        pass.  True iff the cohort drained instead (no successor after
        all — the grant value was never written; the caller must treat
        the handoff as declined)."""
        return self.cohorts[self.cid_of(p)].q_pass(
            p, payload=(token, expires_at))

    def take_grant(self, p: Process) -> Optional[tuple]:
        """Consume a pending direct grant: returns ``(token, expires_at)``
        and resets the budget register to its plain integer share (later
        polls read an ordinary entitlement), or ``None`` if nothing is
        pending."""
        mine = self.cohorts[self.cid_of(p)]
        v = mine.q_granted(p)
        if not isinstance(v, tuple):
            return None
        budget, token, expires_at = v
        mine.q_set_budget(p, budget)
        return (token, expires_at)

    def release(self, p: Process) -> bool:
        """Pass the entitlement within the caller's cohort (or drain it).
        True iff the caller's cohort is now empty."""
        return self.cohorts[self.cid_of(p)].q_pass(p)

    def empty(self, p: Process) -> bool:
        """Both cohorts drained (two tail reads; machine-local for the
        home host).  Used inside grant transactions and by deflation."""
        return not (self.cohorts[LOCAL_COHORT].q_is_locked(p)
                    or self.cohorts[REMOTE_COHORT].q_is_locked(p))
