"""Flash attention forward kernel (Pallas TPU).

TPU-native tiling of the online-softmax attention in
``repro.models.attention.online_attention`` (same contract):

* grid ``(B, H, nq, nk)`` — the last (innermost) dimension is *sequential*
  ("arbitrary" semantics on TPU): the kernel revisits the same output block
  for each KV block, accumulating running (max, sum, acc) in fp32 VMEM
  scratch and finalising on the last KV step;
* BlockSpecs stage ``[qb, d]`` query tiles and ``[kb, d]`` KV tiles into VMEM
  (qb/kb default 512/1024 → the dominant working set is
  qb·d + kb·d + qb·kb ≈ 0.8 MB at d=128 in bf16 — comfortably inside the
  ~16 MB v5e VMEM, leaving room for double buffering);
* matmul tiles are MXU-aligned (qb, kb, d multiples of 128; d=64 heads still
  map acceptably);
* GQA is handled by indexing the KV head as ``h // (H // K)`` in the
  BlockSpec index maps — no repeated KV materialisation in HBM;
* masks (causal / sliding window / tail padding) are applied with 2-D iota
  position tiles, so padded cells never contribute.

Validated in ``interpret=True`` mode against the pure-jnp oracle
(``kernels/ref.py``) across shape/dtype sweeps (tests/test_kernels.py);
this CPU container cannot compile Mosaic, so the XLA path remains the
dry-run/roofline implementation and this kernel is the TPU deployment path
(``ModelConfig.use_pallas``).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed CompilerParams (new) <- TPUCompilerParams (jax 0.4.x)
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, causal: bool, window: int, scale: float,
    qb: int, kb: int, nk: int, tq: int, tk: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                      # [qb, dk]
    k = k_ref[0, :, 0, :]                      # [kb, dk]
    v = v_ref[0, :, 0, :]                      # [kb, dv]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                   # [qb, kb]

    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    mask = (k_pos < tk) & (q_pos < tq)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                         # [qb, 1]
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    p = jnp.exp(s - m_new)                      # [qb, kb]
    corr = jnp.exp(m_prev - m_new)              # [qb, 1]
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)[:, None]
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,   # [B, Tq, H, dk]
    k: jnp.ndarray,   # [B, Tk, K, dk]
    v: jnp.ndarray,   # [B, Tk, K, dv]
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    k_block: int = 1024,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> jnp.ndarray:
    B, Tq, H, dk = q.shape
    _, Tk, K, dv = v.shape
    assert H % K == 0, (H, K)
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)

    qb = min(q_block, Tq)
    kb = min(k_block, Tk)
    pq = (-Tq) % qb
    pk = (-Tk) % kb
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq = q.shape[1] // qb
    nk = k.shape[1] // kb

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, scale=scale,
        qb=qb, kb=kb, nk=nk, tq=Tq, tk=Tk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb, 1, dk), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, kb, 1, dk), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, kb, 1, dv), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, 1, dv), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * qb, H, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, dv), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Tq]
