"""Pallas TPU kernels for the perf-critical hot spots (+ interpret-mode CPU
validation). See flash_attention.py / rglru_scan.py headers for tiling."""

from . import ops, ref  # noqa: F401
