"""Jit'd public wrappers for the Pallas kernels, with autodiff.

``flash_attention`` and ``rglru_scan`` run the Pallas forward kernel and use
a recompute-based backward (``jax.custom_vjp`` around the jnp oracle's vjp) —
the standard flash trade: no O(T²) residuals, backward recomputes tiles.

On this CPU container kernels execute in ``interpret=True`` mode; on TPU set
``REPRO_PALLAS_INTERPRET=0`` (or pass ``interpret=False``) to compile with
Mosaic.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_fwd
from .rglru_scan import rglru_scan_fwd


def _default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def flash_attention(
    q, k, v, causal: bool = True, window: int = 0,
    q_block: int = 512, k_block: int = 1024, scale: Optional[float] = None,
):
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window,
        q_block=q_block, k_block=k_block, scale=scale,
        interpret=_default_interpret(),
    )


def _fa_fwd(q, k, v, causal, window, q_block, k_block, scale):
    out = flash_attention(q, k, v, causal, window, q_block, k_block, scale)
    return out, (q, k, v)


def _fa_bwd(causal, window, q_block, k_block, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, scale=scale
        ),
        q, k, v,
    )
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


@jax.custom_vjp
def rglru_scan(a, b, h0):
    return rglru_scan_fwd(a, b, h0, interpret=_default_interpret())


def _rg_fwd(a, b, h0):
    return rglru_scan(a, b, h0), (a, b, h0)


def _rg_bwd(res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(ref.rglru_scan_ref, a, b, h0)
    return vjp(g)


rglru_scan.defvjp(_rg_fwd, _rg_bwd)
