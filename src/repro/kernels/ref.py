"""Pure-jnp oracles for the Pallas kernels (quadratic / sequential forms)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Quadratic softmax attention with GQA expansion. Same contract as the
    kernel: q [B,Tq,H,dk], k/v [B,Tk,K,d*] → [B,Tq,H,dv]."""
    B, Tq, H, dk = q.shape
    _, Tk, K, dv = v.shape
    G = H // K
    if K != H:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    s = jnp.einsum("bqhd,blhd->bhql", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Tq)
    k_pos = jnp.arange(Tk)
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhql,blhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(v.dtype)


def rglru_scan_ref(a, b, h0) -> jnp.ndarray:
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t. [B,T,W] fp32."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
