"""RG-LRU linear-recurrence kernel (Pallas TPU), time-blocked.

Computes ``h_t = a_t * h_{t-1} + b_t`` over [B, T, W] in fp32.  Tiling:

* grid ``(B, nW, nT)`` — the time dimension is innermost and *sequential*
  ("arbitrary"): the carry ``h`` lives in VMEM scratch across time blocks;
* each invocation processes a ``[tb, wb]`` tile: the within-block scan is a
  log-depth associative scan on registers/VMEM (the same
  ``(a2·a1, a2·b1 + b2)`` combinator as the XLA path), then the incoming
  carry is folded in with a cumulative-product rescale:
  ``h_t_full = h_t_local + cumprod(a)[t] * h_in``;
* wb defaults to 512 lanes (multiple of 128), tb to 256 — working set
  ≈ 3 · tb · wb · 4 B ≈ 1.5 MB of VMEM.

This is the TPU adaptation of the paper's "per-class optimal mechanism":
the recurrence is local math on fast memory; nothing crosses the fabric.
Validated in interpret mode against ``kernels/ref.py`` (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed CompilerParams (new) <- TPUCompilerParams (jax 0.4.x)
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, h_scr, *, nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0, :][None, :] * 0.0 + h0_ref[0, :][None, :]

    a = a_ref[0]                                # [tb, wb]
    b = b_ref[0]

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_cum, h_loc = jax.lax.associative_scan(combine, (a, b), axis=0)
    h_in = h_scr[...]                           # [1, wb]
    h_full = h_loc + a_cum * h_in
    o_ref[0] = h_full.astype(o_ref.dtype)
    h_scr[...] = h_full[-1:, :]


def rglru_scan_fwd(
    a: jnp.ndarray,     # [B, T, W] fp32 decay
    b: jnp.ndarray,     # [B, T, W] fp32 input
    h0: jnp.ndarray,    # [B, W] fp32 initial state
    *,
    t_block: int = 256,
    w_block: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    B, T, W = a.shape
    tb = min(t_block, T)
    wb = min(w_block, W)
    pt = (-T) % tb
    pw = (-W) % wb
    if pt or pw:
        # pad decays with 1s? padding a with 0 and b with 0 keeps h constant
        # only if padded a=1; pad time with a=1,b=0 and width with anything.
        a = jnp.pad(a, ((0, 0), (0, pt), (0, pw)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pt), (0, pw)))
        h0 = jnp.pad(h0, ((0, 0), (0, pw)))
    nt = a.shape[1] // tb
    nw = a.shape[2] // wb

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, nt=nt),
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, tb, wb), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, tb, wb), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, wb), lambda bi, wi, ti: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, tb, wb), lambda bi, wi, ti: (bi, ti, wi)),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, wb), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b, h0)
    return out[:, :T, :W]
