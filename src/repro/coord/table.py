"""Sharded asymmetric lock table: the paper's per-class cost optimality,
applied to a whole keyspace instead of one record.

A single :class:`~repro.core.ALock` makes exactly one host the privileged
"local" class; everyone else pays fabric operations.  That is the right shape
for one hot record, but a control plane serving millions of keys wants the
privilege *spread out*: partition the keyspace into ``num_shards`` shards,
home shard ``s`` on host ``s % num_hosts`` (a stable hash, so placement never
depends on interpreter state), and guard each shard's lease metadata with its
own ALock.  Every host is then the zero-RDMA local class for its slice of the
keyspace, and the paper's cost claims hold *per shard*: a client transacting
on keys homed on its own host issues **zero** simulated RDMA operations, and
a remote client pays the ALock's bounded budget.

Layered on the shard locks is a **lease table** (the long-lived exclusion):

* ``try_acquire(p, key, ttl)`` grants a :class:`Lease` with a monotonically
  increasing **fencing token** per key.  The shard's ALock is held only for
  the short metadata transaction — the lease itself is what excludes other
  clients, so a crashed holder can never wedge the shard: its lease expires
  after ``ttl`` and the next grant carries a larger token, which downstream
  resources use to reject the crashed holder's stale writes.
* ``acquire_batch(p, keys, ttl)`` takes multiple leases in the **global key
  order** ``(shard_of(key), key)``.  All batched clients walk the same total
  order, so no cycle of waiters can form — deadlock freedom without a
  detector (see ``docs/lock-table.md``).

Hot-path optimisations (see the "Hot path" section of ``docs/lock-table.md``):

* **Renewal/release fast path** — the current holder extends or drops its
  lease with a single fencing-token-checked CAS on the expiry register,
  *without* taking the shard ALock: zero simulated RDMA ops for local
  holders, exactly one rCAS for remote holders.  The expiry register packs
  ``(fence_token, expires_at)`` so the CAS validates the fence: a zombie
  holder's CAS always loses after a re-grant (the token moved on).
* **Shard-grouped batches** — ``acquire_batch`` holds each shard's ALock
  once for all of that shard's keys (O(distinct shards) critical sections
  instead of O(keys)), still walking the global order.
* **Doorbell coalescing** — remote clients post the critical section's
  register reads in one :meth:`~repro.core.AsymmetricMemory.post_batch`
  doorbell and its writes in another, modelling RDMA WR posting lists.

Telemetry: every table operation snapshots the calling process's
:class:`~repro.core.OpCounts` (an O(1) tuple snapshot, accumulated in place —
no per-op dict copies) and adds the delta to the target shard's per-class
(LOCAL/REMOTE) totals, so benchmarks and the serving layer can verify the
zero-RDMA home path without instrumenting clients.
"""

from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import ALock, AsymmetricMemory, OpCounts, Process

LOCAL, REMOTE = 0, 1

_NO_HOLDER = -1

# The expiry register packs (fence_token, expires_at).  expires_at <= FREE_AT
# means the key is not held (never granted, or released); a grant always
# writes a strictly positive expiry, so the states cannot be confused.
_FREE_AT = 0.0


@lru_cache(maxsize=1 << 17)
def stable_key_hash(key: str) -> int:
    """A process-stable 64-bit hash (Python's ``hash`` is salted per run).

    Cached: placement hashing of a hot key must not recompute blake2b on
    every operation (the cache is per-process and placement is stable, so
    memoisation can never change an answer).
    """
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big"
    )


@dataclass(frozen=True)
class Lease:
    """A granted lease: the unit of long-lived exclusion.

    ``token`` is the fencing token — strictly increasing per key across
    grants, so any resource that records the largest token it has seen can
    reject writes from a holder whose lease has expired and been re-granted.

    ``expires_at`` doubles as the fast-path CAS witness: ``renew``/``release``
    compare-and-swap the expiry register against ``(token, expires_at)``, so
    hold on to the *latest* lease returned by acquire/renew (the
    :class:`~repro.coord.CoordinationService` lease cache does this for you).
    """

    key: str
    shard: int
    holder_pid: int
    token: int
    expires_at: float
    ttl: float


class _KeyState:
    """Per-key lease registers, allocated on the shard's home node.

    ``holder`` and ``fence`` are read/written **only** inside the shard
    ALock's critical section; ``fence`` is the authoritative token allocator,
    which is why grant tokens are strictly monotonic unconditionally.

    ``expires`` packs ``(fence_token, expires_at)`` and is the one register
    the *current holder* may CAS lock-free (the renewal/release fast path).
    Because remote RMW is not atomic against the critical section's writes
    (Table 1), a **zombie's** in-flight rCAS write phase can, in a vanishing
    window, overwrite a concurrent re-grant's write with its stale tuple.
    The CS-only ``fence`` makes that clobber *detectable* (``expires`` token
    ≠ fence) and *unable to affect token allocation*; grant decisions treat
    a clobbered mirror as expired and repair it (``shard.repairs``
    telemetry).  This is the standard lease-system posture: expiry-time
    races cannot be airtight under asynchrony, fencing tokens are what make
    them harmless downstream — and the tokens themselves never regress.
    """

    __slots__ = ("holder", "expires", "fence")

    def __init__(self, mem: AsymmetricMemory, node: int, name: str):
        self.holder = mem.alloc(node, f"{name}.holder", _NO_HOLDER)
        self.expires = mem.alloc(node, f"{name}.expires", (0, _FREE_AT))
        self.fence = mem.alloc(node, f"{name}.fence", 0)


class LockShard:
    """One shard: an ALock guarding the lease metadata of its keys."""

    def __init__(self, mem: AsymmetricMemory, index: int, home_host: int,
                 init_budget: int, name: str):
        self.index = index
        self.home_host = home_host
        self.alock = ALock(mem, home_host, init_budget, name=f"{name}.s{index}")
        self.keys: Dict[str, _KeyState] = {}
        # Meta-level accounting (not part of the simulated protocol).
        self.stats = {LOCAL: OpCounts(), REMOTE: OpCounts()}
        self.grants = 0
        self.rejects = 0
        self.expirations = 0
        self.fast_renews = 0
        self.fast_releases = 0
        self.repairs = 0  # clobbered expiry mirrors repaired by a grant
        self._meta = threading.Lock()


class ShardedLockTable:
    """N lock shards spread over the hosts of one asymmetric memory."""

    def __init__(
        self,
        mem: AsymmetricMemory,
        num_shards: Optional[int] = None,
        init_budget: int = 4,
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
        name: str = "table",
    ):
        self.mem = mem
        self.num_hosts = mem.num_nodes
        self.num_shards = num_shards or 2 * self.num_hosts
        if self.num_shards <= 0:
            raise ValueError("num_shards must be > 0")
        # clock and sleep travel as a pair: the blocking paths compute their
        # deadline on `clock` and back off on `sleep`, so injecting one
        # without the other (the old wall-clock time.sleep next to a fake
        # clock) would stall a poll loop forever — or time out instantly —
        # whenever the two disagree.  The sim engine injects a virtual clock
        # plus a charging sleep; threaded callers get the time module's pair.
        self.clock = clock or time.monotonic
        self.sleep = sleep or time.sleep
        self.name = name
        self.shards = [
            LockShard(mem, s, s % self.num_hosts, init_budget, name)
            for s in range(self.num_shards)
        ]

    # ---------------------------------------------------------- placement
    def shard_of(self, key: str) -> int:
        """Stable hash placement: same key → same shard, in every process."""
        return stable_key_hash(key) % self.num_shards

    def home_of(self, key: str) -> int:
        """The host that is the zero-RDMA local class for ``key``."""
        return self.shards[self.shard_of(key)].home_host

    def _key_state(self, shard: LockShard, key: str) -> _KeyState:
        st = shard.keys.get(key)
        if st is None:
            with shard._meta:
                st = shard.keys.get(key)
                if st is None:
                    st = _KeyState(
                        self.mem, shard.home_host,
                        f"{self.name}.s{shard.index}.k{stable_key_hash(key):016x}",
                    )
                    shard.keys[key] = st
        return st

    # ---------------------------------------------------------- accounting
    def _account(self, shard: LockShard, p: Process, snap: tuple) -> None:
        cls = LOCAL if p.node == shard.home_host else REMOTE
        with shard._meta:
            shard.stats[cls].add_since(p.counts, snap)

    # --------------------------------------------------- batched register IO
    def _read_pairs(self, p: Process, shard: LockShard,
                    states: Sequence[_KeyState]) -> List[Tuple[tuple, int]]:
        """Read each key's (expires, fence) — one doorbell for remote clients."""
        if p.node == shard.home_host:
            return [
                (self.mem.read(p, st.expires), self.mem.read(p, st.fence))
                for st in states
            ]
        flat = self.mem.post_batch(
            p,
            [wr for st in states
             for wr in (("read", st.expires), ("read", st.fence))],
        )
        return [(flat[2 * i], flat[2 * i + 1]) for i in range(len(states))]

    def _read_key_state(self, p: Process, shard: LockShard,
                        st: _KeyState) -> Tuple[int, tuple, int]:
        """The slow paths' validation read set (holder, expires, fence) —
        one doorbell for remote clients."""
        if p.node == shard.home_host:
            return (self.mem.read(p, st.holder),
                    self.mem.read(p, st.expires),
                    self.mem.read(p, st.fence))
        holder, packed, fence = self.mem.post_batch(p, [
            ("read", st.holder), ("read", st.expires), ("read", st.fence),
        ])
        return holder, packed, fence

    # --------------------------------------------------------------- leases
    def _acquire_group(self, p: Process, shard: LockShard,
                       keys: Sequence[str], ttl: float,
                       ) -> Tuple[List[Lease], bool]:
        """Grant a prefix of ``keys`` (one shard, global order) in **one**
        ALock critical section.

        Returns ``(granted, blocked)``: the leases granted, and whether the
        next key was held by a live lease (granting stops there — taking
        later keys while a smaller one is still wanted would break the
        deadlock-avoidance total order).  Never blocks inside the critical
        section.
        """
        states = [self._key_state(shard, k) for k in keys]
        snap = p.counts.as_tuple()
        local = p.node == shard.home_host
        granted: List[Lease] = []
        writes: List[tuple] = []
        blocked = False
        expirations = 0
        repairs = 0
        # Sample the clock BEFORE acquiring: every register read then happens
        # at-or-after ``now``, so an "expired" verdict (eexp <= now <= read
        # time) can only be beaten by a renewal whose local-clock check
        # predates ``now`` but whose CAS lands after our read — i.e. exactly
        # the documented zombie window.  Sampling after the lock would let a
        # *healthy* pre-expiry renewal race the piggybacked (pre-CS) reads
        # and be silently re-granted over.
        now = self.clock()
        try:
            if local:
                shard.alock.lock(p)
                flat = None
            else:
                # Chain the lease-register reads into the Peterson-engagement
                # doorbell; valid on uncontended fast entry, else re-read.
                flat = shard.alock.lock(p, piggyback_reads=[
                    r for st in states for r in (st.expires, st.fence)
                ])
            try:
                if flat is None:
                    vals = self._read_pairs(p, shard, states)
                else:
                    vals = [(flat[2 * i], flat[2 * i + 1])
                            for i in range(len(states))]
                for key, st, ((etok, eexp), fence) in zip(keys, states, vals):
                    free = eexp <= _FREE_AT
                    clobbered = etok != fence  # zombie CAS hit the mirror
                    if not free and not clobbered and now < eexp:
                        blocked = True
                        break
                    if clobbered:
                        repairs += 1  # untrusted mirror: treat as expired
                    elif not free:
                        expirations += 1  # grant over an expired lease
                    token = fence + 1  # CS-only allocator: never regresses
                    granted.append(
                        Lease(key, shard.index, p.pid, token, now + ttl, ttl)
                    )
                    writes += [
                        ("write", st.fence, token),
                        ("write", st.holder, p.pid),
                        ("write", st.expires, (token, now + ttl)),
                    ]
            finally:
                # The grant writes ride the unlock: applied in place by a
                # local releaser, chained into the tail-drain doorbell by a
                # remote one — still inside the critical section either way.
                shard.alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap)
        with shard._meta:
            shard.grants += len(granted)
            shard.expirations += expirations
            shard.repairs += repairs
            if blocked:
                shard.rejects += 1
        return granted, blocked

    def try_acquire(self, p: Process, key: str, ttl: float) -> Optional[Lease]:
        """One lease-table transaction; non-blocking.

        Grants iff the key is free or its current lease has expired; a fresh
        grant always carries a larger fencing token.  Returns ``None`` while
        a live lease exists — *including* the caller's own (non-reentrant: a
        holder extends via :meth:`renew`; silently superseding would let one
        process posing as several clients steal its own slots).
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        shard = self.shards[self.shard_of(key)]
        granted, _ = self._acquire_group(p, shard, (key,), ttl)
        return granted[0] if granted else None

    def acquire(self, p: Process, key: str, ttl: float,
                timeout: Optional[float] = None,
                poll: float = 0.0005) -> Lease:
        """Blocking acquire: retry ``try_acquire`` until granted or timeout.

        ``poll`` backs off between attempts — every retry is a full shard
        ALock transaction (remote ops for remote clients), so spinning at
        full rate would burn a core *and* inflate the REMOTE-class telemetry
        with retry traffic.
        """
        deadline = None if timeout is None else self.clock() + timeout
        while True:
            lease = self.try_acquire(p, key, ttl)
            if lease is not None:
                return lease
            if deadline is not None and self.clock() > deadline:
                raise TimeoutError(f"lease on {key!r} not granted in {timeout}s")
            self.sleep(poll)

    def renew(self, p: Process, lease: Lease, ttl: Optional[float] = None) -> Optional[Lease]:
        """Extend a still-valid lease; ``None`` if it was lost (fencing).

        **Fast path** (the common case — the holder renews before expiry,
        with its latest lease object): a single fencing-token-checked CAS on
        the expiry register, no shard ALock.  Zero simulated RDMA ops for a
        local holder, exactly one rCAS for a remote holder.  A zombie whose
        key was re-granted always loses the CAS: the register carries the
        new (larger) fence token, and tokens are never reused (no ABA).

        **Slow path** (stale lease object, or contention diagnosis): the
        original fully-validated transaction under the shard ALock.
        """
        ttl = ttl if ttl is not None else lease.ttl
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.as_tuple()
        try:
            now = self.clock()
            if now < lease.expires_at:
                witness = (lease.token, lease.expires_at)
                observed = self.mem.auto_cas(
                    p, st.expires, witness, (lease.token, now + ttl)
                )
                if observed == witness:
                    with shard._meta:
                        shard.fast_renews += 1
                    return Lease(lease.key, lease.shard, lease.holder_pid,
                                 lease.token, now + ttl, ttl)
            shard.alock.lock(p)
            renewed = None
            write = None
            try:
                now = self.clock()
                holder, (etok, eexp), fence = self._read_key_state(p, shard, st)
                # A clobbered mirror (etok != fence) means the expiry can no
                # longer be trusted: refuse the renewal (conservative — the
                # holder must re-acquire) rather than extend blindly.
                if (
                    holder == lease.holder_pid
                    and fence == lease.token
                    and etok == fence
                    and _FREE_AT < eexp
                    and now < eexp
                ):
                    write = [("write", st.expires, (lease.token, now + ttl))]
                    renewed = Lease(lease.key, lease.shard, lease.holder_pid,
                                    lease.token, now + ttl, ttl)
            finally:
                shard.alock.unlock(p, piggyback=write)
            return renewed
        finally:
            self._account(shard, p, snap)

    def release(self, p: Process, lease: Lease) -> bool:
        """Release iff the lease is still the current grant (token match).

        **Fast path**: one fencing-token-checked CAS writes the expiry
        register to ``(token, FREE)`` — no shard ALock, zero RDMA ops for a
        local holder, one rCAS for a remote one.  The stale ``holder``
        register left behind is harmless: grant decisions key off the packed
        expiry + fence, and the next grant overwrites it.

        **Slow path** (stale lease object whose token is still current): the
        fully-validated transaction under the shard ALock.
        """
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.as_tuple()
        try:
            witness = (lease.token, lease.expires_at)
            observed = self.mem.auto_cas(
                p, st.expires, witness, (lease.token, _FREE_AT)
            )
            if observed == witness:
                with shard._meta:
                    shard.fast_releases += 1
                return True
            shard.alock.lock(p)
            released = False
            writes = None
            try:
                holder, (etok, eexp), fence = self._read_key_state(p, shard, st)
                # Stale (expired and re-granted: the fence moved on) or
                # already released (mirror intact at FREE) ⇒ nothing to do.
                # Releasing the current generation is legal even with a
                # clobbered mirror: the write below re-syncs it.
                if (
                    holder == lease.holder_pid
                    and fence == lease.token
                    and not (etok == fence and eexp <= _FREE_AT)
                ):
                    writes = [
                        ("write", st.holder, _NO_HOLDER),
                        ("write", st.expires, (lease.token, _FREE_AT)),
                    ]
                    released = True
            finally:
                shard.alock.unlock(p, piggyback=writes)
            return released
        finally:
            self._account(shard, p, snap)

    # --------------------------------------------------------------- batches
    def batch_order(self, keys: Iterable[str]) -> List[str]:
        """The deadlock-avoidance total order: ``(shard_of(key), key)``."""
        return sorted(set(keys), key=lambda k: (self.shard_of(k), k))

    def acquire_batch(self, p: Process, keys: Sequence[str], ttl: float,
                      timeout: Optional[float] = None,
                      poll: float = 0.0005) -> List[Lease]:
        """Acquire every key (deduplicated) in the global key order.

        Keys are grouped by shard (the global order is primary-by-shard, so
        groups are contiguous) and each shard's ALock is taken **once** for
        all of its keys — O(distinct shards) critical sections instead of
        O(keys), with the group's register reads and writes each coalesced
        into one doorbell for remote clients.  Deadlock freedom is preserved:
        grants still happen in the global order, and a blocked key is waited
        on *outside* the critical section while holding only smaller keys.

        All-or-nothing: ``timeout`` bounds the *whole batch*; on expiry,
        already-granted leases are released and ``TimeoutError`` is raised.
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        ordered = self.batch_order(keys)
        deadline = None if timeout is None else self.clock() + timeout
        held: List[Lease] = []
        try:
            i, n = 0, len(ordered)
            while i < n:
                shard = self.shards[self.shard_of(ordered[i])]
                j = i + 1
                while j < n and self.shard_of(ordered[j]) == shard.index:
                    j += 1
                group = ordered[i:j]
                start = 0
                while start < len(group):
                    granted, blocked = self._acquire_group(
                        p, shard, group[start:], ttl
                    )
                    held.extend(granted)
                    start += len(granted)
                    if blocked:
                        if deadline is not None and self.clock() > deadline:
                            raise TimeoutError(
                                f"batch lease on {group[start]!r} not granted "
                                f"in {timeout}s"
                            )
                        self.sleep(poll)
                i = j
        except TimeoutError:
            for lease in held:
                self.release(p, lease)
            raise
        return held

    def release_batch(self, p: Process, leases: Sequence[Lease]) -> int:
        """Release a batch (any order); returns how many were still current."""
        return sum(1 for lease in leases if self.release(p, lease))

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> List[Dict]:
        """Per-shard snapshot: placement, grant counters, per-class OpCounts."""
        out = []
        for shard in self.shards:
            with shard._meta:
                out.append({
                    "shard": shard.index,
                    "home_host": shard.home_host,
                    "keys": len(shard.keys),
                    "grants": shard.grants,
                    "rejects": shard.rejects,
                    "expirations": shard.expirations,
                    "fast_renews": shard.fast_renews,
                    "fast_releases": shard.fast_releases,
                    "repairs": shard.repairs,
                    "local": shard.stats[LOCAL].snapshot(),
                    "remote": shard.stats[REMOTE].snapshot(),
                })
        return out

    def class_totals(self) -> Dict[int, OpCounts]:
        """Aggregate per-class OpCounts across all shards."""
        totals = {LOCAL: OpCounts(), REMOTE: OpCounts()}
        for shard in self.shards:
            with shard._meta:
                for cls in (LOCAL, REMOTE):
                    totals[cls] = totals[cls] + shard.stats[cls]
        return totals
