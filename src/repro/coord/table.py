"""Sharded asymmetric lock table: the paper's per-class cost optimality,
applied to a whole keyspace instead of one record.

A single :class:`~repro.core.ALock` makes exactly one host the privileged
"local" class; everyone else pays fabric operations.  That is the right shape
for one hot record, but a control plane serving millions of keys wants the
privilege *spread out*: partition the keyspace into ``num_shards`` shards,
home shard ``s`` on host ``s % num_hosts`` (a stable hash, so placement never
depends on interpreter state), and guard each shard's lease metadata with its
own ALock.  Every host is then the zero-RDMA local class for its slice of the
keyspace, and the paper's cost claims hold *per shard*: a client transacting
on keys homed on its own host issues **zero** simulated RDMA operations, and
a remote client pays the ALock's bounded budget.

Layered on the shard locks is a **lease table** (the long-lived exclusion):

* ``try_acquire(p, key, ttl)`` grants a :class:`Lease` with a monotonically
  increasing **fencing token** per key.  The shard's ALock is held only for
  the short metadata transaction — the lease itself is what excludes other
  clients, so a crashed holder can never wedge the shard: its lease expires
  after ``ttl`` and the next grant carries a larger token, which downstream
  resources use to reject the crashed holder's stale writes.
* ``acquire_batch(p, keys, ttl)`` takes multiple leases in the **global key
  order** ``(shard_of(key), key)``.  All batched clients walk the same total
  order, so no cycle of waiters can form — deadlock freedom without a
  detector (see ``docs/lock-table.md``).

Telemetry: every table operation snapshots the calling process's
:class:`~repro.core.OpCounts` and accumulates the delta into the target
shard's per-class (LOCAL/REMOTE) totals, so benchmarks and the serving layer
can verify the zero-RDMA home path without instrumenting clients.
"""

from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import ALock, AsymmetricMemory, OpCounts, Process

LOCAL, REMOTE = 0, 1

_NO_HOLDER = -1


def stable_key_hash(key: str) -> int:
    """A process-stable 64-bit hash (Python's ``hash`` is salted per run)."""
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big"
    )


@dataclass(frozen=True)
class Lease:
    """A granted lease: the unit of long-lived exclusion.

    ``token`` is the fencing token — strictly increasing per key across
    grants, so any resource that records the largest token it has seen can
    reject writes from a holder whose lease has expired and been re-granted.
    """

    key: str
    shard: int
    holder_pid: int
    token: int
    expires_at: float
    ttl: float


class _KeyState:
    """Per-key lease registers, allocated on the shard's home node.

    All three registers are read/written only inside the shard ALock's
    critical section, so plain (asymmetry-dispatched) reads and writes
    suffice — no mixed RMW, hence no Table-1 hazard.
    """

    __slots__ = ("holder", "expires", "fence")

    def __init__(self, mem: AsymmetricMemory, node: int, name: str):
        self.holder = mem.alloc(node, f"{name}.holder", _NO_HOLDER)
        self.expires = mem.alloc(node, f"{name}.expires", 0.0)
        self.fence = mem.alloc(node, f"{name}.fence", 0)


class LockShard:
    """One shard: an ALock guarding the lease metadata of its keys."""

    def __init__(self, mem: AsymmetricMemory, index: int, home_host: int,
                 init_budget: int, name: str):
        self.index = index
        self.home_host = home_host
        self.alock = ALock(mem, home_host, init_budget, name=f"{name}.s{index}")
        self.keys: Dict[str, _KeyState] = {}
        # Meta-level accounting (not part of the simulated protocol).
        self.stats = {LOCAL: OpCounts(), REMOTE: OpCounts()}
        self.grants = 0
        self.rejects = 0
        self.expirations = 0
        self._meta = threading.Lock()


class ShardedLockTable:
    """N lock shards spread over the hosts of one asymmetric memory."""

    def __init__(
        self,
        mem: AsymmetricMemory,
        num_shards: Optional[int] = None,
        init_budget: int = 4,
        clock: Optional[Callable[[], float]] = None,
        name: str = "table",
    ):
        self.mem = mem
        self.num_hosts = mem.num_nodes
        self.num_shards = num_shards or 2 * self.num_hosts
        if self.num_shards <= 0:
            raise ValueError("num_shards must be > 0")
        self.clock = clock or time.monotonic
        self.name = name
        self.shards = [
            LockShard(mem, s, s % self.num_hosts, init_budget, name)
            for s in range(self.num_shards)
        ]

    # ---------------------------------------------------------- placement
    def shard_of(self, key: str) -> int:
        """Stable hash placement: same key → same shard, in every process."""
        return stable_key_hash(key) % self.num_shards

    def home_of(self, key: str) -> int:
        """The host that is the zero-RDMA local class for ``key``."""
        return self.shards[self.shard_of(key)].home_host

    def _key_state(self, shard: LockShard, key: str) -> _KeyState:
        st = shard.keys.get(key)
        if st is None:
            with shard._meta:
                st = shard.keys.get(key)
                if st is None:
                    st = _KeyState(
                        self.mem, shard.home_host,
                        f"{self.name}.s{shard.index}.k{stable_key_hash(key):016x}",
                    )
                    shard.keys[key] = st
        return st

    # ---------------------------------------------------------- accounting
    def _account(self, shard: LockShard, p: Process, snap: OpCounts) -> None:
        d = p.counts.delta(snap)
        cls = LOCAL if p.node == shard.home_host else REMOTE
        with shard._meta:
            shard.stats[cls] = shard.stats[cls] + d

    # --------------------------------------------------------------- leases
    def try_acquire(self, p: Process, key: str, ttl: float) -> Optional[Lease]:
        """One lease-table transaction; non-blocking.

        Grants iff the key is free or its current lease has expired; a fresh
        grant always carries a larger fencing token.  Returns ``None`` while
        a live lease exists — *including* the caller's own (non-reentrant: a
        holder extends via :meth:`renew`; silently superseding would let one
        process posing as several clients steal its own slots).
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        shard = self.shards[self.shard_of(key)]
        st = self._key_state(shard, key)
        snap = p.counts.snapshot()
        try:
            with shard.alock.guard(p):
                now = self.clock()
                holder = self.mem.auto_read(p, st.holder)
                expires = self.mem.auto_read(p, st.expires)
                expired = holder != _NO_HOLDER and now >= expires
                if holder != _NO_HOLDER and not expired:
                    with shard._meta:
                        shard.rejects += 1
                    return None
                token = self.mem.auto_read(p, st.fence) + 1
                self.mem.auto_write(p, st.fence, token)
                self.mem.auto_write(p, st.holder, p.pid)
                self.mem.auto_write(p, st.expires, now + ttl)
                with shard._meta:
                    shard.grants += 1
                    if expired:
                        shard.expirations += 1
                return Lease(key, shard.index, p.pid, token, now + ttl, ttl)
        finally:
            self._account(shard, p, snap)

    def acquire(self, p: Process, key: str, ttl: float,
                timeout: Optional[float] = None,
                poll: float = 0.0005) -> Lease:
        """Blocking acquire: retry ``try_acquire`` until granted or timeout.

        ``poll`` backs off between attempts — every retry is a full shard
        ALock transaction (remote ops for remote clients), so spinning at
        full rate would burn a core *and* inflate the REMOTE-class telemetry
        with retry traffic.
        """
        deadline = None if timeout is None else self.clock() + timeout
        while True:
            lease = self.try_acquire(p, key, ttl)
            if lease is not None:
                return lease
            if deadline is not None and self.clock() > deadline:
                raise TimeoutError(f"lease on {key!r} not granted in {timeout}s")
            time.sleep(poll)

    def renew(self, p: Process, lease: Lease, ttl: Optional[float] = None) -> Optional[Lease]:
        """Extend a still-valid lease; ``None`` if it was lost (fencing)."""
        ttl = ttl if ttl is not None else lease.ttl
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.snapshot()
        try:
            with shard.alock.guard(p):
                now = self.clock()
                if (
                    self.mem.auto_read(p, st.holder) != lease.holder_pid
                    or self.mem.auto_read(p, st.fence) != lease.token
                    or now >= self.mem.auto_read(p, st.expires)
                ):
                    return None
                self.mem.auto_write(p, st.expires, now + ttl)
                return Lease(lease.key, lease.shard, lease.holder_pid,
                             lease.token, now + ttl, ttl)
        finally:
            self._account(shard, p, snap)

    def release(self, p: Process, lease: Lease) -> bool:
        """Release iff the lease is still the current grant (token match)."""
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.snapshot()
        try:
            with shard.alock.guard(p):
                if (
                    self.mem.auto_read(p, st.holder) != lease.holder_pid
                    or self.mem.auto_read(p, st.fence) != lease.token
                ):
                    return False  # stale: expired and re-granted elsewhere
                self.mem.auto_write(p, st.holder, _NO_HOLDER)
                self.mem.auto_write(p, st.expires, 0.0)
                return True
        finally:
            self._account(shard, p, snap)

    # --------------------------------------------------------------- batches
    def batch_order(self, keys: Iterable[str]) -> List[str]:
        """The deadlock-avoidance total order: ``(shard_of(key), key)``."""
        return sorted(set(keys), key=lambda k: (self.shard_of(k), k))

    def acquire_batch(self, p: Process, keys: Sequence[str], ttl: float,
                      timeout: Optional[float] = None) -> List[Lease]:
        """Acquire every key (deduplicated) in the global key order.

        All-or-nothing: ``timeout`` bounds the *whole batch*; on expiry,
        already-granted leases are released and ``TimeoutError`` is raised.
        Because every batched client acquires in the same total order, a
        cycle of waiters cannot form.
        """
        ordered = self.batch_order(keys)
        deadline = None if timeout is None else self.clock() + timeout
        held: List[Lease] = []
        try:
            for key in ordered:
                remaining = (
                    None if deadline is None
                    else max(deadline - self.clock(), 0.0)
                )
                held.append(self.acquire(p, key, ttl, timeout=remaining))
        except TimeoutError:
            for lease in held:
                self.release(p, lease)
            raise
        return held

    def release_batch(self, p: Process, leases: Sequence[Lease]) -> int:
        """Release a batch (any order); returns how many were still current."""
        return sum(1 for lease in leases if self.release(p, lease))

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> List[Dict]:
        """Per-shard snapshot: placement, grant counters, per-class OpCounts."""
        out = []
        for shard in self.shards:
            with shard._meta:
                out.append({
                    "shard": shard.index,
                    "home_host": shard.home_host,
                    "keys": len(shard.keys),
                    "grants": shard.grants,
                    "rejects": shard.rejects,
                    "expirations": shard.expirations,
                    "local": shard.stats[LOCAL].snapshot(),
                    "remote": shard.stats[REMOTE].snapshot(),
                })
        return out

    def class_totals(self) -> Dict[int, OpCounts]:
        """Aggregate per-class OpCounts across all shards."""
        totals = {LOCAL: OpCounts(), REMOTE: OpCounts()}
        for shard in self.shards:
            with shard._meta:
                for cls in (LOCAL, REMOTE):
                    totals[cls] = totals[cls] + shard.stats[cls]
        return totals
