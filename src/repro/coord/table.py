"""Sharded asymmetric lock table: the paper's per-class cost optimality,
applied to a whole keyspace instead of one record.

A single :class:`~repro.core.ALock` makes exactly one host the privileged
"local" class; everyone else pays fabric operations.  That is the right shape
for one hot record, but a control plane serving millions of keys wants the
privilege *spread out*: partition the keyspace into ``num_shards`` shards,
home shard ``s`` on host ``s % num_hosts`` (a stable hash, so placement never
depends on interpreter state), and guard each shard's lease metadata with its
own ALock.  Every host is then the zero-RDMA local class for its slice of the
keyspace, and the paper's cost claims hold *per shard*: a client transacting
on keys homed on its own host issues **zero** simulated RDMA operations, and
a remote client pays the ALock's bounded budget.

Layered on the shard locks is a **lease table** (the long-lived exclusion):

* ``try_acquire(p, key, ttl)`` grants a :class:`Lease` with a monotonically
  increasing **fencing token** per key.  The shard's ALock is held only for
  the short metadata transaction — the lease itself is what excludes other
  clients, so a crashed holder can never wedge the shard: its lease expires
  after ``ttl`` and the next grant carries a larger token, which downstream
  resources use to reject the crashed holder's stale writes.
* ``acquire_batch(p, keys, ttl)`` takes multiple leases in the **global key
  order** ``(shard_of(key) % num_hosts, shard_of(key), key)``.  All batched
  clients walk the same total order, so no cycle of waiters can form —
  deadlock freedom without a detector (see ``docs/lock-table.md``); the
  static-home-major ordering additionally puts same-home shard groups next
  to each other, so a batch chains their WR lists into one posting per
  destination host.

**Lease modes** (see the "Lease modes" section of ``docs/lock-table.md``):
every lease is either :data:`LeaseMode.EXCLUSIVE` (one writer) or
:data:`LeaseMode.SHARED` (a cohort of readers).  The per-key expiry register
packs ``(writer_fence_token, reader_count, expires_at)`` so that a shared
grant is a *single CAS* on one word — readers never take the shard ALock at
all: zero simulated RDMA ops for a home-host reader, one rCAS per attempt
for a remote one (exactly one uncontended and under the sim engine's atomic
steps; a threaded CAS race retries, bounded by the fast-attempt cap).  Reader generations reuse the last CS-allocated token (readers
issue no fenced downstream writes), writer grants still allocate strictly
increasing tokens inside the critical section, and a queued writer **drains**
a live reader cohort through a lease-like intent barrier: new joins and
shared renewals are refused while the barrier is armed, so the cohort dries
up within one TTL and the writer's grant latency is bounded.

Hot-path optimisations (see the "Hot path" section of ``docs/lock-table.md``):

* **Renewal/release fast path** — the current holder extends or drops its
  lease with a single fencing-token-checked CAS on the expiry register,
  *without* taking the shard ALock: zero simulated RDMA ops for local
  holders, exactly one rCAS for remote holders.  The expiry register packs
  ``(fence_token, readers, expires_at)`` so the CAS validates the fence: a
  zombie holder's CAS always loses after a re-grant (the token moved on).
* **Shard-grouped batches** — ``acquire_batch`` holds each shard's ALock
  once for all of that shard's keys (O(distinct shards) critical sections
  instead of O(keys)), still walking the global order; ``release_batch``
  mirrors it, coalescing a shard group's release CASes into one doorbell
  and taking the shard ALock at most once for the group's slow-path leases.
* **Doorbell coalescing** — remote clients post the critical section's
  register reads in one :meth:`~repro.core.AsymmetricMemory.post_batch`
  doorbell and its writes in another, modelling RDMA WR posting lists.

Telemetry: every table operation snapshots the calling process's
:class:`~repro.core.OpCounts` (an O(1) tuple snapshot, accumulated in place —
no per-op dict copies) and adds the delta to the target shard's per-class
(LOCAL/REMOTE) totals — and, since the mode refactor, to the per-mode
per-class totals — so benchmarks and the serving layer can verify the
zero-RDMA home path *per mode* without instrumenting clients.
"""

from __future__ import annotations

import enum
import hashlib
import random
import threading
import time
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import (ALock, AsymmetricMemory, DeadlineExceeded,
                        InflatedKeyQueue, OpCounts, Overloaded, Process,
                        RemoteTimeout, TIMEOUT)

from .faults import FaultInjector
from .inflation import ContentionEstimator, InflationPolicy
from .overload import OverloadControl, OverloadPolicy

LOCAL, REMOTE = 0, 1

_NO_HOLDER = -1

# The expiry register packs (fence_token, reader_count, expires_at).
# expires_at <= FREE_AT means the key is not held (never granted, or
# released); a grant always writes a strictly positive expiry, so the states
# cannot be confused.
_FREE_AT = 0.0

# Bounded optimism: the shared-mode fast paths are read+CAS retry loops (the
# CAS can lose only to another *successful* shared operation, so the system
# as a whole always progresses).  Under the sim engine's atomic steps a
# retry never happens; under threads the cap converts a pathological
# contention storm into a clean reject instead of an unbounded spin.
_FAST_ATTEMPTS = 64

# Seeded exponential backoff for the blocking acquire loops: `poll` is the
# base, doubling per reject up to this many base intervals, with +-50%
# seeded jitter — the thundering-herd fix for threaded hot keys, routed
# through the injected clock/RNG so the sim stays deterministic.
_BACKOFF_CAP_POLLS = 32

# Optimistic (seqlock) read attempts before falling back to a shared lease:
# each attempt is one doorbell for a remote reader (zero for a home one),
# so the cap bounds the read's worst-case fabric cost at a handful of
# doorbells before it degrades to the still-cheap PR 4 shared join.
_OPT_ATTEMPTS = 8

# Feasibility-shed safety margin: an acquire is refused once its remaining
# deadline budget drops below this multiple of the shard's observed
# time-to-completion EWMA.  The EWMA is a *mean*; completion times are
# right-skewed (a contended word only frees on TTL expiry), so admitting
# everything above the mean still burns budget on ~half the borderline
# arrivals.  A modest margin sheds those early — a fast local refusal —
# without touching fresh, feasible work (whose remaining budget is several
# multiples of the EWMA).
_SHED_SVC_MARGIN = 1.5

# Tombstone word written (best-effort) into a deposed home's key registers
# by takeover_shard: a generation no fence ever allocates, under an expiry
# that never lapses — a zombie that still reads the old word sees "held
# forever" and can never grant from it.  The old holder register carries
# the forwarding pointer, encoded below (ordinary pids are >= 0 and the
# free sentinel is -1, so forwarded values -2, -3, ... are unambiguous).
_TOMB_TOKEN = 1 << 62
_TOMB_AT = float("inf")


def _fwd_enc(home: int) -> int:
    """Encode a forwarding pointer for a tombstoned holder register."""
    return -(home + 2)


def forwarded_home(holder: int) -> Optional[int]:
    """Decode a tombstoned holder register's forwarding pointer, or None."""
    return -holder - 2 if holder <= -2 else None


# --------------------------------------------------------- word mode encoding
# The packed word stays one register, (token, readers, expires_at); the
# inflation mode bit rides the READERS field as a two's-complement style
# encoding: readers >= 0 is the classic deflated key with that many live
# readers, readers < 0 is an INFLATED key carrying (-readers - 1) live
# readers (so -1 = inflated + zero readers).  Properties this buys:
#
# * the word stays CAS-only and exactly as wide — every existing witness
#   tuple still works, and the mode transition is ONE CAS that changes
#   neither token nor expiry (an atomic mode swing);
# * every deflated-mode fast-path witness has readers == 0 (or > 0 for
#   cohorts), so it can NEVER accidentally match an inflated word: a
#   zombie whose key inflated under it falls off the fast path and lands
#   in the fully-validated slow path, exactly like a fenced-out zombie;
# * shared reader cohorts keep working while inflated — joins/leaves
#   increment/decrement through the encoding, the writer drain barrier is
#   unchanged.
def _infl(readers: int) -> bool:
    """Is this readers-field value inflated-mode?"""
    return readers < 0


def _dec(readers: int) -> int:
    """Decoded live-reader count, either mode."""
    return -readers - 1 if readers < 0 else readers


def _enc(count: int, inflated: bool) -> int:
    """Encode a live-reader count into the given mode."""
    return -count - 1 if inflated else count


# Fencing-token block reserved by the FIRST critical-section grant on an
# inflated key (not at inflation itself — the pre-inflation holder's lease
# still witnesses ``fence == token`` and must stay releasable): the fence
# register jumps to ``token + _INFL_RESERVE`` (the epoch's CEILING) and the
# direct-handoff chain allocates word tokens UNDER it (each handoff CAS
# writes token + 1, chained through the word itself, so monotonicity needs
# no register round-trip).  Every later CS grant on the inflated key
# allocates ceiling + 1 and re-reserves.  2^20 handoffs per reservation:
# far past any queue tenure, and exhaustion just falls back to a CS grant.
_INFL_RESERVE = 1 << 20


def _trusted(etok: int, fence: int, readers: int) -> bool:
    """Mirror-trust check for the packed word against the fence register.

    Deflated: exact match (any skew means a zombie's piggybacked writes hit
    the mirror — untrusted, repaired via the CS).  Inflated: the fence
    register holds the inflation epoch's reserved ceiling and word tokens
    are allocated *under* it by the direct-handoff chain, so trusted means
    ``etok <= fence``.  A deflated word under a still-raised fence
    (etok < fence, readers >= 0) is the post-deflation state: deliberately
    untrusted, so the next CS grant repairs it with token ``ceiling + 1``
    — which is how the fence mirror re-synchronises after an epoch."""
    return etok <= fence if _infl(readers) else etok == fence


class LeaseMode(enum.IntEnum):
    """S/X lease modes.  SHARED leases form a reader cohort on one packed
    word; EXCLUSIVE leases are the original writer leases."""

    SHARED = 0
    EXCLUSIVE = 1

    @property
    def label(self) -> str:
        return "shared" if self is LeaseMode.SHARED else "exclusive"


SHARED, EXCLUSIVE = LeaseMode.SHARED, LeaseMode.EXCLUSIVE


@lru_cache(maxsize=1 << 17)
def stable_key_hash(key: str) -> int:
    """A process-stable 64-bit hash (Python's ``hash`` is salted per run).

    Cached: placement hashing of a hot key must not recompute blake2b on
    every operation (the cache is per-process and placement is stable, so
    memoisation can never change an answer).
    """
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big"
    )


@dataclass(frozen=True)
class Lease:
    """A granted lease: the unit of long-lived exclusion (or sharing).

    ``token`` is the fencing token — strictly increasing per key across
    *writer* grants, so any resource that records the largest token it has
    seen can reject writes from a holder whose lease has expired and been
    re-granted.  A SHARED lease carries its reader generation's token (the
    last token the critical section allocated): readers issue no fenced
    downstream writes, and the next writer's token is strictly larger than
    every reader generation it displaces.

    ``expires_at`` doubles as the fast-path CAS witness for EXCLUSIVE
    leases: ``renew``/``release`` compare-and-swap the expiry register
    against ``(token, 0, expires_at)``, so hold on to the *latest* lease
    returned by acquire/renew (the :class:`~repro.coord.CoordinationService`
    lease cache does this for you, keyed per mode).  For SHARED leases it is
    the holder's own validity horizon — the packed word tracks the cohort's
    maximum.
    """

    key: str
    shard: int
    holder_pid: int
    token: int
    expires_at: float
    ttl: float
    mode: LeaseMode = LeaseMode.EXCLUSIVE
    # The key's word was in inflated (queued) mode when this lease was
    # granted/renewed: the fast-path witnesses must encode the mode bit
    # (readers == -1, not 0) or they would never match the word again.
    inflated: bool = False

    def witness(self) -> tuple:
        """The fast-path CAS witness for an EXCLUSIVE lease."""
        return (self.token, _enc(0, self.inflated), self.expires_at)


class _KeyState:
    """Per-key lease registers, allocated on the shard's home node.

    ``holder`` and ``fence`` are read/written **only** inside the shard
    ALock's critical section; ``fence`` is the authoritative token allocator,
    which is why writer grant tokens are strictly monotonic unconditionally.

    ``expires`` packs ``(fence_token, reader_count, expires_at)`` and is the
    one register holders may CAS lock-free: the renewal/release fast path,
    shared joins/leaves, and downgrades all operate on this single word.
    Because remote RMW is not atomic against the critical section's writes
    (Table 1), a **zombie's** in-flight rCAS write phase can, in a vanishing
    window, overwrite a concurrent re-grant's write with its stale tuple.
    The CS-only ``fence`` makes that clobber *detectable* (``expires`` token
    ≠ fence) and *unable to affect token allocation*; grant decisions treat
    a clobbered mirror as expired and repair it (``shard.repairs``
    telemetry).  This is the standard lease-system posture: expiry-time
    races cannot be airtight under asynchrony, fencing tokens are what make
    them harmless downstream — and the tokens themselves never regress.

    ``intent`` is the writer drain barrier: a virtual-time deadline written
    only inside the critical section (by a writer blocked on a live reader
    cohort).  The shared fast paths read it and refuse joins/renewals while
    ``now < intent``, so the cohort drains within one TTL; any writer grant
    clears it.  A stale barrier (the writer timed out or was beaten to the
    grant) simply lapses — no cleanup protocol, same posture as the leases
    themselves.

    ``infl`` / ``infl_epoch`` are host-side inflation metadata (like shard
    placement and the client slot ledger — never part of the simulated
    protocol state): the live :class:`~repro.core.InflatedKeyQueue` for an
    inflated key, or ``None``.  The word's mode bit is authoritative; the
    queue object is the advisory FIFO hung off it, discarded wholesale on
    deflation (the epoch counter keeps discarded-queue register names from
    aliasing a later inflation's).
    """

    __slots__ = ("holder", "expires", "fence", "intent", "payload", "infl",
                 "infl_epoch", "infl_ceiling")

    def __init__(self, mem: AsymmetricMemory, node: int, name: str):
        self.holder = mem.alloc(node, f"{name}.holder", _NO_HOLDER)
        self.expires = mem.alloc(node, f"{name}.expires", (0, 0, _FREE_AT))
        self.fence = mem.alloc(node, f"{name}.fence", 0)
        self.intent = mem.alloc(node, f"{name}.intent", _FREE_AT)
        # Optimistic-read payload: ``(publish_token, value)``, written only
        # by ``publish`` (a fenced read+CAS by the live exclusive holder).
        # The token records WHICH writer generation published the value, so
        # a seqlock reader can cross-check the payload against the packed
        # word (payload token > word token ⇒ the word read was stale or
        # clobbered ⇒ retry).  An advisory cache, not protocol state: a
        # takeover re-seeds it empty on the new home (the ledger records
        # leases, not payloads) — readers then see "never published", which
        # is honest, never stale.
        self.payload = mem.alloc(node, f"{name}.payload", (0, None))
        self.infl: Optional[InflatedKeyQueue] = None
        self.infl_epoch = 0
        # Largest word token the current inflation epoch may allocate via
        # direct handoff (== the value the fence register was raised to).
        # Home-shard metadata, maintained under the shard CS.
        self.infl_ceiling = 0


class LockShard:
    """One shard: an ALock guarding the lease metadata of its keys."""

    def __init__(self, mem: AsymmetricMemory, index: int, home_host: int,
                 init_budget: int, name: str):
        self.index = index
        self.home_host = home_host
        self.init_budget = init_budget
        self.alock = ALock(mem, home_host, init_budget, name=f"{name}.s{index}")
        self.keys: Dict[str, _KeyState] = {}
        # Takeover epoch (host-side mirror of the epoch register).  The
        # epoch and forwarding registers live on the shard's rank-order
        # first successor, NOT the home: they must stay reachable after the
        # home dies (the successor bumps the epoch with a LOCAL CAS; the
        # zombie ex-home pays remote and loses the race detectably).  If
        # home and witness die together the shard is unavailable until one
        # recovers — the documented single-failure posture.
        self.epoch = 0
        witness = (home_host + 1) % mem.num_nodes
        self.epoch_reg = mem.alloc(witness, f"{name}.s{index}.epoch", 0)
        self.fwd_reg = mem.alloc(witness, f"{name}.s{index}.fwd", home_host)
        # Meta-level accounting (not part of the simulated protocol).
        self.stats = {LOCAL: OpCounts(), REMOTE: OpCounts()}
        self.mode_stats = {(m, c): OpCounts()
                           for m in LeaseMode for c in (LOCAL, REMOTE)}
        self.grants = 0
        self.rejects = 0
        self.grants_by_mode = {m: 0 for m in LeaseMode}
        self.rejects_by_mode = {m: 0 for m in LeaseMode}
        self.expirations = 0
        self.fast_renews = 0
        self.fast_releases = 0
        self.shared_joins = 0        # fast-path shared grants (no ALock)
        self.shared_renews = 0
        self.shared_releases = 0
        self.shared_remote_grants = 0   # shared grants paid for over the fabric
        self.shared_acquire_rcas = 0    # rCAS posted by remote shared acquires
        self.upgrades = 0
        self.downgrades = 0
        self.intent_blocks = 0       # shared ops refused by a writer barrier
        self.repairs = 0  # clobbered expiry mirrors repaired by a grant
        # Crash-recovery counters (the ledger/reclaim stack).
        self.reclaims = 0            # successful reclaims, any path
        self.reclaim_fast = 0        # exclusive witness-CAS reclaims
        self.reclaim_slow = 0        # exclusive word-probe reclaims
        self.reclaim_shared = 0      # shared cohort-slot re-adoptions
        self.reclaim_rejects = 0     # reclaim refused (expired/fenced out)
        self.orphan_probes = 0       # dangling-intent probes run
        self.orphan_adopts = 0       # probes that adopted a lost grant
        self.reconstructions = 0     # keys audited by reconstruct_shard
        self.reconstruct_resets = 0  # keys whose registers were re-seeded
        # Self-healing failover counters (PR 8).
        self.takeovers = 0           # epoch-fenced re-homings completed
        self.takeover_refusals = 0   # refused by the partition guard
        self.takeover_aborts = 0     # lost the epoch CAS / dead host revived
        self.epoch_aborts = 0        # grants discarded by the epoch fence
        self.rehomed_keys = 0        # ledgered keys carried to the new home
        # Contention-adaptive inflation counters (PR 7).
        self.inflations = 0          # words swung into queued (MCS) mode
        self.deflations = 0          # words swung back, orderly or not
        self.queue_enqueues = 0      # split-phase MCS enqueues
        self.queue_grants = 0        # grants issued via the inflated path
        self.queue_handoffs = 0      # inflated releases that passed the queue
        self.queue_bypasses = 0      # stale-queue fallbacks to the word
        # Per-key blocked-attempt tally (satellite: hot-key report).  Guarded
        # by _meta like every other meta counter; keys only ever accumulate —
        # the table's hot_keys() merges and ranks across shards.
        self.key_retries: Dict[str, int] = {}
        # Per-key fabric-trouble tallies: op timeouts and fabric-level retry
        # rounds charged while transacting on the key (the OpCounts deltas
        # the per-class stats already fold in, re-keyed so the hot-key
        # report can show WHERE the fabric pain lands).
        self.key_timeouts: Dict[str, int] = {}
        self.key_fab_retries: Dict[str, int] = {}
        # Overload-protection counters (PR 9).
        self.sheds = 0               # acquires refused as deadline-infeasible
        self.hedges = 0              # read-only probes that posted a hedge
        self.deadline_exceeded = 0   # ops refused/aborted on caller deadline
        # Optimistic-read (seqlock) counters (PR 10).
        self.opt_reads = 0           # untorn snapshots returned lease-free
        self.opt_read_retries = 0    # unstable/contended attempts retried
        self.opt_read_fallbacks = 0  # reads degraded to a shared lease
        self.opt_read_fwd = 0        # tombstoned words chased to a new home
        self.publishes = 0           # fenced payload publishes that landed
        # EWMA of observed blocking-acquire time-to-completion (grant or
        # burned deadline), the shedding feasibility signal (updated
        # outside _meta: float store is atomic enough for a heuristic;
        # sim steps are atomic anyway).
        self.svc_time = 0.0
        self._meta = threading.Lock()


class ShardedLockTable:
    """N lock shards spread over the hosts of one asymmetric memory."""

    def __init__(
        self,
        mem: AsymmetricMemory,
        num_shards: Optional[int] = None,
        init_budget: int = 4,
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
        name: str = "table",
        fault: Optional[FaultInjector] = None,
        inflation: Optional[InflationPolicy] = None,
        seed: int = 0,
        overload: Optional[OverloadPolicy] = None,
    ):
        self.mem = mem
        self.num_hosts = mem.num_nodes
        self.num_shards = num_shards or 2 * self.num_hosts
        if self.num_shards <= 0:
            raise ValueError("num_shards must be > 0")
        # clock and sleep travel as a pair: the blocking paths compute their
        # deadline on `clock` and back off on `sleep`, so injecting one
        # without the other (the old wall-clock time.sleep next to a fake
        # clock) would stall a poll loop forever — or time out instantly —
        # whenever the two disagree.  The sim engine injects a virtual clock
        # plus a charging sleep; threaded callers get the time module's pair.
        self.clock = clock or time.monotonic
        self.sleep = sleep or time.sleep
        self.name = name
        self.fault = fault
        self.shards = [
            LockShard(mem, s, s % self.num_hosts, init_budget, name)
            for s in range(self.num_shards)
        ]
        # Client-side cohort-slot ledger: pid -> {key: [count, token,
        # horizon]}.  The packed word's reader count is anonymous — a
        # decrement cannot tell WHOSE slot it takes — so the client library
        # must never post one it does not own: a double release (or a renew
        # / release after an upgrade consumed the slot) would otherwise
        # free another live reader's slot and let a writer in beside them.
        # Within one process, slots of the same (key, generation) are
        # fungible: a stale handle releases one of the CALLER'S own slots
        # (self-inflicted, contained) — it can never free another client's.
        # A pid is single-threaded by the spawn contract, so each inner
        # per-pid dict is accessed (and swept, amortised) lock-free by its
        # owner; the guard covers only outer-dict insertion.  Entries die
        # with their horizon, like the service lease cache.
        self._slots: Dict[int, Dict[str, List]] = {}
        self._slots_guard = threading.Lock()
        # Contention-adaptive inflation (None = feature off: one attribute
        # check per exclusive acquire, nothing else — zero cost when idle).
        self.inflation = inflation
        self._estimator = (ContentionEstimator(inflation)
                           if inflation is not None else None)
        self._init_budget = init_budget
        # Inflate/deflate event log: [t, action, key, token, reason] rows,
        # appended in decision order.  Decisions are pure functions of the
        # seeded event sequence + virtual clock, so two same-seed sim runs
        # produce byte-identical logs (a CI determinism gate diffs them).
        self._infl_events: List[List] = []
        self._infl_guard = threading.Lock()
        # Blocking-acquire backoff RNG: seeded so the sim's sleep schedule
        # (hence every downstream decision) is a function of the seed.
        self._rng = random.Random(seed)
        # Overload protection (None = feature off: every gate below is one
        # attribute check, nothing else — the legacy cost shape is intact).
        self.overload = (OverloadControl(overload, seed)
                         if overload is not None else None)
        # Client-side queue-wait ledger, the inflated-mode sibling of
        # ``_slots``: pid -> {key: [queue, last_progress_at, holding]}.
        # Same access contract (a pid is single-threaded, the guard covers
        # only outer-dict insertion).  An entry whose queue is no longer the
        # key's installed one belongs to a discarded epoch and is dropped.
        self._waits: Dict[int, Dict[str, List]] = {}
        self._waits_guard = threading.Lock()
        # Registered async pipelines (PR 10): pid -> AsyncClient.  A hedged
        # probe by a process that drives a pipeline rides that pipeline's
        # next flush for the probed host instead of posting its own
        # doorbell (see _probe/_hedged_read).  Host-side metadata only.
        self._pipelines: Dict[int, object] = {}

    _SLOTS_SWEEP = 1024

    def _pid_slots(self, p: Process) -> Dict[str, List]:
        slots = self._slots.get(p.pid)
        if slots is None:
            with self._slots_guard:
                slots = self._slots.setdefault(p.pid, {})
        return slots

    def _pid_waits(self, p: Process) -> Dict[str, List]:
        waits = self._waits.get(p.pid)
        if waits is None:
            with self._waits_guard:
                waits = self._waits.setdefault(p.pid, {})
        return waits

    def _log_infl_event(self, now: float, action: str, key: str,
                        token: int, reason: str) -> None:
        with self._infl_guard:
            self._infl_events.append(
                [round(now, 9), action, key, token, reason])

    def _slot_join(self, p: Process, key: str, token: int,
                   horizon: float) -> None:
        """Record one cohort slot owned by ``p`` on ``key``."""
        slots = self._pid_slots(p)
        if len(slots) >= self._SLOTS_SWEEP:
            now = self.clock()
            for k in [k for k, e in slots.items()
                      if e[0] <= 0 or now >= e[2]]:
                del slots[k]
        entry = slots.get(key)
        if (entry is not None and entry[1] == token
                and self.clock() < entry[2]):
            entry[0] += 1
            entry[2] = max(entry[2], horizon)
        else:
            slots[key] = [1, token, horizon]

    def _slot_count(self, p: Process, key: str, token: int) -> int:
        """How many slots of ``key``'s generation ``token`` does ``p`` own?"""
        entry = self._pid_slots(p).get(key)
        return entry[0] if entry is not None and entry[1] == token else 0

    def _slot_owned(self, p: Process, key: str, token: int) -> bool:
        return self._slot_count(p, key, token) > 0

    def _slot_extend(self, p: Process, key: str, token: int,
                     horizon: float) -> None:
        entry = self._pid_slots(p).get(key)
        if entry is not None and entry[1] == token:
            entry[2] = max(entry[2], horizon)

    def _slot_consume(self, p: Process, key: str, token: int) -> None:
        entry = self._pid_slots(p).get(key)
        if entry is not None and entry[1] == token and entry[0] > 0:
            entry[0] -= 1

    # ---------------------------------------------------------- placement
    def shard_of(self, key: str) -> int:
        """Stable hash placement: same key → same shard, in every process."""
        return stable_key_hash(key) % self.num_shards

    def home_of(self, key: str) -> int:
        """The host that is the zero-RDMA local class for ``key``."""
        return self.shards[self.shard_of(key)].home_host

    def _key_state(self, shard: LockShard, key: str) -> _KeyState:
        st = shard.keys.get(key)
        if st is None:
            with shard._meta:
                st = shard.keys.get(key)
                if st is None:
                    st = _KeyState(
                        self.mem, shard.home_host,
                        self._key_state_name(shard, key),
                    )
                    shard.keys[key] = st
        return st

    def _key_state_name(self, shard: LockShard, key: str) -> str:
        # Register names are globally unique (mem.alloc raises on reuse), so
        # post-takeover allocations carry the shard epoch: the dead home's
        # registers keep their epoch-0 names, the rebuilt ones never alias.
        suffix = f".e{shard.epoch}" if shard.epoch else ""
        return (f"{self.name}.s{shard.index}"
                f".k{stable_key_hash(key):016x}{suffix}")

    # ------------------------------------------------------ fault injection
    def _crash_point(self, label: str, p: Process) -> None:
        """A labeled crash window (see ``repro.coord.faults``).  Every call
        site sits OUTSIDE the shard ALock's critical section: a holder may
        die at any of them and the shard stays serviceable — leases expire
        (or are reclaimed), the CS is never wedged."""
        if self.fault is not None:
            self.fault.crash_point(label, p.pid)

    # ------------------------------------------------- overload primitives
    def _deadline_gate(self, op: str, key: str, shard: LockShard,
                       deadline: Optional[float]) -> None:
        """Fail fast — zero fabric ops — when the caller's budget is gone.

        Every public op takes an optional absolute ``deadline``; an op
        entered past it refuses with the typed :class:`~repro.core.
        DeadlineExceeded` instead of posting doomed work at a (possibly
        congested) home host.
        """
        if deadline is not None and self.clock() >= deadline:
            with shard._meta:
                shard.deadline_exceeded += 1
            raise DeadlineExceeded(f"{op} of {key!r}: deadline passed")

    def _probe(self, p: Process, reg,
               shard: Optional[LockShard] = None):
        """A read-only liveness probe, hedged under overload control.

        Without a policy (or for a local register) this is exactly
        ``mem.probe``.  With one, the observed latency feeds the
        destination's p99 tracker, and a probe that timed out after the
        tracked threshold may be re-posted ONCE — first response wins —
        provided the destination's retry budget admits the hedge (hedges
        are speculative retry traffic and are capped by the same bucket).
        """
        ctl = self.overload
        host = reg.node
        if ctl is None or p.node == host:
            return self.mem.probe(p, reg)
        t0 = self.clock()
        out = self.mem.probe(p, reg)
        dt = self.clock() - t0
        ctl.observe_latency(host, dt)
        if (out is TIMEOUT and dt >= ctl.hedge_threshold(host)
                and ctl.allow_hedge(host)):
            # The hedge itself is admitted by the same retry budget as
            # before; only its TRANSPORT changes when the caller drives an
            # async pipeline — the re-post then rides the pipeline's flush
            # for this host (sharing a doorbell with any queued work)
            # instead of posting its own.  Idempotent read, so riding a
            # mixed WR list is safe.
            pl = self._pipelines.get(p.pid)
            if pl is not None:
                try:
                    out = pl.ride_read(reg)
                except RemoteTimeout:
                    out = TIMEOUT
            else:
                out = self.mem.probe(p, reg)
            ctl.observe_latency(host, self.clock() - t0)
            if shard is not None:
                with shard._meta:
                    shard.hedges += 1
        return out

    def _hedged_read(self, p: Process, reg,
                     shard: Optional[LockShard] = None):
        """``auto_read`` whose terminal RemoteTimeout may hedge one re-post.

        The reclaim word-probe rides this: a restarted client racing its
        TTL must not die on one exhausted gate when the budget still admits
        a speculative second posting.
        """
        ctl = self.overload
        host = reg.node
        if ctl is None or p.node == host:
            return self.mem.auto_read(p, reg)
        t0 = self.clock()
        try:
            val = self.mem.auto_read(p, reg)
        except RemoteTimeout:
            ctl.observe_latency(host, self.clock() - t0)
            if not ctl.allow_hedge(host):
                raise
            if shard is not None:
                with shard._meta:
                    shard.hedges += 1
            # Same budget, cheaper transport: a pipeline-driving caller's
            # hedge rides the pipeline flush for this host (idempotent
            # read in a shared WR list) instead of a dedicated doorbell.
            pl = self._pipelines.get(p.pid)
            val = (pl.ride_read(reg) if pl is not None
                   else self.mem.auto_read(p, reg))
        ctl.observe_latency(host, self.clock() - t0)
        return val

    # ---------------------------------------------------------- accounting
    def _account(self, shard: LockShard, p: Process, snap: tuple,
                 mode: LeaseMode) -> None:
        cls = LOCAL if p.node == shard.home_host else REMOTE
        with shard._meta:
            shard.stats[cls].add_since(p.counts, snap)
            shard.mode_stats[(mode, cls)].add_since(p.counts, snap)

    # --------------------------------------------------- batched register IO
    def _read_pairs(self, p: Process, shard: LockShard,
                    states: Sequence[_KeyState]) -> List[Tuple[tuple, int]]:
        """Read each key's (expires, fence) — one doorbell for remote clients."""
        if p.node == shard.home_host:
            return [
                (self.mem.read(p, st.expires), self.mem.read(p, st.fence))
                for st in states
            ]
        flat = self.mem.post_batch(
            p,
            [wr for st in states
             for wr in (("read", st.expires), ("read", st.fence))],
        )
        return [(flat[2 * i], flat[2 * i + 1]) for i in range(len(states))]

    def _read_key_state(self, p: Process, shard: LockShard,
                        st: _KeyState) -> Tuple[int, tuple, int, float]:
        """The slow paths' validation read set (holder, expires, fence,
        intent) — one doorbell for remote clients."""
        if p.node == shard.home_host:
            return (self.mem.read(p, st.holder),
                    self.mem.read(p, st.expires),
                    self.mem.read(p, st.fence),
                    self.mem.read(p, st.intent))
        holder, packed, fence, barrier = self.mem.post_batch(p, [
            ("read", st.holder), ("read", st.expires),
            ("read", st.fence), ("read", st.intent),
        ])
        return holder, packed, fence, barrier

    def _shared_read(self, p: Process, shard: LockShard,
                     st: _KeyState) -> Tuple[tuple, int, float]:
        """The shared fast path's read set (expires, fence, intent) — one
        doorbell for remote clients, three machine reads for local ones."""
        if p.node == shard.home_host:
            return (self.mem.read(p, st.expires),
                    self.mem.read(p, st.fence),
                    self.mem.read(p, st.intent))
        packed, fence, barrier = self.mem.post_batch(p, [
            ("read", st.expires), ("read", st.fence), ("read", st.intent),
        ])
        return packed, fence, barrier

    # ------------------------------------------------------- shared fast path
    def _shared_acquire(self, p: Process, shard: LockShard, key: str,
                        ttl: float) -> Optional[Lease]:
        """Grant a SHARED lease with a single CAS on the packed word.

        Joinable states: free, expired (any mode), or a live reader cohort.
        A live writer blocks; an armed writer-intent barrier blocks (drain
        priority); a clobbered mirror (word token ≠ fence) is repaired via
        the critical section like any grant over untrusted state.  The CAS
        either joins the live cohort (count+1, expiry extended to cover this
        reader) or opens a fresh generation (count=1) reusing the last
        CS-allocated token — token allocation stays CS-only, so writer
        tokens remain strictly monotonic and are always strictly larger
        than any reader generation they displace.
        """
        st = self._key_state(shard, key)
        snap = p.counts.as_tuple()
        local = p.node == shard.home_host
        lease: Optional[Lease] = None
        intent_block = False
        repair = False
        expired_over = False
        rcas_posted = 0
        try:
            for _ in range(_FAST_ATTEMPTS):
                now = self.clock()
                packed, fence, barrier = self._shared_read(p, shard, st)
                etok, readers, eexp = packed
                if now < barrier:
                    intent_block = True  # a writer is draining this key
                    break
                if not _trusted(etok, fence, readers):
                    repair = True  # untrusted mirror: go repair via the CS
                    break
                dec, infl = _dec(readers), _infl(readers)
                free = eexp <= _FREE_AT
                live = (not free) and now < eexp
                if live and dec == 0:
                    break  # a live writer holds the key
                if live:  # join the live reader cohort (either mode)
                    new = (etok, _enc(dec + 1, infl), max(eexp, now + ttl))
                else:     # open a fresh generation over free/expired state
                    new = (etok, _enc(1, infl), now + ttl)
                observed = self.mem.auto_cas(p, st.expires, packed, new)
                if not local:
                    rcas_posted += 1
                if observed == packed:
                    lease = Lease(key, shard.index, p.pid, etok, now + ttl,
                                  ttl, LeaseMode.SHARED, infl)
                    expired_over = (not free) and not live
                    break
                self.mem.yield_point()  # lost to another shared CAS: retry
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if repair:
            return self._shared_repair_grant(p, shard, key, st, ttl,
                                             rcas_posted)
        if lease is not None:
            self._slot_join(p, key, lease.token, lease.expires_at)
        with shard._meta:
            shard.shared_acquire_rcas += rcas_posted
            if lease is not None:
                shard.grants += 1
                shard.grants_by_mode[LeaseMode.SHARED] += 1
                shard.shared_joins += 1
                if not local:
                    shard.shared_remote_grants += 1
                if expired_over:
                    shard.expirations += 1
            else:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.SHARED] += 1
                if intent_block:
                    shard.intent_blocks += 1
        return lease

    def _shared_repair_grant(self, p: Process, shard: LockShard, key: str,
                             st: _KeyState, ttl: float,
                             rcas_posted: int) -> Optional[Lease]:
        """A shared grant over a clobbered mirror: the one shared-acquire
        case that must run under the shard ALock (the mirror cannot be
        trusted, so the CS re-validates and re-seeds it — allocating a fresh
        token, exactly like an exclusive grant over untrusted state)."""
        snap = p.counts.as_tuple()
        lease: Optional[Lease] = None
        repaired = False
        blocked_by_intent = False
        try:
            now = self.clock()
            alock = shard.alock  # pin: a takeover swaps shard.alock mid-CS
            alock.lock(p)
            writes: List[tuple] = []
            try:
                holder, packed, fence, barrier = \
                    self._read_key_state(p, shard, st)
                etok, readers, eexp = packed
                if now < barrier:
                    blocked_by_intent = True
                else:
                    free = eexp <= _FREE_AT
                    clobbered = not _trusted(etok, fence, readers)
                    if free or clobbered or now >= eexp:
                        token = fence + 1
                        # CAS, not write: a CS-free join can land between
                        # the read above and this commit; the CAS loses
                        # cleanly and the caller's retry re-reads.
                        if self.mem.auto_cas(p, st.expires, packed,
                                             (token, 1, now + ttl)) == packed:
                            lease = Lease(key, shard.index, p.pid, token,
                                          now + ttl, ttl, LeaseMode.SHARED)
                            writes = [
                                ("write", st.fence, token),
                                ("write", st.holder, _NO_HOLDER),
                                ("write", st.intent, _FREE_AT),
                            ]
                            repaired = clobbered
                            # A repair grant re-seeds the word DEFLATED
                            # (the state was untrusted — disorderly events
                            # always reset queue state rather than trust it).
                            if st.infl is not None:
                                st.infl = None
                                self._estimator.mark_deflated(key, now)
                                self._log_infl_event(now, "deflate", key,
                                                     token, "repair")
                                with shard._meta:
                                    shard.deflations += 1
                    # else: someone re-granted cleanly while we queued for
                    # the CS — report a reject; the caller's retry will join.
            finally:
                alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if lease is not None:
            self._slot_join(p, key, lease.token, lease.expires_at)
        with shard._meta:
            shard.shared_acquire_rcas += rcas_posted
            if lease is not None:
                shard.grants += 1
                shard.grants_by_mode[LeaseMode.SHARED] += 1
                if p.node != shard.home_host:
                    shard.shared_remote_grants += 1
                if repaired:
                    shard.repairs += 1
            else:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.SHARED] += 1
                if blocked_by_intent:
                    shard.intent_blocks += 1
        return lease

    # --------------------------------------------------------------- leases
    def _acquire_group(self, p: Process, shard: LockShard,
                       keys: Sequence[str], ttl: float,
                       mode: LeaseMode = LeaseMode.EXCLUSIVE,
                       ) -> Tuple[List[Lease], bool]:
        """Grant a prefix of ``keys`` (one shard, global order).

        EXCLUSIVE mode runs the original transaction in **one** ALock
        critical section; SHARED mode joins each key's reader cohort with
        the CS-free single-CAS fast path (shared grants never conflict with
        each other, so there is no critical section to batch).

        Returns ``(granted, blocked)``: the leases granted, and whether the
        next key was held by a live lease (granting stops there — taking
        later keys while a smaller one is still wanted would break the
        deadlock-avoidance total order).  Never blocks inside the critical
        section.
        """
        if mode == LeaseMode.SHARED:
            granted: List[Lease] = []
            for key in keys:
                lease = self._shared_acquire(p, shard, key, ttl)
                if lease is None:
                    return granted, True
                granted.append(lease)
            return granted, False

        states = [self._key_state(shard, k) for k in keys]
        snap = p.counts.as_tuple()
        local = p.node == shard.home_host
        granted = []
        writes: List[tuple] = []
        blocked = False
        blocked_key: Optional[str] = None
        inflated_key: Optional[Tuple[str, int]] = None
        armed_drain = False
        expirations = 0
        repairs = 0
        # Sample the clock BEFORE acquiring: every register read then happens
        # at-or-after ``now``, so an "expired" verdict (eexp <= now <= read
        # time) can only be beaten by a renewal whose local-clock check
        # predates ``now`` but whose CAS lands after our read — i.e. exactly
        # the documented zombie window.  Sampling after the lock would let a
        # *healthy* pre-expiry renewal race the piggybacked (pre-CS) reads
        # and be silently re-granted over.
        now = self.clock()
        alock = shard.alock  # pin: a takeover swaps shard.alock mid-CS
        try:
            if local:
                alock.lock(p)
                flat = None
            else:
                # Chain the lease-register reads into the Peterson-engagement
                # doorbell; valid on uncontended fast entry, else re-read.
                flat = alock.lock(p, piggyback_reads=[
                    r for st in states for r in (st.expires, st.fence)
                ])
            try:
                if flat is None:
                    vals = self._read_pairs(p, shard, states)
                else:
                    vals = [(flat[2 * i], flat[2 * i + 1])
                            for i in range(len(states))]
                # Verdict pass: the grantable prefix in global order.
                plan = []  # (key, st, packed, new token, clobbered, free, enc0)
                for key, st, ((etok, readers, eexp), fence) in zip(
                        keys, states, vals):
                    free = eexp <= _FREE_AT
                    # Untrusted mirror: a zombie CAS hit it, or the word is
                    # freshly deflated under a still-raised epoch ceiling.
                    clobbered = not _trusted(etok, fence, readers)
                    if not free and not clobbered and now < eexp:
                        blocked = True
                        blocked_key = key
                        if _dec(readers) > 0:
                            # A live reader cohort: arm the drain barrier so
                            # no new reader joins (and no shared renewal
                            # extends the cohort) past its current horizon —
                            # the writer's wait is bounded by one TTL.
                            writes.append(("write", st.intent, eexp))
                            armed_drain = True
                        elif (self._estimator is not None
                                and not _infl(readers)):
                            # Blocked on a live writer-held deflated word:
                            # the contention signal the estimator feeds on.
                            self._estimator.note(key, now)
                            if (st.infl is None
                                    and self._estimator.should_inflate(
                                        key, now)):
                                # Install the queue BEFORE the mode CAS: a
                                # concurrent step must never observe an
                                # inflated word with no queue behind it.
                                st.infl_epoch += 1
                                st.infl = InflatedKeyQueue(
                                    self.mem, shard.home_host,
                                    self._init_budget,
                                    f"{self.name}.s{shard.index}"
                                    f".k{stable_key_hash(key):016x}"
                                    f".iq{st.infl_epoch}")
                                # One CAS swings the mode: token and expiry
                                # untouched, readers 0 -> -1 (inflated, no
                                # readers).  Losing (to the holder's renew /
                                # release CAS) reverts cleanly — the next
                                # blocked attempt re-decides.
                                if self.mem.auto_cas(
                                    p, st.expires, (etok, readers, eexp),
                                    (etok, _enc(0, True), eexp),
                                ) == (etok, readers, eexp):
                                    self._estimator.mark_inflated(key, now)
                                    inflated_key = (key, etok)
                                    # No token-block reservation yet: the
                                    # pre-inflation holder's lease still
                                    # witnesses ``fence == token``, and
                                    # raising the fence here would strand
                                    # its release until TTL expiry.  The
                                    # ceiling stays at the current token
                                    # (zero direct-handoff headroom) until
                                    # the FIRST critical-section grant on
                                    # the inflated key reserves the block.
                                    st.infl_ceiling = etok
                                else:
                                    st.infl = None
                        break
                    if st.infl is not None and not st.infl.empty(p):
                        # FIFO discipline: an inflated key's grant order is
                        # owned by its queue — a CS transaction must not
                        # jump live waiters (the inflated acquire path is
                        # the only granting entry while the queue is
                        # populated).
                        blocked = True
                        blocked_key = key
                        break
                    token = fence + 1  # CS-only allocator: never regresses
                    plan.append((key, st, (etok, readers, eexp), token,
                                 clobbered, free,
                                 _enc(0, st.infl is not None)))
                # Commit pass: every packed-word mutation is a CAS against
                # the value this transaction read — the CS excludes other
                # critical sections but NOT the CS-free shared joins, so a
                # plain grant write could stomp a reader that joined the
                # free word in the decision window.  The CAS loses instead
                # (and the key reports blocked).  Remote clients post the
                # whole group's grant CASes in one doorbell.
                if plan:
                    if local:
                        won = [
                            self.mem.cas(p, st.expires, packed,
                                         (token, enc0, now + ttl)) == packed
                            for (_k, st, packed, token, _c, _f, enc0) in plan
                        ]
                    else:
                        obs = self.mem.post_batch(p, [
                            ("cas", st.expires, packed,
                             (token, enc0, now + ttl))
                            for (_k, st, packed, token, _c, _f, enc0) in plan
                        ])
                        won = [o == packed
                               for o, (_k, _s, packed, *_r) in zip(obs, plan)]
                    cut = won.index(False) if False in won else len(plan)
                    # Global-order discipline: nothing may be held past the
                    # first loser.  The batch's CASes already executed, so
                    # un-grant any stray winners after the cut (we hold the
                    # only witness to the value we just wrote; only the
                    # vanishing remote-window can beat the rollback, and a
                    # clobbered word is repaired by the next grant).
                    rollback = [
                        ("cas", st.expires, (token, enc0, now + ttl), packed)
                        for i, (_k, st, packed, token, _c, _f, enc0)
                        in enumerate(plan)
                        if i > cut and won[i]
                    ]
                    if rollback:
                        if local:
                            for _op, reg, exp_v, new_v in rollback:
                                self.mem.cas(p, reg, exp_v, new_v)
                        else:
                            self.mem.post_batch(p, rollback)
                    if cut < len(plan):
                        blocked = True
                        blocked_key = plan[cut][0]
                    for (key, st, packed, token, clobbered, free,
                         enc0) in plan[:cut]:
                        if clobbered:
                            repairs += 1  # untrusted mirror: repaired
                        elif not free:
                            expirations += 1  # grant over an expired lease
                        granted.append(
                            Lease(key, shard.index, p.pid, token, now + ttl,
                                  ttl, LeaseMode.EXCLUSIVE, _infl(enc0))
                        )
                        fence_val = token
                        if _infl(enc0):
                            # A CS grant on a still-inflated key re-reserves
                            # the direct-handoff token block above it.
                            st.infl_ceiling = fence_val = token + _INFL_RESERVE
                        writes += [
                            ("write", st.fence, fence_val),
                            ("write", st.holder, p.pid),
                            ("write", st.intent, _FREE_AT),  # barrier served
                        ]
            finally:
                # The grant writes ride the unlock: applied in place by a
                # local releaser, chained into the tail-drain doorbell by a
                # remote one — still inside the critical section either way.
                alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        with shard._meta:
            shard.grants += len(granted)
            shard.grants_by_mode[LeaseMode.EXCLUSIVE] += len(granted)
            shard.expirations += expirations
            shard.repairs += repairs
            if inflated_key is not None:
                shard.inflations += 1
            if blocked:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.EXCLUSIVE] += 1
                if blocked_key is not None:
                    shard.key_retries[blocked_key] = \
                        shard.key_retries.get(blocked_key, 0) + 1
        if inflated_key is not None:
            self._log_infl_event(now, "inflate", inflated_key[0],
                                 inflated_key[1], "hot")
            # The inflater is a (blocked) waiter, not a holder: its death
            # here leaves a freshly inflated key whose queue it never
            # joined — the key serves normally through the inflated path
            # and deflates when cool.
            self._crash_point("inflate.mid", p)
        if armed_drain:
            # The writer just armed a reader-cohort drain barrier and is
            # about to wait outside the CS — the window where its death
            # abandons the barrier (which lapses on its own: it is a
            # deadline, not a lock).
            self._crash_point("drain.mid", p)
        return granted, blocked

    def _unlock_run(self, p: Process, locked: List[ALock],
                    writes: List[tuple]) -> None:
        """Unlock a run's ALocks; all piggybacked writes ride the FIRST
        unlock's doorbell — every group's critical section is still held
        when that posting executes, so each write stays CS-protected by
        its own shard's lock.  Nested finallys: a fabric failure in one
        unlock never strands the rest."""
        if not locked:
            return
        try:
            locked[0].unlock(p, piggyback=writes or None)
        finally:
            self._unlock_run(p, locked[1:], [])

    def _acquire_run(self, p: Process,
                     groups: Sequence[Tuple[LockShard, Sequence[str]]],
                     ttl: float) -> Tuple[List[Lease], bool]:
        """EXCLUSIVE grant pass over a *run* of shard groups sharing one
        home host — ``_acquire_group`` generalised so the cross-group WR
        lists merge into one posting per destination (satellite: the
        batch/shards16 3.55-doorbells/op fix).

        The run's ALocks are taken in ascending shard order (the global
        total order — every locker ascends, so no cycle of CS waiters can
        form), each engagement piggybacking its own group's lease-register
        reads; failed piggybacks re-read in ONE merged posting; the grant
        CASes of *all* groups commit in ONE posting (WR lists execute in
        order, preserving the key order inside the doorbell); the fence/
        holder/intent writes all ride the first unlock while every CS is
        still held.  Per-group doorbells drop from 3 (engage, commit,
        unlock) to 2 + 1/k.  Verdict logic, inflation decisions, and the
        stop-at-first-blocked discipline are exactly ``_acquire_group``'s,
        applied over the run's flat key order.
        """
        first_shard = groups[0][0]
        local = p.node == first_shard.home_host
        snap = p.counts.as_tuple()
        granted: List[Lease] = []
        writes: List[tuple] = []
        blocked = False
        blocked_at: Optional[Tuple[LockShard, str]] = None
        inflated_at: Optional[Tuple[LockShard, str, int]] = None
        armed_drain = False
        expirations: Dict[int, int] = {}
        repairs: Dict[int, int] = {}
        # Clock sampled before any lock, same zombie-window argument as
        # _acquire_group (see there).
        now = self.clock()
        locked: List[ALock] = []
        ctx: List[Tuple[LockShard, Sequence[str], List[_KeyState],
                        Optional[list]]] = []
        try:
            try:
                for shard, keys in groups:
                    states = [self._key_state(shard, k) for k in keys]
                    alock = shard.alock  # pin: takeover swaps it mid-CS
                    if local:
                        alock.lock(p)
                        flat = None
                    else:
                        flat = alock.lock(p, piggyback_reads=[
                            r for st in states
                            for r in (st.expires, st.fence)
                        ])
                    locked.append(alock)
                    ctx.append((shard, keys, states, flat))
                # Re-read every group whose piggyback went unvalidated —
                # ONE merged posting for the whole run (every register
                # lives on the run's single home node).
                need = [(gi, c[2]) for gi, c in enumerate(ctx)
                        if c[3] is None]
                reread: Dict[int, List[Tuple[tuple, int]]] = {}
                if need:
                    if local:
                        for gi, states in need:
                            reread[gi] = [
                                (self.mem.read(p, st.expires),
                                 self.mem.read(p, st.fence))
                                for st in states]
                    else:
                        flatv = self.mem.post_batch(p, [
                            wr for _gi, states in need for st in states
                            for wr in (("read", st.expires),
                                       ("read", st.fence))])
                        off = 0
                        for gi, states in need:
                            reread[gi] = [
                                (flatv[off + 2 * i], flatv[off + 2 * i + 1])
                                for i in range(len(states))]
                            off += 2 * len(states)
                # Verdict pass over the run's flat key order; stops at the
                # first blocked key (global-order discipline: nothing past
                # it may be planned, in THIS group or any later one).
                plan = []  # (shard, key, st, packed, token, clob, free, enc0)
                for gi, (shard, keys, states, flat) in enumerate(ctx):
                    if blocked:
                        break
                    if flat is not None:
                        vals = [(flat[2 * i], flat[2 * i + 1])
                                for i in range(len(states))]
                    else:
                        vals = reread[gi]
                    for key, st, ((etok, readers, eexp), fence) in zip(
                            keys, states, vals):
                        free = eexp <= _FREE_AT
                        clobbered = not _trusted(etok, fence, readers)
                        if not free and not clobbered and now < eexp:
                            blocked = True
                            blocked_at = (shard, key)
                            if _dec(readers) > 0:
                                writes.append(("write", st.intent, eexp))
                                armed_drain = True
                            elif (self._estimator is not None
                                    and not _infl(readers)):
                                self._estimator.note(key, now)
                                if (st.infl is None
                                        and self._estimator.should_inflate(
                                            key, now)):
                                    st.infl_epoch += 1
                                    st.infl = InflatedKeyQueue(
                                        self.mem, shard.home_host,
                                        self._init_budget,
                                        f"{self.name}.s{shard.index}"
                                        f".k{stable_key_hash(key):016x}"
                                        f".iq{st.infl_epoch}")
                                    if self.mem.auto_cas(
                                        p, st.expires, (etok, readers, eexp),
                                        (etok, _enc(0, True), eexp),
                                    ) == (etok, readers, eexp):
                                        self._estimator.mark_inflated(key, now)
                                        inflated_at = (shard, key, etok)
                                        st.infl_ceiling = etok
                                    else:
                                        st.infl = None
                            break
                        if st.infl is not None and not st.infl.empty(p):
                            blocked = True
                            blocked_at = (shard, key)
                            break
                        token = fence + 1  # CS-only allocator
                        plan.append((shard, key, st, (etok, readers, eexp),
                                     token, clobbered, free,
                                     _enc(0, st.infl is not None)))
                # Commit pass: ONE posting of every group's grant CASes
                # (same CAS-against-read discipline as _acquire_group; WR
                # entries execute in list order, so grants land in the
                # global key order even inside the merged doorbell).
                if plan:
                    if local:
                        won = [
                            self.mem.cas(p, st.expires, packed,
                                         (token, enc0, now + ttl)) == packed
                            for (_sh, _k, st, packed, token, _c, _f, enc0)
                            in plan
                        ]
                    else:
                        obs = self.mem.post_batch(p, [
                            ("cas", st.expires, packed,
                             (token, enc0, now + ttl))
                            for (_sh, _k, st, packed, token, _c, _f, enc0)
                            in plan
                        ])
                        won = [o == packed
                               for o, (_sh, _k, _s, packed, *_r)
                               in zip(obs, plan)]
                    cut = won.index(False) if False in won else len(plan)
                    rollback = [
                        ("cas", st.expires, (token, enc0, now + ttl), packed)
                        for i, (_sh, _k, st, packed, token, _c, _f, enc0)
                        in enumerate(plan)
                        if i > cut and won[i]
                    ]
                    if rollback:
                        if local:
                            for _op, reg, exp_v, new_v in rollback:
                                self.mem.cas(p, reg, exp_v, new_v)
                        else:
                            self.mem.post_batch(p, rollback)
                    if cut < len(plan):
                        blocked = True
                        blocked_at = (plan[cut][0], plan[cut][1])
                    for (shard, key, st, packed, token, clobbered, free,
                         enc0) in plan[:cut]:
                        if clobbered:
                            repairs[shard.index] = \
                                repairs.get(shard.index, 0) + 1
                        elif not free:
                            expirations[shard.index] = \
                                expirations.get(shard.index, 0) + 1
                        granted.append(
                            Lease(key, shard.index, p.pid, token, now + ttl,
                                  ttl, LeaseMode.EXCLUSIVE, _infl(enc0))
                        )
                        fence_val = token
                        if _infl(enc0):
                            st.infl_ceiling = fence_val = \
                                token + _INFL_RESERVE
                        writes += [
                            ("write", st.fence, fence_val),
                            ("write", st.holder, p.pid),
                            ("write", st.intent, _FREE_AT),
                        ]
            finally:
                self._unlock_run(p, locked, writes)
        finally:
            # Merged-posting accounting lands on the run's first shard
            # (the per-class split is identical — one home, one class).
            self._account(first_shard, p, snap, LeaseMode.EXCLUSIVE)
        ngrant: Dict[int, int] = {}
        for g in granted:
            ngrant[g.shard] = ngrant.get(g.shard, 0) + 1
        for shard, _keys in groups:
            si = shard.index
            if not (si in ngrant or si in expirations or si in repairs
                    or (blocked_at is not None
                        and blocked_at[0].index == si)
                    or (inflated_at is not None
                        and inflated_at[0].index == si)):
                continue
            with shard._meta:
                shard.grants += ngrant.get(si, 0)
                shard.grants_by_mode[LeaseMode.EXCLUSIVE] += ngrant.get(si, 0)
                shard.expirations += expirations.get(si, 0)
                shard.repairs += repairs.get(si, 0)
                if inflated_at is not None and inflated_at[0].index == si:
                    shard.inflations += 1
                if blocked_at is not None and blocked_at[0].index == si:
                    shard.rejects += 1
                    shard.rejects_by_mode[LeaseMode.EXCLUSIVE] += 1
                    shard.key_retries[blocked_at[1]] = \
                        shard.key_retries.get(blocked_at[1], 0) + 1
        if inflated_at is not None:
            self._log_infl_event(now, "inflate", inflated_at[1],
                                 inflated_at[2], "hot")
            self._crash_point("inflate.mid", p)
        if armed_drain:
            self._crash_point("drain.mid", p)
        return granted, blocked

    def try_acquire(self, p: Process, key: str, ttl: float,
                    mode: LeaseMode = LeaseMode.EXCLUSIVE) -> Optional[Lease]:
        """One lease-table transaction; non-blocking.

        EXCLUSIVE: grants iff the key is free or its current lease (either
        mode) has expired; a fresh grant always carries a larger fencing
        token.  Returns ``None`` while a live lease exists — *including* the
        caller's own (non-reentrant: a holder extends via :meth:`renew`;
        silently superseding would let one process posing as several clients
        steal its own slots).

        SHARED: grants iff the key is free, expired, or held by a live
        reader cohort with no writer draining it — a single CAS (per
        attempt; a lost race with another shared CAS retries, bounded by
        ``_FAST_ATTEMPTS``), no shard ALock.  Shared joins by the same
        process stack (each join holds one cohort slot and needs its own
        release); a live writer or an armed writer-intent barrier yields
        ``None``.

        When the table carries an :class:`~repro.coord.OverloadPolicy`, a
        remote attempt is gated by the destination host's circuit breaker
        (an open breaker raises :class:`~repro.core.Overloaded` *before*
        any fabric op is posted — the fast-refusal path), and the attempt's
        outcome (RemoteTimeout, or op timeouts absorbed by the fabric's
        internal retries, count as failure) feeds the breaker window and
        refills the retry budget on success.
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        shard = self.shards[self.shard_of(key)]
        home = shard.home_host
        ctl = self.overload
        gated = ctl is not None and p.node != home
        if gated:
            ctl.admit_remote(home, self.clock())
        t0, r0 = p.counts.timeouts, p.counts.retries
        epoch0 = shard.epoch
        ok = True
        try:
            if mode == LeaseMode.SHARED:
                lease = self._shared_acquire(p, shard, key, ttl)
            elif (self.inflation is not None
                    and (st := shard.keys.get(key)) is not None
                    and st.infl is not None):
                lease = self._inflated_acquire(p, shard, key, st, ttl)
            else:
                granted, _ = self._acquire_group(p, shard, (key,), ttl, mode)
                lease = granted[0] if granted else None
        except RemoteTimeout:
            ok = False
            raise
        finally:
            dt_t = p.counts.timeouts - t0
            dt_r = p.counts.retries - r0
            if dt_t or dt_r:
                # Satellite: the fabric already counts op timeouts and
                # retry rounds in OpCounts, but nothing said WHERE they
                # landed — re-key the deltas so hot_keys() can report them.
                with shard._meta:
                    if dt_t:
                        shard.key_timeouts[key] = \
                            shard.key_timeouts.get(key, 0) + dt_t
                    if dt_r:
                        shard.key_fab_retries[key] = \
                            shard.key_fab_retries.get(key, 0) + dt_r
            if gated:
                ctl.on_outcome(home, ok and dt_t == 0, self.clock())
        return self._epoch_fence(p, shard, epoch0, lease)

    def _epoch_fence(self, p: Process, shard: LockShard, epoch0: int,
                     lease: Optional[Lease]) -> Optional[Lease]:
        """Discard a grant that raced an epoch bump (shard takeover).

        A transaction that read the shard's key states before a takeover
        committed may have granted against the **dead epoch's** registers —
        state the new home neither sees nor honors.  The fence is checked
        after every grant commits: epoch moved ⇒ the grant never happened
        (its word is a tombstone on a dead host), the caller retries against
        the re-homed shard.  This is the client-side half of the zombie
        fence; the epoch CAS itself keeps two successors from both
        rebuilding.
        """
        if lease is None or shard.epoch == epoch0:
            return lease
        with shard._meta:
            shard.epoch_aborts += 1
            shard.grants -= 1
            shard.grants_by_mode[lease.mode] -= 1
        if lease.mode == LeaseMode.SHARED:
            self._slot_consume(p, lease.key, lease.token)
        return None

    # ------------------------------------------------- inflated (queued) mode
    def _inflated_acquire(self, p: Process, shard: LockShard, key: str,
                          st: _KeyState, ttl: float) -> Optional[Lease]:
        """One non-blocking attempt on an inflated key, through its queue.

        First call enqueues into the caller's class cohort (local clients:
        machine-local CAS, 0 RDMA; remote clients: one rCAS + at most one
        rWrite — the bounded constant the queue buys).  Subsequent calls
        poll: ``parked`` waiters return ``None`` after ONE local read (the
        whole point — no shard CS, no word CAS, no remote op per retry);
        the cohort head attempts the grant.  A head whose handoff never
        comes (dead predecessor, discarded epoch) distrusts the queue after
        ``stale_after_ttls`` TTLs and bypasses to the word directly.
        """
        q = st.infl
        if q is None:
            # Deflated between the routing check and here: normal path.
            granted, _ = self._acquire_group(p, shard, (key,), ttl)
            return granted[0] if granted else None
        waits = self._pid_waits(p)
        ws = waits.get(key)
        if ws is not None and ws[0] is not q:
            del waits[key]  # a discarded epoch's wait: start over
            ws = None
        snap = p.counts.as_tuple()
        enqueued = False
        bypass = False
        blocked = False
        lease: Optional[Lease] = None
        try:
            if ws is None:
                leader = q.enqueue(p)
                waits[key] = [q, self.clock(), False]
                enqueued = True
                if not leader:
                    blocked = True
                    return None  # parked behind a predecessor: poll later
            else:
                verdict = q.poll(p)
                if verdict == "granted":
                    # The predecessor handed the lock over directly: the
                    # word already carries our token — consume the payload
                    # and walk away holding, zero word ops, zero CS.
                    grant = q.take_grant(p)
                    now = self.clock()
                    if grant is not None and now < grant[1]:
                        token, expires = grant
                        ws[1] = now
                        ws[2] = True
                        lease = Lease(key, shard.index, p.pid, token,
                                      expires, ttl, LeaseMode.EXCLUSIVE,
                                      True)
                        return lease
                    # Stamped before we looked, expired before we woke: the
                    # word has (or will) move on without us — fall back to
                    # an ordinary entitled attempt next poll.
                    ws[1] = self.clock()
                    blocked = True
                    return None
                if verdict == "defer":
                    ws[1] = self.clock()  # the queue is live: not stale
                    blocked = True
                    return None
                if verdict == "parked":
                    if (self.clock() - ws[1]
                            < self.inflation.stale_after_ttls * ttl):
                        blocked = True
                        return None
                    bypass = True  # wedged queue: probe the word directly
                else:
                    ws[1] = self.clock()
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            if enqueued or blocked or lease is not None:
                with shard._meta:
                    if enqueued:
                        shard.queue_enqueues += 1
                    if blocked:
                        # Queue-mode pressure shows up in the same per-key
                        # retry counter the deflated CAS lottery feeds, so
                        # the hot-key report sees inflated keys too.
                        shard.key_retries[key] = \
                            shard.key_retries.get(key, 0) + 1
                    if lease is not None:
                        shard.grants += 1
                        shard.grants_by_mode[LeaseMode.EXCLUSIVE] += 1
                        shard.queue_grants += 1
        return self._inflated_grant(p, shard, key, st, ttl, q, bypass)

    def _inflated_grant(self, p: Process, shard: LockShard, key: str,
                        st: _KeyState, ttl: float, q: InflatedKeyQueue,
                        bypass: bool) -> Optional[Lease]:
        """The cohort head's grant attempt: cheap word pre-check, then the
        ordinary fully-validated critical-section grant.

        ``bypass`` is the disorderly exit: a stale head stops trusting the
        queue, and its grant (if the word really is free/expired) re-seeds
        the key DEFLATED and discards the whole queue — every other waiter
        notices its wait entry points at a dead epoch and starts over.
        """
        snap = p.counts.as_tuple()
        local = p.node == shard.home_host
        lease: Optional[Lease] = None
        expired_over = False
        repaired = False
        discarded: Optional[Tuple[float, int]] = None
        try:
            if not bypass:
                # Pre-check outside the CS: an entitled head polling a
                # still-live holder must not pay a critical section per
                # poll (that is the deflated path's failure mode).
                now = self.clock()
                if local:
                    packed = self.mem.read(p, st.expires)
                    fence = self.mem.read(p, st.fence)
                else:
                    packed, fence = self.mem.post_batch(
                        p, [("read", st.expires), ("read", st.fence)])
                etok, readers, eexp = packed
                if (_trusted(etok, fence, readers)
                        and _FREE_AT < eexp and now < eexp):
                    return None  # live holder: stay entitled, poll again
            alock = shard.alock  # pin: a takeover swaps shard.alock mid-CS
            alock.lock(p)
            writes: List[tuple] = []
            try:
                now = self.clock()
                _holder, (etok, readers, eexp), fence, _barrier = \
                    self._read_key_state(p, shard, st)
                free = eexp <= _FREE_AT
                clobbered = not _trusted(etok, fence, readers)
                if not free and not clobbered and now < eexp:
                    if _dec(readers) > 0:
                        # Reader cohort under the inflated word: arm the
                        # writer drain barrier, same bounded wait as the
                        # deflated path.
                        writes.append(("write", st.intent, eexp))
                else:
                    token = fence + 1
                    keep = st.infl is q and not bypass
                    if self.mem.auto_cas(
                        p, st.expires, (etok, readers, eexp),
                        (token, _enc(0, keep), now + ttl),
                    ) == (etok, readers, eexp):
                        lease = Lease(key, shard.index, p.pid, token,
                                      now + ttl, ttl, LeaseMode.EXCLUSIVE,
                                      keep)
                        fence_val = token
                        if keep:
                            # Still inflated: re-reserve the direct-handoff
                            # block (a bypass grant deflates, so its plain
                            # ``token`` write re-syncs the mirror instead).
                            st.infl_ceiling = fence_val = token + _INFL_RESERVE
                        writes = [
                            ("write", st.fence, fence_val),
                            ("write", st.holder, p.pid),
                            ("write", st.intent, _FREE_AT),
                        ]
                        repaired = clobbered
                        expired_over = (not free) and not clobbered
                        if bypass and st.infl is q:
                            # Disorderly deflation: the queue is gone the
                            # moment the deflated grant lands.
                            st.infl = None
                            self._estimator.mark_deflated(key, now)
                            discarded = (now, token)
            finally:
                alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        if lease is not None:
            waits = self._pid_waits(p)
            ws = waits.get(key)
            if lease.inflated and ws is not None and ws[0] is q:
                ws[2] = True  # holding via the queue: release must pass it
            elif ws is not None and ws[0] is q:
                del waits[key]  # granted deflated: no queue obligation
        if discarded is not None:
            self._log_infl_event(discarded[0], "deflate", key,
                                 discarded[1], "bypass")
        with shard._meta:
            if lease is not None:
                shard.grants += 1
                shard.grants_by_mode[LeaseMode.EXCLUSIVE] += 1
                shard.queue_grants += 1
                if expired_over:
                    shard.expirations += 1
                if repaired:
                    shard.repairs += 1
                if discarded is not None:
                    shard.queue_bypasses += 1
                    shard.deflations += 1
            else:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.EXCLUSIVE] += 1
                shard.key_retries[key] = shard.key_retries.get(key, 0) + 1
        return lease

    def _inflated_release(self, p: Process, shard: LockShard, st: _KeyState,
                          lease: Lease) -> Optional[bool]:
        """Direct lock handoff — the inflated hot path's whole payoff.

        A queue-entitled holder with a successor parked behind it does not
        free the word at all: ONE witness CAS moves the word straight to
        ``(token + 1, inflated, now + ttl)`` — ownership transferred, token
        chain advanced — and the cohort pass (the budget write the handoff
        was making anyway) carries ``(token, expires_at)`` to the successor,
        whose next poll returns the lease without touching the word or the
        shard CS.  Remote-holder cost: 1 rCAS + 1 rWrite per handoff,
        regardless of contention; the thundering re-grant (pre-check + CS +
        grant CAS per waiter) vanishes.

        Returns ``None`` when direct handoff does not apply — no successor,
        the cohort-budget fairness rule owes the other cohort a free word
        to CAS for, the epoch's token reservation ran out, the lease is
        already expired, or the caller is not queue-entitled — and the
        ordinary release path (free the word, then pass plain entitlement
        via :meth:`_inflated_handoff`) takes over.
        """
        q = st.infl
        waits = self._pid_waits(p)
        ws = waits.get(lease.key)
        if (q is None or ws is None or ws[0] is not q or not ws[2]):
            return None  # not holding via the live queue epoch
        snap = p.counts.as_tuple()
        passed: Optional[int] = None
        try:
            now = self.clock()
            if (now >= lease.expires_at
                    or lease.token + 1 > st.infl_ceiling
                    or not q.can_direct(p)):
                return None
            token = lease.token + 1
            expires = now + lease.ttl
            witness = lease.witness()
            if self.mem.auto_cas(
                p, st.expires, witness,
                (token, _enc(0, True), expires),
            ) != witness:
                return None  # superseded (zombie): ordinary path cleans up
            del waits[lease.key]
            # The window where a holder dies having moved the word to its
            # successor's token but never written the successor's budget:
            # the successor stalls parked, distrusts the queue after the
            # staleness deadline, and bypasses to the (by then expired)
            # word — the bypass grant deflates the key.
            self._crash_point("deflate.mid", p)
            q.pass_grant(p, token, expires)
            passed = token
            return True
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            with shard._meta:
                if passed is not None:
                    shard.fast_releases += 1
                    shard.queue_handoffs += 1

    def _inflated_handoff(self, p: Process, shard: LockShard, st: _KeyState,
                          key: str, lease: Lease) -> None:
        """After releasing an inflated-mode grant: pass the queue on, and
        deflate if the key has cooled.

        The releaser hands its cohort's entitlement to its successor (one
        local write — FIFO, no thundering herd) or drains the cohort.  When
        its own cohort drained, the other cohort is empty too, the policy's
        hysteresis says cold, and the word still carries the release value,
        ONE CAS swings the mode bit off — the queue object is discarded
        wholesale (a new epoch allocates fresh registers).
        """
        self._crash_point("deflate.mid", p)
        q = st.infl
        waits = self._pid_waits(p)
        ws = waits.get(key)
        if ws is not None and ws[0] is not q:
            del waits[key]
            return
        if ws is None or not ws[2] or q is None:
            return  # not holding via the queue (pre-inflation holder, or
            # a reclaimed incarnation): nothing to pass — waiters poll the
            # word and self-heal via the staleness bypass if stranded.
        snap = p.counts.as_tuple()
        deflated: Optional[Tuple[float, int]] = None
        try:
            drained = q.release(p)
            del waits[key]
            now = self.clock()
            if (drained and st.infl is q and q.empty(p)
                    and self._estimator.should_deflate(key, now)):
                released_word = (lease.token, _enc(0, True), _FREE_AT)
                if self.mem.auto_cas(
                    p, st.expires, released_word,
                    (lease.token, 0, _FREE_AT),
                ) == released_word:
                    st.infl = None
                    self._estimator.mark_deflated(key, now)
                    deflated = (now, lease.token)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            if deflated is not None:
                self._log_infl_event(deflated[0], "deflate", key,
                                     deflated[1], "cool")
            with shard._meta:
                shard.queue_handoffs += 1
                if deflated is not None:
                    shard.deflations += 1

    def acquire(self, p: Process, key: str, ttl: float,
                timeout: Optional[float] = None,
                poll: float = 0.0005,
                mode: LeaseMode = LeaseMode.EXCLUSIVE,
                deadline: Optional[float] = None,
                priority: int = 0) -> Lease:
        """Blocking acquire: retry ``try_acquire`` until granted or timeout.

        Rejected attempts back off with seeded-jitter binary exponential
        delay: base ``poll``, doubling per consecutive reject up to
        ``poll * _BACKOFF_CAP_POLLS``, each sleep scaled by a seeded
        uniform in [0.5, 1.5).  Every retry is a full table transaction
        (remote ops for remote clients), so fixed-interval polling under a
        hot key synchronises the herd — all losers re-arrive together —
        while the jittered doubling spreads them out.  Both the clock and
        the RNG are injected/seeded, so the sim schedule stays a pure
        function of the seed.

        **Deadline propagation.**  ``deadline`` is an *absolute* instant on
        the table's clock (the caller's end-to-end budget, threaded through
        every layer); ``timeout`` remains the legacy relative form, and when
        both are given the earlier wins.  No backoff sleep ever overshoots
        the remaining budget (each sleep is clamped to ``deadline - now``),
        and an explicit deadline that expires raises the typed
        :class:`~repro.core.DeadlineExceeded` — a ``TimeoutError`` subclass,
        so legacy ``except TimeoutError`` handlers keep working, while the
        timeout-only path keeps its historical ``TimeoutError`` message.

        **Load shedding.**  With an explicit ``deadline`` and
        ``priority <= 0``, an attempt whose remaining budget is already
        below the shard's observed time-to-completion (an EWMA over how
        long blocking acquires here take to grant — or to burn their whole
        budget failing) is **shed**: :class:`~repro.core.Overloaded`
        (``reason="shed"``) is raised *before* another retry round spends
        fabric ops that cannot possibly land in budget.
        Positive-priority work is never shed (it may still exceed its
        deadline).  Legacy callers (no explicit deadline) are never shed.

        **Retry budgets.**  When the table was built with an
        :class:`~repro.coord.OverloadPolicy`, each backoff round against a
        *remote* home consumes one token from that host's retry budget;
        a dry budget raises :class:`~repro.core.Overloaded`
        (``reason="budget"``) instead of joining a retry storm.
        """
        explicit = deadline is not None
        if timeout is not None:
            tdl = self.clock() + timeout
            deadline = tdl if deadline is None else min(deadline, tdl)
        shard = self.shards[self.shard_of(key)]
        if explicit:
            # An op entered past its deadline fails fast — zero fabric ops
            # — instead of posting a grant its caller can no longer use.
            # (Timeout-only callers keep their historical one-free-attempt
            # semantics: their budget starts now, by construction.)
            self._deadline_gate("acquire", key, shard, deadline)
        home = shard.home_host
        ctl = self.overload
        delay = poll
        entered = self.clock()

        def _observe(end: float) -> None:
            # Time-to-completion EWMA: how long a blocking acquire on this
            # shard actually takes to resolve — a grant's full retry chain,
            # or the whole burned budget of a deadline failure.  This (not
            # the single-attempt cost) is what the feasibility shed
            # compares the remaining budget against: under load the
            # failures push it up and the shed bites earlier; when load
            # drains the quick grants pull it back down.
            dt = end - entered
            shard.svc_time = (dt if shard.svc_time == 0.0
                              else 0.9 * shard.svc_time + 0.1 * dt)

        while True:
            now = self.clock()
            if (explicit and priority <= 0 and shard.svc_time > 0.0
                    and deadline - now < _SHED_SVC_MARGIN * shard.svc_time):
                # Admission-side feasibility shed: the remaining budget is
                # already below the shard's observed time-to-completion,
                # so this acquire is statistically doomed — refuse locally
                # before posting anything.  A grant produced after its
                # deadline is pure waste (the caller cannot use it), and
                # under overload those late grants are exactly what
                # starves the feasible work behind them.
                with shard._meta:
                    shard.sheds += 1
                raise Overloaded(
                    f"shed: lease on {key!r} infeasible within deadline "
                    f"(remaining {deadline - now:.6f}s < svc "
                    f"{shard.svc_time:.6f}s)", reason="shed", host=home)
            lease = self.try_acquire(p, key, ttl, mode=mode)
            if lease is not None:
                _observe(self.clock())
                return lease
            now = self.clock()
            # >= not >: the backoff clamp below can land the clock EXACTLY
            # on the deadline, and a cost-free attempt would then spin on
            # zero-length sleeps forever under a strict comparison.
            if deadline is not None and now >= deadline:
                _observe(now)
                with shard._meta:
                    shard.deadline_exceeded += 1
                if explicit:
                    raise DeadlineExceeded(
                        f"lease on {key!r}: deadline passed "
                        f"({now - deadline:.6f}s over)")
                raise TimeoutError(f"lease on {key!r} not granted in {timeout}s")
            if (explicit and priority <= 0 and shard.svc_time > 0.0
                    and deadline - now < _SHED_SVC_MARGIN * shard.svc_time):
                # Infeasible: the remaining budget is below the observed
                # time a blocking acquire here takes to resolve.  Shed now —
                # a fast local refusal — instead of burning fabric ops on
                # a lost cause (the brownout half: positive-priority and
                # legacy work never takes this exit).
                with shard._meta:
                    shard.sheds += 1
                raise Overloaded(
                    f"shed: lease on {key!r} infeasible within deadline "
                    f"(remaining {deadline - now:.6f}s < svc "
                    f"{shard.svc_time:.6f}s)", reason="shed", host=home)
            if ctl is not None and p.node != home:
                ctl.spend_retry(home)
            slp = delay * (0.5 + self._rng.random())
            if deadline is not None:
                slp = min(slp, max(0.0, deadline - now))
            self.sleep(slp)
            delay = min(delay * 2.0, poll * _BACKOFF_CAP_POLLS)

    def renew(self, p: Process, lease: Lease, ttl: Optional[float] = None,
              deadline: Optional[float] = None) -> Optional[Lease]:
        """Extend a still-valid lease; ``None`` if it was lost (fencing).

        **EXCLUSIVE fast path** (the common case — the holder renews before
        expiry, with its latest lease object): a single fencing-token-checked
        CAS on the expiry register, no shard ALock.  Zero simulated RDMA ops
        for a local holder, exactly one rCAS for a remote holder.  A zombie
        whose key was re-granted always loses the CAS: the register carries
        the new (larger) fence token, and tokens are never reused (no ABA).

        **EXCLUSIVE slow path** (stale lease object, or contention
        diagnosis): the original fully-validated transaction under the shard
        ALock.

        **SHARED**: a read + CAS extending the cohort's expiry horizon — no
        ALock in any case.  Refused while a writer-intent barrier is armed
        (the drain protocol: the reader keeps its slot until its own expiry,
        but cannot extend), after the holder's own ``expires_at`` (a crashed
        reader cannot resurrect its slot late), or when the generation moved
        on (token mismatch).
        """
        ttl = ttl if ttl is not None else lease.ttl
        shard = self.shards[lease.shard]
        # A renewal entered past its deadline — or past the lease's own
        # remaining TTL, which is the renewal's *implicit* budget (a CAS
        # landing after expiry extends nothing) — fails fast, zero ops.
        self._deadline_gate("renew", lease.key, shard,
                            None if deadline is None
                            else min(deadline, lease.expires_at))
        st = self._key_state(shard, lease.key)
        if lease.mode == LeaseMode.SHARED:
            return self._shared_renew(p, shard, st, lease, ttl)
        snap = p.counts.as_tuple()
        try:
            now = self.clock()
            if now < lease.expires_at:
                witness = lease.witness()
                observed = self.mem.auto_cas(
                    p, st.expires, witness,
                    (lease.token, _enc(0, lease.inflated), now + ttl)
                )
                if observed == witness:
                    with shard._meta:
                        shard.fast_renews += 1
                    return Lease(lease.key, lease.shard, lease.holder_pid,
                                 lease.token, now + ttl, ttl,
                                 LeaseMode.EXCLUSIVE, lease.inflated)
            alock = shard.alock  # pin: a takeover swaps shard.alock mid-CS
            alock.lock(p)
            renewed = None
            try:
                now = self.clock()
                holder, (etok, readers, eexp), fence, _barrier = \
                    self._read_key_state(p, shard, st)
                # A clobbered mirror (etok != fence) means the expiry can no
                # longer be trusted: refuse the renewal (conservative — the
                # holder must re-acquire) rather than extend blindly.  A
                # reader count (readers > 0) under our own token means the
                # key was released and re-opened as a reader generation
                # reusing it: our exclusive lease is long gone.
                if (
                    holder == lease.holder_pid
                    and fence == lease.token
                    and etok == fence
                    and _dec(readers) == 0
                    and _FREE_AT < eexp
                    and now < eexp
                ):
                    # CAS against the read value (the word is CAS-only);
                    # the readers field is written back as observed, so a
                    # renewal never flips the mode bit — a holder whose key
                    # inflated under it renews fine and learns the mode.
                    if self.mem.auto_cas(
                        p, st.expires, (etok, readers, eexp),
                        (lease.token, readers, now + ttl),
                    ) == (etok, readers, eexp):
                        renewed = Lease(lease.key, lease.shard,
                                        lease.holder_pid, lease.token,
                                        now + ttl, ttl, LeaseMode.EXCLUSIVE,
                                        _infl(readers))
            finally:
                alock.unlock(p)
            return renewed
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)

    def _shared_renew(self, p: Process, shard: LockShard, st: _KeyState,
                      lease: Lease, ttl: float) -> Optional[Lease]:
        if not self._slot_owned(p, lease.key, lease.token):
            return None  # released/upgraded already: the slot is not ours
        snap = p.counts.as_tuple()
        renewed = None
        intent_block = False
        try:
            for _ in range(_FAST_ATTEMPTS):
                now = self.clock()
                if now >= lease.expires_at:
                    break  # the holder's own slot lapsed: no resurrection
                packed, fence, barrier = self._shared_read(p, shard, st)
                etok, readers, eexp = packed
                if now < barrier:
                    intent_block = True  # writer draining: stop extending
                    break
                if (etok != lease.token or etok != fence
                        or _dec(readers) <= 0 or now >= eexp):
                    break  # generation moved on, clobbered, or expired
                new = (etok, readers, max(eexp, now + ttl))
                if self.mem.auto_cas(p, st.expires, packed, new) == packed:
                    renewed = Lease(lease.key, lease.shard, lease.holder_pid,
                                    etok, now + ttl, ttl, LeaseMode.SHARED,
                                    _infl(readers))
                    break
                self.mem.yield_point()  # lost to another shared CAS: retry
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if renewed is not None:
            self._slot_extend(p, lease.key, lease.token, renewed.expires_at)
        with shard._meta:
            if renewed is not None:
                shard.shared_renews += 1
            elif intent_block:
                shard.intent_blocks += 1
        return renewed

    def release(self, p: Process, lease: Lease,
                deadline: Optional[float] = None) -> bool:
        """Release iff the lease is still the current grant (token match).

        **EXCLUSIVE fast path**: one fencing-token-checked CAS writes the
        expiry register to ``(token, 0, FREE)`` — no shard ALock, zero RDMA
        ops for a local holder, one rCAS for a remote one.  The stale
        ``holder`` register left behind is harmless: grant decisions key off
        the packed expiry + fence, and the next grant overwrites it.

        **EXCLUSIVE slow path** (stale lease object whose token is still
        current): the fully-validated transaction under the shard ALock.

        **SHARED**: a read + CAS decrementing the cohort count (the last
        reader out writes FREE) — no ALock in any case.  A lapsed shared
        lease (past its own ``expires_at``) returns ``False``: its slot dies
        with the generation, which closes the ABA window where a zombie
        reader could decrement a *successor* generation that reused the
        token.
        """
        shard = self.shards[lease.shard]
        # Deadline-aware callers fail fast; the abandoned lease expires on
        # its own TTL (a refused release is safe — never a leak, only a
        # bounded wait for successors).
        self._deadline_gate("release", lease.key, shard, deadline)
        st = self._key_state(shard, lease.key)
        if lease.mode == LeaseMode.SHARED:
            return self._shared_release(p, shard, st, lease)
        if lease.inflated and self.inflation is not None:
            handled = self._inflated_release(p, shard, st, lease)
            if handled is not None:
                return handled
        snap = p.counts.as_tuple()
        handoff = lease.inflated
        try:
            witness = lease.witness()
            observed = self.mem.auto_cas(
                p, st.expires, witness,
                (lease.token, _enc(0, lease.inflated), _FREE_AT)
            )
            if observed == witness:
                with shard._meta:
                    shard.fast_releases += 1
                return True
            alock = shard.alock  # pin: a takeover swaps shard.alock mid-CS
            alock.lock(p)
            released = False
            infl_word = False
            writes = None
            try:
                holder, (etok, readers, eexp), fence, _barrier = \
                    self._read_key_state(p, shard, st)
                # Stale (expired and re-granted: the fence moved on), already
                # released (mirror intact at FREE), or superseded by a reader
                # generation reusing our token (readers > 0) ⇒ nothing to do.
                # Releasing the current generation is legal even with a
                # clobbered mirror: the write below re-syncs it.
                if (
                    holder == lease.holder_pid
                    and fence == lease.token
                    and _dec(readers) == 0
                    and not (etok == fence and eexp <= _FREE_AT)
                ):
                    # CAS against the read value (the word is CAS-only);
                    # the readers field carries the mode bit through —
                    # a release never deflates by accident.
                    if self.mem.auto_cas(
                        p, st.expires, (etok, readers, eexp),
                        (lease.token, readers, _FREE_AT),
                    ) == (etok, readers, eexp):
                        writes = [("write", st.holder, _NO_HOLDER)]
                        released = True
                        infl_word = _infl(readers)
            finally:
                alock.unlock(p, piggyback=writes)
            handoff = handoff or (released and infl_word)
            return released
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            if handoff:
                # Outside the ops accounting above: the handoff does its
                # own snapshot (its queue ops must not be double-counted).
                self._inflated_handoff(p, shard, st, lease.key, lease)

    def _shared_release(self, p: Process, shard: LockShard, st: _KeyState,
                        lease: Lease) -> bool:
        if not self._slot_owned(p, lease.key, lease.token):
            # Double release, or the slot was consumed by an upgrade: the
            # word's count is anonymous, so posting a decrement we do not
            # own would free ANOTHER live reader's slot and let a writer in
            # beside them.  Refuse without touching the word.
            return False
        snap = p.counts.as_tuple()
        released = False
        try:
            for _ in range(_FAST_ATTEMPTS):
                now = self.clock()
                if now >= lease.expires_at:
                    break  # lapsed: the slot dies with the generation (ABA)
                if p.node == shard.home_host:
                    packed = self.mem.read(p, st.expires)
                else:
                    packed = self.mem.rread(p, st.expires)
                etok, readers, eexp = packed
                dec, infl = _dec(readers), _infl(readers)
                if etok != lease.token or dec <= 0:
                    break  # the generation moved on: nothing to release
                new = (etok, _enc(dec - 1, infl),
                       eexp if dec > 1 else _FREE_AT)
                if self.mem.auto_cas(p, st.expires, packed, new) == packed:
                    released = True
                    break
                self.mem.yield_point()  # lost to another shared CAS: retry
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if released:
            self._slot_consume(p, lease.key, lease.token)
            with shard._meta:
                shard.shared_releases += 1
        return released

    # ------------------------------------------------------ mode transitions
    def upgrade(self, p: Process, lease: Lease,
                ttl: Optional[float] = None) -> Optional[Lease]:
        """SHARED → EXCLUSIVE, iff the caller is the *sole* live reader.

        Runs under the shard ALock (it allocates a token).  With other
        readers present it arms the writer-intent drain barrier (no new
        joins, no renewal extensions) and returns ``None`` — poll until the
        cohort drains.  Two holders upgrading the same key concurrently
        cannot both succeed; bound the polling with a timeout and release on
        failure (the classic S/X upgrade deadlock is the caller's to break).
        The upgraded lease's token is strictly larger than the reader
        generation's, so fencing monotonicity is preserved.
        """
        if lease.mode != LeaseMode.SHARED:
            raise ValueError("upgrade() takes a SHARED lease")
        if not self._slot_owned(p, lease.key, lease.token):
            return None  # released/consumed already: not our slot to trade
        ttl = ttl if ttl is not None else lease.ttl
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.as_tuple()
        upgraded = None
        try:
            now = self.clock()
            if now >= lease.expires_at:
                return None
            alock = shard.alock  # pin: a takeover swaps shard.alock mid-CS
            alock.lock(p)
            writes: List[tuple] = []
            try:
                now = self.clock()
                _holder, (etok, readers, eexp), fence, _barrier = \
                    self._read_key_state(p, shard, st)
                if (etok == fence == lease.token and _dec(readers) >= 1
                        and _FREE_AT < eexp and now < eexp
                        and now < lease.expires_at):
                    if _dec(readers) == 1:  # the sole live reader is us
                        token = fence + 1
                        infl = _infl(readers)
                        # CAS, not write: a CS-free join can slip in between
                        # the read and this commit — it must not be stomped
                        # into a phantom reader under our exclusive grant.
                        if self.mem.auto_cas(
                            p, st.expires, (etok, readers, eexp),
                            (token, _enc(0, infl), now + ttl),
                        ) == (etok, readers, eexp):
                            writes = [
                                ("write", st.fence, token),
                                ("write", st.holder, p.pid),
                                ("write", st.intent, _FREE_AT),
                            ]
                            upgraded = Lease(lease.key, lease.shard, p.pid,
                                             token, now + ttl, ttl,
                                             LeaseMode.EXCLUSIVE, infl)
                        else:  # a joiner beat us: drain them first
                            writes = [("write", st.intent, eexp)]
                    else:  # drain the rest of the cohort first
                        writes = [("write", st.intent, eexp)]
            finally:
                alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        if upgraded is not None:
            self._slot_consume(p, lease.key, lease.token)
        with shard._meta:
            if upgraded is not None:
                shard.upgrades += 1
                shard.grants += 1
                shard.grants_by_mode[LeaseMode.EXCLUSIVE] += 1
            else:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.EXCLUSIVE] += 1
        if upgraded is None and writes:
            # The upgrader armed the drain barrier and will poll from
            # outside the CS; its death here leaves the barrier to lapse
            # and its shared slot counted until the slot's own horizon
            # (reclaimable by a restarted incarnation).
            self._crash_point("upgrade.mid", p)
        return upgraded

    def downgrade(self, p: Process, lease: Lease,
                  ttl: Optional[float] = None) -> Optional[Lease]:
        """EXCLUSIVE → SHARED without a window for another writer.

        A single fencing-token-checked CAS turns the writer lease into a
        one-reader cohort that keeps the writer's token (the generation the
        readers share) — zero RDMA ops for a local holder, exactly one rCAS
        for a remote one.  Other readers can join the instant the CAS lands.
        ``None`` if the lease was stale (the witness lost).
        """
        if lease.mode != LeaseMode.EXCLUSIVE:
            raise ValueError("downgrade() takes an EXCLUSIVE lease")
        ttl = ttl if ttl is not None else lease.ttl
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.as_tuple()
        downgraded = None
        try:
            now = self.clock()
            if now < lease.expires_at:
                witness = lease.witness()
                observed = self.mem.auto_cas(
                    p, st.expires, witness,
                    (lease.token, _enc(1, lease.inflated), now + ttl)
                )
                if observed == witness:
                    downgraded = Lease(lease.key, lease.shard, p.pid,
                                       lease.token, now + ttl, ttl,
                                       LeaseMode.SHARED, lease.inflated)
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if downgraded is not None:
            self._slot_join(p, lease.key, downgraded.token,
                            downgraded.expires_at)
            with shard._meta:
                shard.downgrades += 1
            if lease.inflated:
                # The writer slot is gone: pass the queue entitlement on
                # (the word is reader-held, so the deflate CAS inside the
                # handoff can never fire — successors drain the cohort via
                # the intent barrier like any queued writer).
                self._inflated_handoff(p, shard, st, lease.key, lease)
        return downgraded

    # -------------------------------------------- optimistic (seqlock) reads
    def _opt_read_wrs(self, st: _KeyState) -> List[tuple]:
        """The seqlock read set, in WR-list execution order: packed word,
        payload, packed word again, intent barrier.  One posting — so one
        doorbell and **zero** CAS — for a remote reader; the async pipeline
        chains several of these into a single posting per host."""
        return [("read", st.expires), ("read", st.payload),
                ("read", st.expires), ("read", st.intent)]

    def _opt_read_verdict(self, now: float, w1: tuple, payload: tuple,
                          w2: tuple, barrier: float) -> Tuple[str, tuple]:
        """Classify one seqlock read set.

        Returns ``("ok", (value, publish_token))``, ``("forward", ())`` for
        a takeover tombstone (chase the forwarding pointer, never serve the
        stale payload), or ``("retry", reason)``.

        Validity argument (the torn/stale-read proof obligation):

        * ``w1 == w2`` — the word did not move across the payload read, so
          no writer *generation change* raced the snapshot.  WR-list
          entries are not mutually atomic (``post_batch`` schedules between
          them), which is exactly why the re-read is required.
        * the word is not a live EXCLUSIVE hold — a live writer may be
          mid-``publish``, so the payload cannot be trusted even under a
          stable word.
        * no writer-intent barrier is armed and the word is not in
          inflated (queued) mode: both states mean a writer is imminent or
          queued, so optimistic reads step aside exactly like shared joins
          do (refuse/retry, per the drain discipline).
        * ``payload_token <= word_token`` — publishes are fenced monotone
          in the writer token, so a payload token *above* the word token
          proves the word read was stale (e.g. a zombie's clobbered
          mirror): retry.  Under that fence, the payload IS the newest
          published value — generations that never published leave it
          untouched, which is fresh, not stale.
        """
        etok, readers, eexp = w1
        if w1 != w2:
            return ("retry", "unstable")
        if etok == _TOMB_TOKEN:
            return ("forward", ())
        if now < barrier:
            return ("retry", "intent")
        if _infl(readers):
            return ("retry", "inflated")
        if _FREE_AT < eexp and now < eexp and _dec(readers) == 0:
            return ("retry", "writer")
        ptok, value = payload
        if ptok > etok:
            return ("retry", "stale-word")
        return ("ok", (value, ptok))

    def read_optimistic(self, p: Process, key: str,
                        poll: float = 0.0005,
                        ttl: float = 1.0,
                        deadline: Optional[float] = None
                        ) -> Optional[Tuple[object, int]]:
        """Lease-free untorn snapshot of ``key``'s published payload.

        The seqlock read at the endpoint of the paper's cost hierarchy:
        read the packed word, read the payload, re-read the word — a
        stable ``(token, readers, expires)`` word with no intent barrier
        armed and no live writer proves an untorn snapshot, with **zero**
        coordination writes.  A home reader touches memory directly (0
        simulated RDMA ops); a remote reader posts the whole read set as
        one WR list: **one doorbell, zero CAS** per attempt.

        *Transient* instability (a torn word, a stale-word fence miss)
        retries in place on the table's seeded exponential backoff up to
        ``_OPT_ATTEMPTS`` times.  *Blocked* verdicts — a live writer, an
        armed intent barrier, an inflated (queued) word — cannot clear
        without writer progress, so the read does NOT spin on them: it
        degrades once to the bounded shared-lease fallback (join, read,
        leave — the PR 4 cost shape), and if even that single-CAS join is
        refused it returns ``None``, the same non-blocking retry contract
        as :meth:`try_acquire`.  Waiting out a holder belongs at the
        caller (who can yield), never inside the table.  A takeover
        tombstone is chased through the forwarding pointer to the key's
        new home; the stale payload is never returned.

        Returns ``(value, publish_token)`` — ``(None, 0)`` when nothing
        was ever published — or ``None`` when a writer holds the key
        *right now* (back off and call again).  The token lets callers
        order snapshots and reject stale reads downstream, same
        discipline as lease fencing.
        """
        shard = self.shards[self.shard_of(key)]
        self._deadline_gate("read_optimistic", key, shard, deadline)
        delay = poll
        for _ in range(_OPT_ATTEMPTS):
            # Re-resolve placement every attempt: a tombstone chase (or a
            # takeover committing mid-loop) swaps the shard's home and key
            # registers, and the stale _KeyState must not be re-read.
            shard = self.shards[self.shard_of(key)]
            st = self._key_state(shard, key)
            snap = p.counts.as_tuple()
            verdict, out = "retry", ("fabric",)
            try:
                now = self.clock()
                if p.node == shard.home_host:
                    w1 = self.mem.read(p, st.expires)
                    payload = self.mem.read(p, st.payload)
                    w2 = self.mem.read(p, st.expires)
                    barrier = self.mem.read(p, st.intent)
                else:
                    w1, payload, w2, barrier = self.mem.post_batch(
                        p, self._opt_read_wrs(st))
                verdict, out = self._opt_read_verdict(
                    now, w1, payload, w2, barrier)
                if verdict == "forward":
                    # Tombstoned word: decode the forwarding pointer from
                    # the deposed holder register, then retry against the
                    # re-homed registers (the placement re-resolve above
                    # picks them up once the takeover has committed).
                    fwd = forwarded_home(self.mem.auto_read(p, st.holder))
                    out = (fwd,)
            finally:
                self._account(shard, p, snap, LeaseMode.SHARED)
            if verdict == "ok":
                with shard._meta:
                    shard.opt_reads += 1
                return out
            with shard._meta:
                if verdict == "forward":
                    shard.opt_read_fwd += 1
                else:
                    shard.opt_read_retries += 1
            if verdict == "forward":
                continue  # re-resolve immediately: no backoff needed
            now = self.clock()
            if deadline is not None and now >= deadline:
                with shard._meta:
                    shard.deadline_exceeded += 1
                raise DeadlineExceeded(
                    f"read_optimistic of {key!r}: deadline passed")
            if out in ("writer", "intent", "inflated"):
                # Blocked on writer progress: spinning here can only end
                # by expiring the holder's lease (poisonous under the
                # sim's atomic blocking semantics, wasteful under
                # threads).  Degrade now; the caller owns the backoff.
                if out != "inflated":
                    # A shared join refuses on the exact same live-writer
                    # / intent check — don't pay a doomed CAS for it.
                    return None
                break  # inflated: a shared join may legally ride the queue
            ctl = self.overload
            if ctl is not None and p.node != shard.home_host:
                ctl.spend_retry(shard.home_host)
            slp = delay * (0.5 + self._rng.random())
            if deadline is not None:
                slp = min(slp, max(0.0, deadline - now))
            self.sleep(slp)
            delay = min(delay * 2.0, poll * _BACKOFF_CAP_POLLS)
        with shard._meta:
            shard.opt_read_fallbacks += 1
        return self._opt_read_fallback(p, key, ttl)

    def _opt_read_fallback(self, p: Process, key: str, ttl: float
                           ) -> Optional[Tuple[object, int]]:
        """Bounded degradation: read the payload under a shared lease.

        The cohort excludes writers for the lease's lifetime, so a single
        payload register read is untorn by construction; the join/leave
        pair is the PR 4 shared fast path (one CAS each, zero RDMA for a
        home reader).  ONE non-blocking join attempt: if the single-CAS
        shared join is itself refused (live writer, armed intent,
        inflation drain) the whole read returns ``None`` — retry is the
        caller's, with the caller's own backoff.  The table never waits
        out another process's hold on the read path.
        """
        lease = self.try_acquire(p, key, ttl, mode=LeaseMode.SHARED)
        if lease is None:
            return None
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.as_tuple()
        try:
            ptok, value = self.mem.auto_read(p, st.payload)
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        self.release(p, lease)
        return (value, ptok)

    def publish(self, p: Process, lease: Lease, value: object,
                deadline: Optional[float] = None) -> bool:
        """Publish ``key``'s optimistic-read payload under the holder's
        fencing token.

        Only a live EXCLUSIVE holder may publish: the payload register is
        read then CASed to ``(lease.token, value)``, and the CAS is
        **fenced** — a payload already carrying a larger token means a
        newer generation published first (this holder is a zombie), so the
        write is refused rather than regressing the payload.  Tokens are
        monotone across publishes, which is the invariant the seqlock
        readers' staleness check stands on.

        Zero simulated RDMA ops for a home holder (one local read + CAS);
        two doorbells for a remote one.  Returns ``False`` when fenced out
        or expired — like ``renew``, the caller must re-acquire.
        """
        if lease.mode != LeaseMode.EXCLUSIVE:
            raise ValueError("publish() takes an EXCLUSIVE lease")
        shard = self.shards[lease.shard]
        self._deadline_gate("publish", lease.key, shard,
                            None if deadline is None
                            else min(deadline, lease.expires_at))
        st = self._key_state(shard, lease.key)
        snap = p.counts.as_tuple()
        done = False
        try:
            if self.clock() >= lease.expires_at:
                return False
            cur = self.mem.auto_read(p, st.payload)
            for _ in range(_FAST_ATTEMPTS):
                if cur[0] > lease.token:
                    return False  # fenced: a newer generation published
                obs = self.mem.auto_cas(p, st.payload, cur,
                                        (lease.token, value))
                if obs == cur:
                    done = True
                    return True
                cur = obs
                self.mem.yield_point()  # lost to another publish: retry
            return False
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            if done:
                with shard._meta:
                    shard.publishes += 1

    def attach_pipeline(self, p: Process, client) -> None:
        """Register ``p``'s :class:`~repro.coord.AsyncClient` so hedged
        probes issued by ``p`` ride its flushes (see ``_probe``)."""
        self._pipelines[p.pid] = client

    # ------------------------------------------------------ crash recovery
    def reclaim(self, p: Process, lease: Lease,
                ttl: Optional[float] = None,
                deadline: Optional[float] = None) -> Optional[Lease]:
        """Crash-restart re-entry: re-adopt a still-valid lease.

        ``lease`` is the witness a restarted client replayed from its
        ledger (see ``repro.coord.ledger``).  Reclaim never *extends* a
        dead grant's reach: it succeeds only while the grant is still the
        key's live generation, and a lease the world has moved past
        (expired and re-granted, fenced out, cohort gone) returns ``None``
        — the client re-acquires like anyone else.

        **EXCLUSIVE fast path**: one fencing-token-checked CAS against the
        ledger's witness ``(token, 0, expires_at)``, re-timing the lease to
        ``now + ttl`` — zero simulated RDMA ops for a local holder, exactly
        one rCAS for a remote one, same cost shape as a renewal.  This is
        what makes restart re-entry ~three orders cheaper than the TTL
        wedge.

        **EXCLUSIVE word-probe path**: the witness can be stale-LOW (a
        renewal's CAS landed but its ledger record died with the client),
        so a missed fast CAS probes the authoritative word and CASes
        against *it* — still CS-free, and the probe reuses the failed
        CAS's own observation (a CAS returns the word), so a dead lease
        costs exactly the one rCAS that discovered it and a stale-LOW
        reclaim costs two, with a fresh read doorbell paid only when the
        witness was already expired and no CAS was attempted.  Sound for the same reason the
        renewal fast path is: fence tokens are never reused, so a word
        still carrying OUR token with no readers IS our live grant, and
        re-timing it is just a renewal.  Restart recovery therefore costs
        reads and CASes (doorbells), never a shard ALock critical section.
        Past the word's own expiry the lease is dead — reclaim never
        resurrects.

        **SHARED**: the crashed reader's cohort slot is still counted in
        the packed word (nobody else may decrement it — the client-side
        slot ledger forbids it), so reclaim re-adopts the slot under the
        new incarnation and extends the cohort horizon like a renewal,
        gated on the slot's OWN ``expires_at`` (the same no-resurrection
        ABA posture as ``_shared_release``: past its horizon the slot died
        with its generation) and refused while a writer drain barrier is
        armed.

        The reclaimed EXCLUSIVE lease keeps the *original* ``holder_pid``:
        that pid is the grant's identity (the ``holder`` register still
        names it, and pids are never reused), so the slow renew/release
        validations keep working for the new incarnation.  SHARED reclaims
        carry the new pid — cohort slots are owned per live process.
        """
        if ttl is None:
            ttl = lease.ttl
        shard = self.shards[lease.shard]
        # Restart recovery races the TTL wedge: a reclaim entered past the
        # caller's budget fails fast and the client re-acquires instead.
        self._deadline_gate("reclaim", lease.key, shard, deadline)
        st = self._key_state(shard, lease.key)
        if lease.mode == LeaseMode.SHARED:
            return self._shared_reclaim(p, shard, st, lease, ttl)
        snap = p.counts.as_tuple()
        got: Optional[Lease] = None
        fast = False
        try:
            now = self.clock()
            packed = None
            if now < lease.expires_at:
                witness = lease.witness()
                observed = self.mem.auto_cas(
                    p, st.expires, witness,
                    (lease.token, _enc(0, lease.inflated), now + ttl)
                )
                if observed == witness:
                    got = Lease(lease.key, lease.shard, lease.holder_pid,
                                lease.token, now + ttl, ttl,
                                LeaseMode.EXCLUSIVE, lease.inflated)
                    fast = True
                else:
                    # A failed CAS *returns* the word: the probe below
                    # starts from that observation instead of paying a
                    # fresh read doorbell for the same value.
                    packed = observed
            if got is None:
                for _ in range(_FAST_ATTEMPTS):
                    now = self.clock()
                    if deadline is not None and now >= deadline:
                        break  # budget gone mid-probe: stop cleanly
                    if packed is None:
                        # The word probe may hedge one re-post under
                        # overload control (see _hedged_read).
                        packed = self._hedged_read(p, st.expires, shard)
                    etok, readers, eexp = packed
                    if (etok != lease.token or _dec(readers) != 0
                            or eexp <= _FREE_AT or now >= eexp):
                        break  # expired, re-granted, or a reader generation
                    # The readers field is written back as observed: a
                    # reclaim learns the word's current mode (the key may
                    # have inflated or deflated since the ledger record).
                    observed = self.mem.auto_cas(
                        p, st.expires, packed, (lease.token, readers,
                                                now + ttl)
                    )
                    if observed == packed:
                        got = Lease(lease.key, lease.shard, lease.holder_pid,
                                    lease.token, now + ttl, ttl,
                                    LeaseMode.EXCLUSIVE, _infl(readers))
                        break
                    packed = observed  # lost a word race: the loser's
                    self.mem.yield_point()  # observation feeds the retry
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        with shard._meta:
            if got is not None:
                shard.reclaims += 1
                if fast:
                    shard.reclaim_fast += 1
                else:
                    shard.reclaim_slow += 1
            else:
                shard.reclaim_rejects += 1
        return got

    def _shared_reclaim(self, p: Process, shard: LockShard, st: _KeyState,
                        lease: Lease, ttl: float) -> Optional[Lease]:
        snap = p.counts.as_tuple()
        got: Optional[Lease] = None
        try:
            for _ in range(_FAST_ATTEMPTS):
                now = self.clock()
                if now >= lease.expires_at:
                    break  # the slot's horizon passed: it died with the
                    # generation (no resurrection — the ABA guard that
                    # keeps a reclaim from decrementing, later, a
                    # successor generation that reused the token)
                packed, fence, barrier = self._shared_read(p, shard, st)
                etok, readers, eexp = packed
                if now < barrier:
                    break  # writer draining: no extensions, no re-adoption
                if (etok != lease.token or etok != fence
                        or _dec(readers) <= 0 or now >= eexp):
                    break  # generation moved on, clobbered, or expired
                new = (etok, readers, max(eexp, now + ttl))
                if self.mem.auto_cas(p, st.expires, packed, new) == packed:
                    got = Lease(lease.key, lease.shard, p.pid, etok,
                                now + ttl, ttl, LeaseMode.SHARED,
                                _infl(readers))
                    break
                self.mem.yield_point()  # lost to another shared CAS: retry
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if got is not None:
            self._slot_join(p, lease.key, got.token, got.expires_at)
        with shard._meta:
            if got is not None:
                shard.reclaims += 1
                shard.reclaim_shared += 1
            else:
                shard.reclaim_rejects += 1
        return got

    def reclaim_orphan(self, p: Process, key: str,
                       dead_pids: Sequence[int],
                       ttl: float) -> Optional[Lease]:
        """Adopt a live EXCLUSIVE grant left by a dead incarnation.

        The one crash window reclaim-by-witness cannot cover: the grant
        CAS committed but the client died before its ledger recorded the
        token (``grant.pre_ledger``, or mid-batch).  The restarted client
        knows only that an *intent* is dangling — but the ``holder``
        register names the grantee, and pids are never reused, so under
        the shard ALock a live word whose holder is one of the caller's
        dead pids is provably the caller's lost grant.  The CAS re-times
        it and the holder register is re-pointed at the new incarnation.

        Probe cost is one CS per dangling intent — proportional to what
        was in flight at the crash, not to the keyspace (the adaptive
        recovery-cost shape of Dhoked & Mittal's RME transformation).
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        dead = set(dead_pids)
        shard = self.shards[self.shard_of(key)]
        st = self._key_state(shard, key)
        snap = p.counts.as_tuple()
        got: Optional[Lease] = None
        writes = None
        try:
            if dead:
                alock = shard.alock  # pin across a concurrent takeover
                alock.lock(p)
                try:
                    now = self.clock()
                    holder, (etok, readers, eexp), fence, _barrier = \
                        self._read_key_state(p, shard, st)
                    if (
                        holder in dead
                        and etok == fence
                        and _dec(readers) == 0
                        and _FREE_AT < eexp
                        and now < eexp
                    ):
                        if self.mem.auto_cas(
                            p, st.expires, (etok, readers, eexp),
                            (etok, readers, now + ttl),
                        ) == (etok, readers, eexp):
                            writes = [("write", st.holder, p.pid)]
                            got = Lease(key, shard.index, p.pid, etok,
                                        now + ttl, ttl, LeaseMode.EXCLUSIVE,
                                        _infl(readers))
                finally:
                    alock.unlock(p, piggyback=writes)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        with shard._meta:
            shard.orphan_probes += 1
            if got is not None:
                shard.orphan_adopts += 1
                shard.reclaims += 1
        return got

    def reconstruct_shard(self, p: Process, shard_index: int,
                          records: Iterable, fence_slack: int = 16,
                          ) -> Dict[str, int]:
        """Audit-and-repair one shard's registers after a home-host restart.

        ``records`` is the merged record stream from surviving clients'
        ledgers (duck-typed: anything with ``op``/``key``/``token``/
        ``expires_at`` — see ``repro.coord.ledger.LedgerRecord``).  For
        every ledgered key homed on this shard, under the shard ALock:

        * **intact** — the fence register matches the word's generation and
          is at least the largest token any ledger has seen: nothing to do.
        * **fence_repaired** — the word still carries a ledger-live lease
          but the fence register lagged (lost with the host): the fence is
          re-seeded from the word, preserving the lease (its holder can
          still reclaim it).
        * **reset** — anything else (word and fence disagree with the
          ledgers): the key is re-seeded FREE under a fence advanced past
          everything observed **plus ``fence_slack``**, covering grants
          that died unrecorded in the pre-ledger window — so no
          post-reconstruction grant can ever reuse a token some downstream
          resource has already honored.

        Returns the per-action counts.  Token monotonicity is the one
        invariant reconstruction must preserve at all costs; availability
        of individual leases is sacrificed whenever the state cannot be
        trusted (a reset key's holder simply re-acquires).
        """
        shard = self.shards[shard_index]
        ledger_max: Dict[str, int] = {}
        grants: Dict[str, Dict[int, tuple]] = {}
        tombs: Dict[str, set] = {}
        for rec in records:
            key = rec.key
            if not key or rec.op not in ("grant", "reclaim", "renew",
                                         "release", "lost"):
                continue
            if self.shard_of(key) != shard_index:
                continue
            if rec.token > ledger_max.get(key, 0):
                ledger_max[key] = rec.token
            if rec.op in ("grant", "reclaim"):
                grants.setdefault(key, {})[rec.token] = (rec.token,
                                                         rec.expires_at)
            elif rec.op == "renew":
                cur = grants.get(key, {}).get(rec.token)
                if cur is not None and rec.expires_at > cur[1]:
                    grants[key][rec.token] = (rec.token, rec.expires_at)
            else:  # release / lost
                tombs.setdefault(key, set()).add(rec.token)
        report = {"intact": 0, "fence_repaired": 0, "reset": 0}
        for key in sorted(ledger_max):
            # The plausibly-live generation: the largest untombstoned grant
            # (cross-ledger merge order is not time order, so selection is
            # by token — tokens ARE the time order).
            live_tok = max(
                (t for t in grants.get(key, {}) if t not in tombs.get(key, set())),
                default=None,
            )
            st = self._key_state(shard, key)
            snap = p.counts.as_tuple()
            writes: List[tuple] = []
            action = "reset"
            try:
                alock = shard.alock  # pin across a concurrent takeover
                alock.lock(p)
                try:
                    now = self.clock()
                    _holder, (etok, readers, eexp), fence, _barrier = \
                        self._read_key_state(p, shard, st)
                    lmax = ledger_max[key]
                    word_live = _FREE_AT < eexp and now < eexp
                    if etok == fence and fence >= lmax:
                        action = "intact"  # registers survived the restart
                    elif (live_tok is not None and etok == live_tok
                          and word_live and fence <= etok and etok >= lmax):
                        # The word is authoritative for a ledger-live lease;
                        # only the fence register lagged.  Re-seed it from
                        # the word — the lease stays reclaimable.
                        writes = [("write", st.fence, etok)]
                        action = "fence_repaired"
                    else:
                        nf = max(fence, etok, lmax) + fence_slack
                        packed = (etok, readers, eexp)
                        # CAS, not write (the word is CAS-only: a CS-free
                        # shared join can land between read and commit);
                        # a lost race re-reads and retries — the joiner
                        # reused the same untrusted generation, which is
                        # exactly what the reset must displace.
                        for _ in range(_FAST_ATTEMPTS):
                            if self.mem.auto_cas(
                                p, st.expires, packed, (nf, 0, _FREE_AT),
                            ) == packed:
                                writes = [
                                    ("write", st.fence, nf),
                                    ("write", st.holder, _NO_HOLDER),
                                    ("write", st.intent, _FREE_AT),
                                ]
                                if st.infl is not None:
                                    # Re-seeded FREE and DEFLATED: a reset
                                    # key's queue state is as untrusted as
                                    # its registers were.
                                    st.infl = None
                                    if self._estimator is not None:
                                        self._estimator.mark_deflated(
                                            key, now)
                                    self._log_infl_event(now, "deflate",
                                                         key, nf,
                                                         "reconstruct")
                                    with shard._meta:
                                        shard.deflations += 1
                                break
                            packed = self.mem.auto_read(p, st.expires)
                            self.mem.yield_point()
                finally:
                    alock.unlock(p, piggyback=writes or None)
            finally:
                self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            report[action] += 1
        with shard._meta:
            shard.reconstructions += sum(report.values())
            shard.reconstruct_resets += report["reset"]
        return report

    def takeover_shard(self, p: Process, shard_index: int,
                       records: Iterable,
                       membership=None, fence_slack: int = 16,
                       ) -> Optional[Dict[str, int]]:
        """Epoch-fenced automatic takeover of a dead home's shard.

        The successor (``p`` must run ON the new home) re-homes the shard
        onto its own host: unlike :meth:`reconstruct_shard` — which audits
        the *surviving* registers after the home restarts — takeover cannot
        touch the old registers at all (they died with the host), so it
        rebuilds the shard from the merged ledger stream alone.  The
        sequence, in fencing order:

        1. **Partition guard** — if ``membership`` is given (duck-typed:
           ``can_serve()`` / ``confirm_dead(host)``), refuse without a live
           majority attestation: a minority island must degrade to
           read-only lease validation, never re-home shards.
        2. **Epoch CAS** — bump the shard's epoch register, which lives on
           the rank-order first successor rather than the home exactly so
           it survives the home's death.  Losing the CAS means another
           successor already owns the rebuild: abort.
        3. **Liveness re-probe** — after winning the epoch, re-probe the
           "dead" host's member lease: a live unexpired word means we were
           on the wrong side of a heal (the burned epoch is harmless — it
           only ever fences grants *we* would have made).
        4. **Rebuild** — fold the ledgers exactly like reconstruction:
           a key whose largest ledgered token is an unexpired, untombstoned
           EXCLUSIVE grant is installed *intact* on the new home (word,
           fence, and holder match the lease — the third-party holder's
           witness CASes keep working across the re-homing); every other
           ledgered key is re-seeded FREE under a fence advanced
           ``fence_slack`` past everything observed (covering grants that
           died unrecorded — same token-monotonicity posture as
           reconstruction; shared generations are reset, readers issue no
           fenced writes and simply re-join).  All registers (including a
           fresh ALock) carry epoch-suffixed names; keys never ledgered by
           any surviving client are lost with the host.
        5. **Tombstones + forwarding** — one probe decides reachability of
           the deposed home; if it answers (deposed-but-alive, e.g. healed
           partition loser), every old key word is tombstoned with a
           never-expiring sentinel generation and its holder register
           becomes a forwarding pointer to the new home; the shard's
           forwarding register (next to the epoch register) is updated
           either way.  If the probe times out the old registers are
           unreachable garbage and the epoch fence alone handles zombies.
        6. **Swap** — home_host / keys / ALock / epoch swing in one
           ``_meta``-guarded step; in-flight transactions pinned to the old
           ALock drain against dead registers and are discarded by
           :meth:`_epoch_fence`.

        Returns the rebuild report, or ``None`` on refusal/abort.
        """
        shard = self.shards[shard_index]
        new_home = p.node
        old_home = shard.home_host
        if new_home == old_home:
            raise ValueError("takeover_shard: successor must be a new home "
                             "(use reconstruct_shard after a home restart)")
        snap = p.counts.as_tuple()
        try:
            if membership is not None and not membership.can_serve():
                with shard._meta:
                    shard.takeover_refusals += 1
                return None
            # Witness reachability is decided by a non-blocking probe: a
            # takeover must never ride the fabric's heal-wait across a
            # cut.  One atomic recovery step spanning a heal would read a
            # post-heal view in which the "dead" host's renewals could
            # not yet have landed — and the liveness re-probe below would
            # wrongly confirm.  Unreachable witness: retry next sweep.
            # The probe may hedge one re-posting under overload control: a
            # takeover stalled on one lost witness probe delays every
            # client of the dead home's shards.
            if self._probe(p, shard.epoch_reg, shard) is TIMEOUT:
                with shard._meta:
                    shard.takeover_aborts += 1
                return None
            # The epoch register is authoritative (the python-side
            # shard.epoch mirror only advances on commit: aborted attempts
            # burn register epochs without un-fencing anything).
            reg_epoch = self.mem.auto_read(p, shard.epoch_reg)
            if self.mem.auto_cas(p, shard.epoch_reg, reg_epoch,
                                 reg_epoch + 1) != reg_epoch:
                with shard._meta:
                    shard.takeover_aborts += 1
                return None
            new_epoch = reg_epoch + 1
            if membership is not None and not membership.confirm_dead(old_home):
                with shard._meta:
                    shard.takeover_aborts += 1
                return None

            # ---- ledger fold (same selection rules as reconstruct_shard)
            ledger_max: Dict[str, int] = {}
            grants: Dict[str, Dict[int, tuple]] = {}
            tombs: Dict[str, set] = {}
            for rec in records:
                key = rec.key
                if not key or rec.op not in ("grant", "reclaim", "renew",
                                             "release", "lost"):
                    continue
                if self.shard_of(key) != shard_index:
                    continue
                if rec.token > ledger_max.get(key, 0):
                    ledger_max[key] = rec.token
                if rec.op in ("grant", "reclaim"):
                    grants.setdefault(key, {})[rec.token] = (
                        rec.token, rec.expires_at, rec.pid, rec.mode)
                elif rec.op == "renew":
                    cur = grants.get(key, {}).get(rec.token)
                    if cur is not None and rec.expires_at > cur[1]:
                        grants[key][rec.token] = (rec.token, rec.expires_at,
                                                  cur[2], cur[3])
                else:  # release / lost
                    tombs.setdefault(key, set()).add(rec.token)

            # ---- rebuild on the new home (all ops local to `p`)
            prefix = f"{self.name}.s{shard_index}.e{new_epoch}"
            new_alock = ALock(self.mem, new_home, shard.init_budget,
                              name=prefix)
            new_keys: Dict[str, _KeyState] = {}
            now = self.clock()
            report = {"epoch": new_epoch, "intact": 0, "reset": 0,
                      "tombstoned": 0}
            for key in sorted(ledger_max):
                live_tok = max(
                    (t for t in grants.get(key, {})
                     if t not in tombs.get(key, set())),
                    default=None,
                )
                lmax = ledger_max[key]
                st = _KeyState(self.mem, new_home,
                               f"{prefix}.k{stable_key_hash(key):016x}")
                live = (live_tok is not None and live_tok == lmax
                        and grants[key][live_tok][3] == int(LeaseMode.EXCLUSIVE)
                        and grants[key][live_tok][1] > now)
                if live:
                    tok, exp, pid, _m = grants[key][live_tok]
                    self.mem.write(p, st.expires, (tok, 0, exp))
                    self.mem.write(p, st.fence, tok)
                    self.mem.write(p, st.holder, pid)
                    report["intact"] += 1
                else:
                    nf = lmax + fence_slack
                    self.mem.write(p, st.expires, (nf, 0, _FREE_AT))
                    self.mem.write(p, st.fence, nf)
                    report["reset"] += 1
                new_keys[key] = st

            # ---- tombstone the deposed home's registers, if it answers
            old_keys = dict(shard.keys)
            if old_keys:
                first = next(iter(old_keys.values()))
                if self._probe(p, first.expires, shard) is not TIMEOUT:
                    try:
                        self.mem.post_batch(p, [
                            w for ost in old_keys.values()
                            for w in (("write", ost.expires,
                                       (_TOMB_TOKEN, 0, _TOMB_AT)),
                                      ("write", ost.holder,
                                       _fwd_enc(new_home)))
                        ])
                        report["tombstoned"] = len(old_keys)
                    except RemoteTimeout:
                        pass  # it died under us: the epoch fence suffices
            self.mem.auto_write(p, shard.fwd_reg, new_home)

            # ---- commit: one atomic swap, then the epoch fence is live
            with shard._meta:
                shard.home_host = new_home
                shard.alock = new_alock
                shard.keys = new_keys
                shard.epoch = new_epoch
                shard.takeovers += 1
                shard.rehomed_keys += len(new_keys)
                shard.reconstructions += report["intact"] + report["reset"]
                shard.reconstruct_resets += report["reset"]
            return report
        finally:
            # Classified by hand: the commit flips home_host to p.node, so
            # _account would file the successor's recovery ops (epoch CAS
            # on the witness, tombstones on the deposed home) as LOCAL.
            # Takeover traffic is remote by construction — the guard above
            # rejects p.node == old_home.
            with shard._meta:
                shard.stats[REMOTE].add_since(p.counts, snap)
                shard.mode_stats[(LeaseMode.EXCLUSIVE, REMOTE)].add_since(
                    p.counts, snap)

    # --------------------------------------------------------------- batches
    def batch_order(self, keys: Iterable[str]) -> List[str]:
        """The deadlock-avoidance total order:
        ``(shard_of(key) % num_hosts, shard_of(key), key)``.

        Primary-by-**static-home** (the shard's placement-time host,
        ``shard % num_hosts`` — a pure function of the key, identical in
        every process, never moved by a takeover), so shard groups homed
        on the same fabric peer are *adjacent* and ``acquire_batch`` can
        chain their WR lists into one posting per destination host.  Any
        total order all clients share preserves deadlock freedom; this one
        additionally makes the doorbell merge order-compliant.
        """
        nh = self.num_hosts
        return sorted(
            set(keys),
            key=lambda k: (self.shard_of(k) % nh, self.shard_of(k), k))

    def acquire_batch(self, p: Process, keys: Sequence[str], ttl: float,
                      timeout: Optional[float] = None,
                      poll: float = 0.0005,
                      mode: LeaseMode = LeaseMode.EXCLUSIVE,
                      deadline: Optional[float] = None) -> List[Lease]:
        """Acquire every key (deduplicated) in the global key order.

        Keys are grouped by shard (the global order is primary-by-shard, so
        groups are contiguous); EXCLUSIVE groups take each shard's ALock
        **once** for all of that shard's keys — O(distinct shards) critical
        sections instead of O(keys), with the group's register reads and
        writes each coalesced into one doorbell for remote clients — while
        SHARED groups join each key's cohort CS-free.  Deadlock freedom is
        preserved: grants still happen in the global order, and a blocked
        key is waited on *outside* the critical section while holding only
        smaller keys.

        All-or-nothing: ``timeout`` (relative) and/or ``deadline``
        (absolute, the earlier wins) bound the *whole batch*; on expiry,
        already-granted leases are released and ``TimeoutError`` is raised
        (:class:`~repro.core.DeadlineExceeded` when the bound came from an
        explicit ``deadline``).  Backoff sleeps never overshoot the
        remaining budget.  A ``RemoteTimeout`` that escapes the fabric's
        bounded retries mid-batch triggers the same suffix rollback: the
        held prefix is released best-effort (a release that itself times
        out is abandoned to TTL expiry — reclaimable via the ledger), so
        no grant is left held by a caller that reported failure.
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        ordered = self.batch_order(keys)
        explicit = deadline is not None
        if timeout is not None:
            tdl = self.clock() + timeout
            deadline = tdl if deadline is None else min(deadline, tdl)
        if explicit and ordered:
            # Entered past the deadline: fail fast before granting (and
            # then rolling back) a prefix nobody can use.
            self._deadline_gate("acquire_batch", ordered[0],
                                self.shards[self.shard_of(ordered[0])],
                                deadline)
        held: List[Lease] = []
        try:
            i, n = 0, len(ordered)
            while i < n:
                # One *run*: the maximal span of consecutive shard groups
                # sharing a (runtime) home host.  The static-home-major
                # order makes same-home groups adjacent, so an EXCLUSIVE
                # run transacts them together — the cross-shard-group WR
                # lists chain into one posting per destination host
                # instead of one commit doorbell per group.  SHARED mode
                # keeps per-group processing (CS-free joins have nothing
                # to merge).
                home = self.shards[self.shard_of(ordered[i])].home_host
                j = i + 1
                if mode == LeaseMode.EXCLUSIVE:
                    while (j < n and self.shards[
                            self.shard_of(ordered[j])].home_host == home):
                        j += 1
                else:
                    sidx = self.shard_of(ordered[i])
                    while j < n and self.shard_of(ordered[j]) == sidx:
                        j += 1
                run_keys = ordered[i:j]
                start = 0
                delay = poll
                while start < len(run_keys):
                    rem = run_keys[start:]
                    groups: List[Tuple[LockShard, List[str]]] = []
                    a = 0
                    while a < len(rem):
                        sidx = self.shard_of(rem[a])
                        b = a + 1
                        while b < len(rem) and self.shard_of(rem[b]) == sidx:
                            b += 1
                        groups.append((self.shards[sidx], rem[a:b]))
                        a = b
                    epochs = {sh.index: sh.epoch for sh, _ in groups}
                    if mode == LeaseMode.SHARED or len(groups) == 1:
                        granted, blocked = self._acquire_group(
                            p, groups[0][0], groups[0][1], ttl, mode)
                    else:
                        granted, blocked = self._acquire_run(p, groups, ttl)
                    # Epoch fencing, run-aware: grants land as a prefix of
                    # ``rem``, but the fence discards per *shard* — a
                    # surviving grant sitting past a discarded one would
                    # break the held-prefix invariant, so release it and
                    # resume the retry loop at the first discard.
                    resume: Optional[int] = None
                    survivors: List[Tuple[int, Lease]] = []
                    for gi, g in enumerate(granted):
                        fenced = self._epoch_fence(
                            p, self.shards[g.shard], epochs[g.shard], g)
                        if fenced is None:
                            if resume is None:
                                resume = gi
                        else:
                            survivors.append((gi, fenced))
                    if resume is None:
                        held.extend(g for _gi, g in survivors)
                        start += len(granted)
                        progressed = bool(granted)
                    else:
                        for gi, g in survivors:
                            if gi < resume:
                                held.append(g)
                            else:
                                try:
                                    self.release(p, g)
                                except RemoteTimeout:
                                    pass
                        start += resume
                        progressed = resume > 0
                    if progressed:
                        delay = poll  # progress: reset the backoff ladder
                    if blocked and start < len(run_keys):
                        shard = self.shards[self.shard_of(run_keys[start])]
                        now = self.clock()
                        # >= not >: see acquire — the clamp can land the
                        # clock exactly on the deadline.
                        if deadline is not None and now >= deadline:
                            with shard._meta:
                                shard.deadline_exceeded += 1
                            if explicit:
                                raise DeadlineExceeded(
                                    f"batch lease on {run_keys[start]!r}: "
                                    f"deadline passed")
                            raise TimeoutError(
                                f"batch lease on {run_keys[start]!r} not "
                                f"granted in {timeout}s"
                            )
                        # Same seeded-jitter exponential backoff as
                        # ``acquire`` (see there for the rationale), clamped
                        # to the batch's remaining budget.
                        slp = delay * (0.5 + self._rng.random())
                        if deadline is not None:
                            slp = min(slp, max(0.0, deadline - now))
                        self.sleep(slp)
                        delay = min(delay * 2.0, poll * _BACKOFF_CAP_POLLS)
                i = j
                if i < n:
                    # Between two host runs: a prefix of the batch is
                    # held; death here abandons it under a dead pid (the
                    # recoverable client's dangling intents drive the
                    # orphan probe on restart).
                    self._crash_point("batch.mid", p)
        except (TimeoutError, RemoteTimeout, Overloaded):
            # All-or-nothing rollback (TimeoutError covers DeadlineExceeded).
            # Releases are best-effort: over a faulty fabric the rollback
            # itself can time out, and an unreleased lease merely waits out
            # its TTL (no orphan — the ledger, if any, still witnesses it).
            for lease in held:
                try:
                    self.release(p, lease)
                except RemoteTimeout:
                    pass
            raise
        return held

    def release_batch(self, p: Process, leases: Sequence[Lease]) -> int:
        """Release a batch (any order); returns how many were still current.

        Mirrors ``acquire_batch``'s shard grouping: leases are grouped by
        shard, each group's EXCLUSIVE fast-path CASes are coalesced into
        **one doorbell** for remote clients (one posting for the whole
        group instead of one per lease), SHARED releases batch their cohort
        reads and decrement CASes the same way, and whatever falls off the
        fast path is settled under **one** shard ALock critical section per
        group — the exact structure the old per-key loop paid for K times.
        """
        by_shard: Dict[int, List[Lease]] = {}
        for lease in leases:
            by_shard.setdefault(lease.shard, []).append(lease)
        released = 0
        # Cross-shard-group coalescing (the release half of the batch
        # doorbell fix): exclusive witness CASes carry no ordering
        # constraint, so every shard group homed on the same REMOTE host
        # posts its fast-path CASes in ONE doorbell for the whole cluster.
        by_home: Dict[int, List[int]] = {}
        for sidx in sorted(by_shard):
            by_home.setdefault(self.shards[sidx].home_host, []).append(sidx)
        for home in sorted(by_home):
            sidxs = by_home[home]
            if p.node != home and len(sidxs) > 1:
                released += self._release_cluster(p, sidxs, by_shard)
            else:
                for sidx in sidxs:
                    released += self._release_group(
                        p, self.shards[sidx], by_shard[sidx])
        return released

    def _release_cluster(self, p: Process, sidxs: Sequence[int],
                         by_shard: Dict[int, List[Lease]]) -> int:
        """Release several shard groups homed on one remote host: one
        merged witness-CAS posting for every group's EXCLUSIVE fast path,
        then the usual per-shard slow/shared settlement for the rest."""
        excl: List[Tuple[LockShard, Lease, _KeyState]] = []
        for sidx in sidxs:
            shard = self.shards[sidx]
            for lease in by_shard[sidx]:
                if lease.mode == LeaseMode.EXCLUSIVE:
                    excl.append((shard, lease,
                                 self._key_state(shard, lease.key)))
        released = 0
        slow: Dict[int, List[Lease]] = {}
        handoffs: List[Tuple[LockShard, _KeyState, Lease]] = []
        if excl:
            snap = p.counts.as_tuple()
            try:
                observed = self.mem.post_batch(p, [
                    ("cas", st.expires, lease.witness(),
                     (lease.token, _enc(0, lease.inflated), _FREE_AT))
                    for _sh, lease, st in excl
                ])
            finally:
                # Merged posting: accounted to the cluster's first shard
                # (same host, same class — totals stay exact).
                self._account(excl[0][0], p, snap, LeaseMode.EXCLUSIVE)
            nfast: Dict[int, int] = {}
            for (shard, lease, st), obs in zip(excl, observed):
                if obs == lease.witness():
                    nfast[shard.index] = nfast.get(shard.index, 0) + 1
                    if lease.inflated:
                        handoffs.append((shard, st, lease))
                else:
                    slow.setdefault(shard.index, []).append(lease)
            for sidx, cnt in nfast.items():
                with self.shards[sidx]._meta:
                    self.shards[sidx].fast_releases += cnt
                released += cnt
            for shard, st, lease in handoffs:
                self._inflated_handoff(p, shard, st, lease.key, lease)
            for sidx in sidxs:
                if sidx in slow:
                    released += self._release_group_slow(
                        p, self.shards[sidx], slow[sidx])
        for sidx in sidxs:
            shrd = [l for l in by_shard[sidx]
                    if l.mode == LeaseMode.SHARED]
            if shrd:
                released += self._release_group_shared(
                    p, self.shards[sidx], shrd)
        return released

    def _release_group(self, p: Process, shard: LockShard,
                       group: Sequence[Lease]) -> int:
        local = p.node == shard.home_host
        released = 0
        # --- EXCLUSIVE leases: witness CASes, one doorbell for the group.
        excl = [l for l in group if l.mode == LeaseMode.EXCLUSIVE]
        slow: List[Lease] = []
        handoffs: List[Tuple[_KeyState, Lease]] = []
        if excl:
            snap = p.counts.as_tuple()
            nfast = 0
            try:
                sts = [self._key_state(shard, l.key) for l in excl]
                if local:
                    observed = [
                        self.mem.cas(p, st.expires, l.witness(),
                                     (l.token, _enc(0, l.inflated), _FREE_AT))
                        for st, l in zip(sts, excl)
                    ]
                else:
                    observed = self.mem.post_batch(p, [
                        ("cas", st.expires, l.witness(),
                         (l.token, _enc(0, l.inflated), _FREE_AT))
                        for st, l in zip(sts, excl)
                    ])
                for lease, st, obs in zip(excl, sts, observed):
                    if obs == lease.witness():
                        nfast += 1
                        if lease.inflated:
                            handoffs.append((st, lease))
                    else:
                        slow.append(lease)
            finally:
                self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            with shard._meta:
                shard.fast_releases += nfast
            released += nfast
            for st, lease in handoffs:
                self._inflated_handoff(p, shard, st, lease.key, lease)
            if slow:
                released += self._release_group_slow(p, shard, slow)
        # --- SHARED leases: cohort reads + decrement CASes, batched.
        shrd = [l for l in group if l.mode == LeaseMode.SHARED]
        if shrd:
            released += self._release_group_shared(p, shard, shrd)
        return released

    def _release_group_slow(self, p: Process, shard: LockShard,
                            group: Sequence[Lease]) -> int:
        """Slow-path releases for one shard, in ONE critical section."""
        states = [self._key_state(shard, l.key) for l in group]
        snap = p.counts.as_tuple()
        local = p.node == shard.home_host
        released = 0
        writes: List[tuple] = []
        handoffs: List[Tuple[_KeyState, Lease]] = []
        try:
            alock = shard.alock  # pin: a takeover swaps shard.alock mid-CS
            if local:
                alock.lock(p)
                flat = None
            else:
                flat = alock.lock(p, piggyback_reads=[
                    r for st in states
                    for r in (st.holder, st.expires, st.fence)
                ])
            try:
                if flat is None:
                    if local:
                        vals = [(self.mem.read(p, st.holder),
                                 self.mem.read(p, st.expires),
                                 self.mem.read(p, st.fence))
                                for st in states]
                    else:
                        out = self.mem.post_batch(p, [
                            wr for st in states
                            for wr in (("read", st.holder),
                                       ("read", st.expires),
                                       ("read", st.fence))
                        ])
                        vals = [tuple(out[3 * i:3 * i + 3])
                                for i in range(len(states))]
                else:
                    vals = [tuple(flat[3 * i:3 * i + 3])
                            for i in range(len(states))]
                plan = []  # (st, packed-as-read, release tuple, lease)
                for lease, st, (holder, (etok, readers, eexp), fence) in zip(
                        group, states, vals):
                    if (
                        holder == lease.holder_pid
                        and fence == lease.token
                        and _dec(readers) == 0
                        and not (etok == fence and eexp <= _FREE_AT)
                    ):
                        plan.append((st, (etok, readers, eexp),
                                     (lease.token, readers, _FREE_AT),
                                     lease))
                # Commit by CAS (the word is CAS-only — a CS-free join can
                # land between read and commit); one doorbell for the group.
                if plan:
                    if local:
                        won = [self.mem.cas(p, st.expires, packed, new)
                               == packed for st, packed, new, _l in plan]
                    else:
                        obs = self.mem.post_batch(p, [
                            ("cas", st.expires, packed, new)
                            for st, packed, new, _l in plan
                        ])
                        won = [o == packed
                               for o, (_s, packed, _n, _l) in zip(obs, plan)]
                    for (st, packed, _new, lease), ok in zip(plan, won):
                        if ok:
                            writes.append(("write", st.holder, _NO_HOLDER))
                            released += 1
                            if _infl(packed[1]):
                                handoffs.append((st, lease))
            finally:
                alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        for st, lease in handoffs:
            self._inflated_handoff(p, shard, st, lease.key, lease)
        return released

    def _release_group_shared(self, p: Process, shard: LockShard,
                              group: Sequence[Lease]) -> int:
        """Batched shared releases: one read doorbell + one CAS doorbell for
        the group's first round; CAS losers retry individually (rare — only
        same-key leases in one batch, or an outside racer)."""
        local = p.node == shard.home_host
        released = 0
        if local:
            for lease in group:
                st = self._key_state(shard, lease.key)
                if self._shared_release(p, shard, st, lease):
                    released += 1
            return released
        snap = p.counts.as_tuple()
        retry: List[Lease] = []
        done: List[Lease] = []
        try:
            now = self.clock()
            # The slot-ledger filter applies batch-wide: a decrement the
            # caller does not own (double release, consumed by an upgrade,
            # or a duplicate of an earlier batch entry) is never posted.
            owned: List[Lease] = []
            counted: Dict[Tuple[str, int], int] = {}
            for lease in group:
                if now >= lease.expires_at:
                    continue
                k = (lease.key, lease.token)
                counted[k] = counted.get(k, 0) + 1
                if counted[k] <= self._slot_count(p, lease.key, lease.token):
                    owned.append(lease)
            pending = [(l, self._key_state(shard, l.key)) for l in owned]
            if pending:
                packeds = self.mem.post_batch(
                    p, [("read", st.expires) for _, st in pending])
                wrs, metas = [], []
                for (lease, st), packed in zip(pending, packeds):
                    etok, readers, eexp = packed
                    dec, infl = _dec(readers), _infl(readers)
                    if etok != lease.token or dec <= 0:
                        continue  # generation moved on: nothing to release
                    new = (etok, _enc(dec - 1, infl),
                           eexp if dec > 1 else _FREE_AT)
                    wrs.append(("cas", st.expires, packed, new))
                    metas.append((lease, packed))
                outs = self.mem.post_batch(p, wrs) if wrs else []
                for (lease, packed), obs in zip(metas, outs):
                    if obs == packed:
                        done.append(lease)
                    else:
                        retry.append(lease)
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if done:
            for lease in done:
                self._slot_consume(p, lease.key, lease.token)
            with shard._meta:
                shard.shared_releases += len(done)
            released += len(done)
        for lease in retry:
            st = self._key_state(shard, lease.key)
            if self._shared_release(p, shard, st, lease):
                released += 1
        return released

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> List[Dict]:
        """Per-shard snapshot: placement, grant counters, per-class OpCounts
        (total and per mode)."""
        out = []
        for shard in self.shards:
            with shard._meta:
                out.append({
                    "shard": shard.index,
                    "home_host": shard.home_host,
                    "keys": len(shard.keys),
                    "grants": shard.grants,
                    "rejects": shard.rejects,
                    "grants_shared": shard.grants_by_mode[LeaseMode.SHARED],
                    "grants_exclusive":
                        shard.grants_by_mode[LeaseMode.EXCLUSIVE],
                    "rejects_shared": shard.rejects_by_mode[LeaseMode.SHARED],
                    "rejects_exclusive":
                        shard.rejects_by_mode[LeaseMode.EXCLUSIVE],
                    "expirations": shard.expirations,
                    "fast_renews": shard.fast_renews,
                    "fast_releases": shard.fast_releases,
                    "shared_joins": shard.shared_joins,
                    "shared_renews": shard.shared_renews,
                    "shared_releases": shard.shared_releases,
                    "shared_remote_grants": shard.shared_remote_grants,
                    "shared_acquire_rcas": shard.shared_acquire_rcas,
                    "upgrades": shard.upgrades,
                    "downgrades": shard.downgrades,
                    "intent_blocks": shard.intent_blocks,
                    "repairs": shard.repairs,
                    "reclaims": shard.reclaims,
                    "reclaim_fast": shard.reclaim_fast,
                    "reclaim_slow": shard.reclaim_slow,
                    "reclaim_shared": shard.reclaim_shared,
                    "reclaim_rejects": shard.reclaim_rejects,
                    "orphan_probes": shard.orphan_probes,
                    "orphan_adopts": shard.orphan_adopts,
                    "reconstructions": shard.reconstructions,
                    "reconstruct_resets": shard.reconstruct_resets,
                    "epoch": shard.epoch,
                    "takeovers": shard.takeovers,
                    "takeover_refusals": shard.takeover_refusals,
                    "takeover_aborts": shard.takeover_aborts,
                    "epoch_aborts": shard.epoch_aborts,
                    "rehomed_keys": shard.rehomed_keys,
                    "inflations": shard.inflations,
                    "deflations": shard.deflations,
                    "queue_enqueues": shard.queue_enqueues,
                    "queue_grants": shard.queue_grants,
                    "queue_handoffs": shard.queue_handoffs,
                    "queue_bypasses": shard.queue_bypasses,
                    "contended_keys": len(shard.key_retries),
                    "blocked_attempts": sum(shard.key_retries.values()),
                    # Overload-protection counters (PR 9): the shard-side
                    # (shed/deadline/hedge) half; the breaker/budget half
                    # lives on table.overload.report().
                    "sheds": shard.sheds,
                    "hedges": shard.hedges,
                    "deadline_exceeded": shard.deadline_exceeded,
                    # Optimistic-read (seqlock) counters (PR 10).
                    "opt_reads": shard.opt_reads,
                    "opt_read_retries": shard.opt_read_retries,
                    "opt_read_fallbacks": shard.opt_read_fallbacks,
                    "opt_read_fwd": shard.opt_read_fwd,
                    "publishes": shard.publishes,
                    "timeouts": (shard.stats[LOCAL].timeouts
                                 + shard.stats[REMOTE].timeouts),
                    "fabric_retries": (shard.stats[LOCAL].retries
                                       + shard.stats[REMOTE].retries),
                    "local": shard.stats[LOCAL].snapshot(),
                    "remote": shard.stats[REMOTE].snapshot(),
                    "shared_local":
                        shard.mode_stats[(LeaseMode.SHARED, LOCAL)].snapshot(),
                    "shared_remote":
                        shard.mode_stats[(LeaseMode.SHARED, REMOTE)].snapshot(),
                    "exclusive_local":
                        shard.mode_stats[(LeaseMode.EXCLUSIVE, LOCAL)].snapshot(),
                    "exclusive_remote":
                        shard.mode_stats[(LeaseMode.EXCLUSIVE, REMOTE)].snapshot(),
                })
        return out

    def queued(self, p: Process, key: str) -> bool:
        """Is ``p`` parked in ``key``'s inflated-mode queue?  Host-side
        metadata check, zero simulated ops — clients use it to pick their
        retry cadence: a queued waiter's poll is ONE local read (the MCS
        local spin), so it polls fine-grained instead of exponentially
        backing off like a CAS-word contender."""
        ws = self._waits.get(p.pid, {}).get(key)
        if ws is None:
            return False
        st = self.shards[self.shard_of(key)].keys.get(key)
        return st is not None and st.infl is ws[0]

    def hot_keys(self, k: int = 10) -> List[List]:
        """Top-``k`` keys by blocked-attempt count across all shards, as
        ``[key, blocked_attempts, op_timeouts, fabric_retries]`` rows
        (count-desc, then key — a total order, so the report is
        deterministic).  The two fabric columns surface WHERE the op
        timeouts and fabric-level retry rounds (already counted in the
        per-class OpCounts) actually landed — a congested home's keys show
        fabric pain even when they are not CAS-contended."""
        merged: Dict[str, int] = {}
        t_merged: Dict[str, int] = {}
        r_merged: Dict[str, int] = {}
        for shard in self.shards:
            with shard._meta:
                for key, n in shard.key_retries.items():
                    merged[key] = merged.get(key, 0) + n
                for key, n in shard.key_timeouts.items():
                    t_merged[key] = t_merged.get(key, 0) + n
                    merged.setdefault(key, 0)
                for key, n in shard.key_fab_retries.items():
                    r_merged[key] = r_merged.get(key, 0) + n
                    merged.setdefault(key, 0)
        ranked = sorted(merged.items(), key=lambda kv: (-kv[1], kv[0]))
        return [[key, n, t_merged.get(key, 0), r_merged.get(key, 0)]
                for key, n in ranked[:k]]

    def inflation_log(self) -> List[List]:
        """The inflate/deflate event log, in decision order: rows of
        ``[t, action, key, token, reason]``.  Same-seed sim runs produce
        byte-identical logs (the CI determinism gate relies on it)."""
        with self._infl_guard:
            return [list(row) for row in self._infl_events]

    def class_totals(self) -> Dict[int, OpCounts]:
        """Aggregate per-class OpCounts across all shards."""
        totals = {LOCAL: OpCounts(), REMOTE: OpCounts()}
        for shard in self.shards:
            with shard._meta:
                for cls in (LOCAL, REMOTE):
                    totals[cls] = totals[cls] + shard.stats[cls]
        return totals

    def mode_class_totals(self) -> Dict[LeaseMode, Dict[int, OpCounts]]:
        """Aggregate per-(mode, class) OpCounts across all shards."""
        totals = {m: {LOCAL: OpCounts(), REMOTE: OpCounts()}
                  for m in LeaseMode}
        for shard in self.shards:
            with shard._meta:
                for m in LeaseMode:
                    for cls in (LOCAL, REMOTE):
                        totals[m][cls] = (totals[m][cls]
                                          + shard.mode_stats[(m, cls)])
        return totals
