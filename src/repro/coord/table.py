"""Sharded asymmetric lock table: the paper's per-class cost optimality,
applied to a whole keyspace instead of one record.

A single :class:`~repro.core.ALock` makes exactly one host the privileged
"local" class; everyone else pays fabric operations.  That is the right shape
for one hot record, but a control plane serving millions of keys wants the
privilege *spread out*: partition the keyspace into ``num_shards`` shards,
home shard ``s`` on host ``s % num_hosts`` (a stable hash, so placement never
depends on interpreter state), and guard each shard's lease metadata with its
own ALock.  Every host is then the zero-RDMA local class for its slice of the
keyspace, and the paper's cost claims hold *per shard*: a client transacting
on keys homed on its own host issues **zero** simulated RDMA operations, and
a remote client pays the ALock's bounded budget.

Layered on the shard locks is a **lease table** (the long-lived exclusion):

* ``try_acquire(p, key, ttl)`` grants a :class:`Lease` with a monotonically
  increasing **fencing token** per key.  The shard's ALock is held only for
  the short metadata transaction — the lease itself is what excludes other
  clients, so a crashed holder can never wedge the shard: its lease expires
  after ``ttl`` and the next grant carries a larger token, which downstream
  resources use to reject the crashed holder's stale writes.
* ``acquire_batch(p, keys, ttl)`` takes multiple leases in the **global key
  order** ``(shard_of(key), key)``.  All batched clients walk the same total
  order, so no cycle of waiters can form — deadlock freedom without a
  detector (see ``docs/lock-table.md``).

**Lease modes** (see the "Lease modes" section of ``docs/lock-table.md``):
every lease is either :data:`LeaseMode.EXCLUSIVE` (one writer) or
:data:`LeaseMode.SHARED` (a cohort of readers).  The per-key expiry register
packs ``(writer_fence_token, reader_count, expires_at)`` so that a shared
grant is a *single CAS* on one word — readers never take the shard ALock at
all: zero simulated RDMA ops for a home-host reader, one rCAS per attempt
for a remote one (exactly one uncontended and under the sim engine's atomic
steps; a threaded CAS race retries, bounded by the fast-attempt cap).  Reader generations reuse the last CS-allocated token (readers
issue no fenced downstream writes), writer grants still allocate strictly
increasing tokens inside the critical section, and a queued writer **drains**
a live reader cohort through a lease-like intent barrier: new joins and
shared renewals are refused while the barrier is armed, so the cohort dries
up within one TTL and the writer's grant latency is bounded.

Hot-path optimisations (see the "Hot path" section of ``docs/lock-table.md``):

* **Renewal/release fast path** — the current holder extends or drops its
  lease with a single fencing-token-checked CAS on the expiry register,
  *without* taking the shard ALock: zero simulated RDMA ops for local
  holders, exactly one rCAS for remote holders.  The expiry register packs
  ``(fence_token, readers, expires_at)`` so the CAS validates the fence: a
  zombie holder's CAS always loses after a re-grant (the token moved on).
* **Shard-grouped batches** — ``acquire_batch`` holds each shard's ALock
  once for all of that shard's keys (O(distinct shards) critical sections
  instead of O(keys)), still walking the global order; ``release_batch``
  mirrors it, coalescing a shard group's release CASes into one doorbell
  and taking the shard ALock at most once for the group's slow-path leases.
* **Doorbell coalescing** — remote clients post the critical section's
  register reads in one :meth:`~repro.core.AsymmetricMemory.post_batch`
  doorbell and its writes in another, modelling RDMA WR posting lists.

Telemetry: every table operation snapshots the calling process's
:class:`~repro.core.OpCounts` (an O(1) tuple snapshot, accumulated in place —
no per-op dict copies) and adds the delta to the target shard's per-class
(LOCAL/REMOTE) totals — and, since the mode refactor, to the per-mode
per-class totals — so benchmarks and the serving layer can verify the
zero-RDMA home path *per mode* without instrumenting clients.
"""

from __future__ import annotations

import enum
import hashlib
import threading
import time
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import ALock, AsymmetricMemory, OpCounts, Process

from .faults import FaultInjector

LOCAL, REMOTE = 0, 1

_NO_HOLDER = -1

# The expiry register packs (fence_token, reader_count, expires_at).
# expires_at <= FREE_AT means the key is not held (never granted, or
# released); a grant always writes a strictly positive expiry, so the states
# cannot be confused.
_FREE_AT = 0.0

# Bounded optimism: the shared-mode fast paths are read+CAS retry loops (the
# CAS can lose only to another *successful* shared operation, so the system
# as a whole always progresses).  Under the sim engine's atomic steps a
# retry never happens; under threads the cap converts a pathological
# contention storm into a clean reject instead of an unbounded spin.
_FAST_ATTEMPTS = 64


class LeaseMode(enum.IntEnum):
    """S/X lease modes.  SHARED leases form a reader cohort on one packed
    word; EXCLUSIVE leases are the original writer leases."""

    SHARED = 0
    EXCLUSIVE = 1

    @property
    def label(self) -> str:
        return "shared" if self is LeaseMode.SHARED else "exclusive"


SHARED, EXCLUSIVE = LeaseMode.SHARED, LeaseMode.EXCLUSIVE


@lru_cache(maxsize=1 << 17)
def stable_key_hash(key: str) -> int:
    """A process-stable 64-bit hash (Python's ``hash`` is salted per run).

    Cached: placement hashing of a hot key must not recompute blake2b on
    every operation (the cache is per-process and placement is stable, so
    memoisation can never change an answer).
    """
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big"
    )


@dataclass(frozen=True)
class Lease:
    """A granted lease: the unit of long-lived exclusion (or sharing).

    ``token`` is the fencing token — strictly increasing per key across
    *writer* grants, so any resource that records the largest token it has
    seen can reject writes from a holder whose lease has expired and been
    re-granted.  A SHARED lease carries its reader generation's token (the
    last token the critical section allocated): readers issue no fenced
    downstream writes, and the next writer's token is strictly larger than
    every reader generation it displaces.

    ``expires_at`` doubles as the fast-path CAS witness for EXCLUSIVE
    leases: ``renew``/``release`` compare-and-swap the expiry register
    against ``(token, 0, expires_at)``, so hold on to the *latest* lease
    returned by acquire/renew (the :class:`~repro.coord.CoordinationService`
    lease cache does this for you, keyed per mode).  For SHARED leases it is
    the holder's own validity horizon — the packed word tracks the cohort's
    maximum.
    """

    key: str
    shard: int
    holder_pid: int
    token: int
    expires_at: float
    ttl: float
    mode: LeaseMode = LeaseMode.EXCLUSIVE


class _KeyState:
    """Per-key lease registers, allocated on the shard's home node.

    ``holder`` and ``fence`` are read/written **only** inside the shard
    ALock's critical section; ``fence`` is the authoritative token allocator,
    which is why writer grant tokens are strictly monotonic unconditionally.

    ``expires`` packs ``(fence_token, reader_count, expires_at)`` and is the
    one register holders may CAS lock-free: the renewal/release fast path,
    shared joins/leaves, and downgrades all operate on this single word.
    Because remote RMW is not atomic against the critical section's writes
    (Table 1), a **zombie's** in-flight rCAS write phase can, in a vanishing
    window, overwrite a concurrent re-grant's write with its stale tuple.
    The CS-only ``fence`` makes that clobber *detectable* (``expires`` token
    ≠ fence) and *unable to affect token allocation*; grant decisions treat
    a clobbered mirror as expired and repair it (``shard.repairs``
    telemetry).  This is the standard lease-system posture: expiry-time
    races cannot be airtight under asynchrony, fencing tokens are what make
    them harmless downstream — and the tokens themselves never regress.

    ``intent`` is the writer drain barrier: a virtual-time deadline written
    only inside the critical section (by a writer blocked on a live reader
    cohort).  The shared fast paths read it and refuse joins/renewals while
    ``now < intent``, so the cohort drains within one TTL; any writer grant
    clears it.  A stale barrier (the writer timed out or was beaten to the
    grant) simply lapses — no cleanup protocol, same posture as the leases
    themselves.
    """

    __slots__ = ("holder", "expires", "fence", "intent")

    def __init__(self, mem: AsymmetricMemory, node: int, name: str):
        self.holder = mem.alloc(node, f"{name}.holder", _NO_HOLDER)
        self.expires = mem.alloc(node, f"{name}.expires", (0, 0, _FREE_AT))
        self.fence = mem.alloc(node, f"{name}.fence", 0)
        self.intent = mem.alloc(node, f"{name}.intent", _FREE_AT)


class LockShard:
    """One shard: an ALock guarding the lease metadata of its keys."""

    def __init__(self, mem: AsymmetricMemory, index: int, home_host: int,
                 init_budget: int, name: str):
        self.index = index
        self.home_host = home_host
        self.alock = ALock(mem, home_host, init_budget, name=f"{name}.s{index}")
        self.keys: Dict[str, _KeyState] = {}
        # Meta-level accounting (not part of the simulated protocol).
        self.stats = {LOCAL: OpCounts(), REMOTE: OpCounts()}
        self.mode_stats = {(m, c): OpCounts()
                           for m in LeaseMode for c in (LOCAL, REMOTE)}
        self.grants = 0
        self.rejects = 0
        self.grants_by_mode = {m: 0 for m in LeaseMode}
        self.rejects_by_mode = {m: 0 for m in LeaseMode}
        self.expirations = 0
        self.fast_renews = 0
        self.fast_releases = 0
        self.shared_joins = 0        # fast-path shared grants (no ALock)
        self.shared_renews = 0
        self.shared_releases = 0
        self.shared_remote_grants = 0   # shared grants paid for over the fabric
        self.shared_acquire_rcas = 0    # rCAS posted by remote shared acquires
        self.upgrades = 0
        self.downgrades = 0
        self.intent_blocks = 0       # shared ops refused by a writer barrier
        self.repairs = 0  # clobbered expiry mirrors repaired by a grant
        # Crash-recovery counters (the ledger/reclaim stack).
        self.reclaims = 0            # successful reclaims, any path
        self.reclaim_fast = 0        # exclusive witness-CAS reclaims
        self.reclaim_slow = 0        # exclusive word-probe reclaims
        self.reclaim_shared = 0      # shared cohort-slot re-adoptions
        self.reclaim_rejects = 0     # reclaim refused (expired/fenced out)
        self.orphan_probes = 0       # dangling-intent probes run
        self.orphan_adopts = 0       # probes that adopted a lost grant
        self.reconstructions = 0     # keys audited by reconstruct_shard
        self.reconstruct_resets = 0  # keys whose registers were re-seeded
        self._meta = threading.Lock()


class ShardedLockTable:
    """N lock shards spread over the hosts of one asymmetric memory."""

    def __init__(
        self,
        mem: AsymmetricMemory,
        num_shards: Optional[int] = None,
        init_budget: int = 4,
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
        name: str = "table",
        fault: Optional[FaultInjector] = None,
    ):
        self.mem = mem
        self.num_hosts = mem.num_nodes
        self.num_shards = num_shards or 2 * self.num_hosts
        if self.num_shards <= 0:
            raise ValueError("num_shards must be > 0")
        # clock and sleep travel as a pair: the blocking paths compute their
        # deadline on `clock` and back off on `sleep`, so injecting one
        # without the other (the old wall-clock time.sleep next to a fake
        # clock) would stall a poll loop forever — or time out instantly —
        # whenever the two disagree.  The sim engine injects a virtual clock
        # plus a charging sleep; threaded callers get the time module's pair.
        self.clock = clock or time.monotonic
        self.sleep = sleep or time.sleep
        self.name = name
        self.fault = fault
        self.shards = [
            LockShard(mem, s, s % self.num_hosts, init_budget, name)
            for s in range(self.num_shards)
        ]
        # Client-side cohort-slot ledger: pid -> {key: [count, token,
        # horizon]}.  The packed word's reader count is anonymous — a
        # decrement cannot tell WHOSE slot it takes — so the client library
        # must never post one it does not own: a double release (or a renew
        # / release after an upgrade consumed the slot) would otherwise
        # free another live reader's slot and let a writer in beside them.
        # Within one process, slots of the same (key, generation) are
        # fungible: a stale handle releases one of the CALLER'S own slots
        # (self-inflicted, contained) — it can never free another client's.
        # A pid is single-threaded by the spawn contract, so each inner
        # per-pid dict is accessed (and swept, amortised) lock-free by its
        # owner; the guard covers only outer-dict insertion.  Entries die
        # with their horizon, like the service lease cache.
        self._slots: Dict[int, Dict[str, List]] = {}
        self._slots_guard = threading.Lock()

    _SLOTS_SWEEP = 1024

    def _pid_slots(self, p: Process) -> Dict[str, List]:
        slots = self._slots.get(p.pid)
        if slots is None:
            with self._slots_guard:
                slots = self._slots.setdefault(p.pid, {})
        return slots

    def _slot_join(self, p: Process, key: str, token: int,
                   horizon: float) -> None:
        """Record one cohort slot owned by ``p`` on ``key``."""
        slots = self._pid_slots(p)
        if len(slots) >= self._SLOTS_SWEEP:
            now = self.clock()
            for k in [k for k, e in slots.items()
                      if e[0] <= 0 or now >= e[2]]:
                del slots[k]
        entry = slots.get(key)
        if (entry is not None and entry[1] == token
                and self.clock() < entry[2]):
            entry[0] += 1
            entry[2] = max(entry[2], horizon)
        else:
            slots[key] = [1, token, horizon]

    def _slot_count(self, p: Process, key: str, token: int) -> int:
        """How many slots of ``key``'s generation ``token`` does ``p`` own?"""
        entry = self._pid_slots(p).get(key)
        return entry[0] if entry is not None and entry[1] == token else 0

    def _slot_owned(self, p: Process, key: str, token: int) -> bool:
        return self._slot_count(p, key, token) > 0

    def _slot_extend(self, p: Process, key: str, token: int,
                     horizon: float) -> None:
        entry = self._pid_slots(p).get(key)
        if entry is not None and entry[1] == token:
            entry[2] = max(entry[2], horizon)

    def _slot_consume(self, p: Process, key: str, token: int) -> None:
        entry = self._pid_slots(p).get(key)
        if entry is not None and entry[1] == token and entry[0] > 0:
            entry[0] -= 1

    # ---------------------------------------------------------- placement
    def shard_of(self, key: str) -> int:
        """Stable hash placement: same key → same shard, in every process."""
        return stable_key_hash(key) % self.num_shards

    def home_of(self, key: str) -> int:
        """The host that is the zero-RDMA local class for ``key``."""
        return self.shards[self.shard_of(key)].home_host

    def _key_state(self, shard: LockShard, key: str) -> _KeyState:
        st = shard.keys.get(key)
        if st is None:
            with shard._meta:
                st = shard.keys.get(key)
                if st is None:
                    st = _KeyState(
                        self.mem, shard.home_host,
                        f"{self.name}.s{shard.index}.k{stable_key_hash(key):016x}",
                    )
                    shard.keys[key] = st
        return st

    # ------------------------------------------------------ fault injection
    def _crash_point(self, label: str, p: Process) -> None:
        """A labeled crash window (see ``repro.coord.faults``).  Every call
        site sits OUTSIDE the shard ALock's critical section: a holder may
        die at any of them and the shard stays serviceable — leases expire
        (or are reclaimed), the CS is never wedged."""
        if self.fault is not None:
            self.fault.crash_point(label, p.pid)

    # ---------------------------------------------------------- accounting
    def _account(self, shard: LockShard, p: Process, snap: tuple,
                 mode: LeaseMode) -> None:
        cls = LOCAL if p.node == shard.home_host else REMOTE
        with shard._meta:
            shard.stats[cls].add_since(p.counts, snap)
            shard.mode_stats[(mode, cls)].add_since(p.counts, snap)

    # --------------------------------------------------- batched register IO
    def _read_pairs(self, p: Process, shard: LockShard,
                    states: Sequence[_KeyState]) -> List[Tuple[tuple, int]]:
        """Read each key's (expires, fence) — one doorbell for remote clients."""
        if p.node == shard.home_host:
            return [
                (self.mem.read(p, st.expires), self.mem.read(p, st.fence))
                for st in states
            ]
        flat = self.mem.post_batch(
            p,
            [wr for st in states
             for wr in (("read", st.expires), ("read", st.fence))],
        )
        return [(flat[2 * i], flat[2 * i + 1]) for i in range(len(states))]

    def _read_key_state(self, p: Process, shard: LockShard,
                        st: _KeyState) -> Tuple[int, tuple, int, float]:
        """The slow paths' validation read set (holder, expires, fence,
        intent) — one doorbell for remote clients."""
        if p.node == shard.home_host:
            return (self.mem.read(p, st.holder),
                    self.mem.read(p, st.expires),
                    self.mem.read(p, st.fence),
                    self.mem.read(p, st.intent))
        holder, packed, fence, barrier = self.mem.post_batch(p, [
            ("read", st.holder), ("read", st.expires),
            ("read", st.fence), ("read", st.intent),
        ])
        return holder, packed, fence, barrier

    def _shared_read(self, p: Process, shard: LockShard,
                     st: _KeyState) -> Tuple[tuple, int, float]:
        """The shared fast path's read set (expires, fence, intent) — one
        doorbell for remote clients, three machine reads for local ones."""
        if p.node == shard.home_host:
            return (self.mem.read(p, st.expires),
                    self.mem.read(p, st.fence),
                    self.mem.read(p, st.intent))
        packed, fence, barrier = self.mem.post_batch(p, [
            ("read", st.expires), ("read", st.fence), ("read", st.intent),
        ])
        return packed, fence, barrier

    # ------------------------------------------------------- shared fast path
    def _shared_acquire(self, p: Process, shard: LockShard, key: str,
                        ttl: float) -> Optional[Lease]:
        """Grant a SHARED lease with a single CAS on the packed word.

        Joinable states: free, expired (any mode), or a live reader cohort.
        A live writer blocks; an armed writer-intent barrier blocks (drain
        priority); a clobbered mirror (word token ≠ fence) is repaired via
        the critical section like any grant over untrusted state.  The CAS
        either joins the live cohort (count+1, expiry extended to cover this
        reader) or opens a fresh generation (count=1) reusing the last
        CS-allocated token — token allocation stays CS-only, so writer
        tokens remain strictly monotonic and are always strictly larger
        than any reader generation they displace.
        """
        st = self._key_state(shard, key)
        snap = p.counts.as_tuple()
        local = p.node == shard.home_host
        lease: Optional[Lease] = None
        intent_block = False
        repair = False
        expired_over = False
        rcas_posted = 0
        try:
            for _ in range(_FAST_ATTEMPTS):
                now = self.clock()
                packed, fence, barrier = self._shared_read(p, shard, st)
                etok, readers, eexp = packed
                if now < barrier:
                    intent_block = True  # a writer is draining this key
                    break
                if etok != fence:
                    repair = True  # untrusted mirror: go repair via the CS
                    break
                free = eexp <= _FREE_AT
                live = (not free) and now < eexp
                if live and readers == 0:
                    break  # a live writer holds the key
                if live:  # join the live reader cohort
                    new = (etok, readers + 1, max(eexp, now + ttl))
                else:     # open a fresh generation over free/expired state
                    new = (etok, 1, now + ttl)
                observed = self.mem.auto_cas(p, st.expires, packed, new)
                if not local:
                    rcas_posted += 1
                if observed == packed:
                    lease = Lease(key, shard.index, p.pid, etok, now + ttl,
                                  ttl, LeaseMode.SHARED)
                    expired_over = (not free) and not live
                    break
                self.mem.yield_point()  # lost to another shared CAS: retry
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if repair:
            return self._shared_repair_grant(p, shard, key, st, ttl,
                                             rcas_posted)
        if lease is not None:
            self._slot_join(p, key, lease.token, lease.expires_at)
        with shard._meta:
            shard.shared_acquire_rcas += rcas_posted
            if lease is not None:
                shard.grants += 1
                shard.grants_by_mode[LeaseMode.SHARED] += 1
                shard.shared_joins += 1
                if not local:
                    shard.shared_remote_grants += 1
                if expired_over:
                    shard.expirations += 1
            else:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.SHARED] += 1
                if intent_block:
                    shard.intent_blocks += 1
        return lease

    def _shared_repair_grant(self, p: Process, shard: LockShard, key: str,
                             st: _KeyState, ttl: float,
                             rcas_posted: int) -> Optional[Lease]:
        """A shared grant over a clobbered mirror: the one shared-acquire
        case that must run under the shard ALock (the mirror cannot be
        trusted, so the CS re-validates and re-seeds it — allocating a fresh
        token, exactly like an exclusive grant over untrusted state)."""
        snap = p.counts.as_tuple()
        lease: Optional[Lease] = None
        repaired = False
        blocked_by_intent = False
        try:
            now = self.clock()
            shard.alock.lock(p)
            writes: List[tuple] = []
            try:
                holder, packed, fence, barrier = \
                    self._read_key_state(p, shard, st)
                etok, readers, eexp = packed
                if now < barrier:
                    blocked_by_intent = True
                else:
                    free = eexp <= _FREE_AT
                    clobbered = etok != fence
                    if free or clobbered or now >= eexp:
                        token = fence + 1
                        # CAS, not write: a CS-free join can land between
                        # the read above and this commit; the CAS loses
                        # cleanly and the caller's retry re-reads.
                        if self.mem.auto_cas(p, st.expires, packed,
                                             (token, 1, now + ttl)) == packed:
                            lease = Lease(key, shard.index, p.pid, token,
                                          now + ttl, ttl, LeaseMode.SHARED)
                            writes = [
                                ("write", st.fence, token),
                                ("write", st.holder, _NO_HOLDER),
                                ("write", st.intent, _FREE_AT),
                            ]
                            repaired = clobbered
                    # else: someone re-granted cleanly while we queued for
                    # the CS — report a reject; the caller's retry will join.
            finally:
                shard.alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if lease is not None:
            self._slot_join(p, key, lease.token, lease.expires_at)
        with shard._meta:
            shard.shared_acquire_rcas += rcas_posted
            if lease is not None:
                shard.grants += 1
                shard.grants_by_mode[LeaseMode.SHARED] += 1
                if p.node != shard.home_host:
                    shard.shared_remote_grants += 1
                if repaired:
                    shard.repairs += 1
            else:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.SHARED] += 1
                if blocked_by_intent:
                    shard.intent_blocks += 1
        return lease

    # --------------------------------------------------------------- leases
    def _acquire_group(self, p: Process, shard: LockShard,
                       keys: Sequence[str], ttl: float,
                       mode: LeaseMode = LeaseMode.EXCLUSIVE,
                       ) -> Tuple[List[Lease], bool]:
        """Grant a prefix of ``keys`` (one shard, global order).

        EXCLUSIVE mode runs the original transaction in **one** ALock
        critical section; SHARED mode joins each key's reader cohort with
        the CS-free single-CAS fast path (shared grants never conflict with
        each other, so there is no critical section to batch).

        Returns ``(granted, blocked)``: the leases granted, and whether the
        next key was held by a live lease (granting stops there — taking
        later keys while a smaller one is still wanted would break the
        deadlock-avoidance total order).  Never blocks inside the critical
        section.
        """
        if mode == LeaseMode.SHARED:
            granted: List[Lease] = []
            for key in keys:
                lease = self._shared_acquire(p, shard, key, ttl)
                if lease is None:
                    return granted, True
                granted.append(lease)
            return granted, False

        states = [self._key_state(shard, k) for k in keys]
        snap = p.counts.as_tuple()
        local = p.node == shard.home_host
        granted = []
        writes: List[tuple] = []
        blocked = False
        armed_drain = False
        expirations = 0
        repairs = 0
        # Sample the clock BEFORE acquiring: every register read then happens
        # at-or-after ``now``, so an "expired" verdict (eexp <= now <= read
        # time) can only be beaten by a renewal whose local-clock check
        # predates ``now`` but whose CAS lands after our read — i.e. exactly
        # the documented zombie window.  Sampling after the lock would let a
        # *healthy* pre-expiry renewal race the piggybacked (pre-CS) reads
        # and be silently re-granted over.
        now = self.clock()
        try:
            if local:
                shard.alock.lock(p)
                flat = None
            else:
                # Chain the lease-register reads into the Peterson-engagement
                # doorbell; valid on uncontended fast entry, else re-read.
                flat = shard.alock.lock(p, piggyback_reads=[
                    r for st in states for r in (st.expires, st.fence)
                ])
            try:
                if flat is None:
                    vals = self._read_pairs(p, shard, states)
                else:
                    vals = [(flat[2 * i], flat[2 * i + 1])
                            for i in range(len(states))]
                # Verdict pass: the grantable prefix in global order.
                plan = []  # (key, st, packed-as-read, new token, clobbered, free)
                for key, st, ((etok, readers, eexp), fence) in zip(
                        keys, states, vals):
                    free = eexp <= _FREE_AT
                    clobbered = etok != fence  # zombie CAS hit the mirror
                    if not free and not clobbered and now < eexp:
                        blocked = True
                        if readers > 0:
                            # A live reader cohort: arm the drain barrier so
                            # no new reader joins (and no shared renewal
                            # extends the cohort) past its current horizon —
                            # the writer's wait is bounded by one TTL.
                            writes.append(("write", st.intent, eexp))
                            armed_drain = True
                        break
                    token = fence + 1  # CS-only allocator: never regresses
                    plan.append((key, st, (etok, readers, eexp), token,
                                 clobbered, free))
                # Commit pass: every packed-word mutation is a CAS against
                # the value this transaction read — the CS excludes other
                # critical sections but NOT the CS-free shared joins, so a
                # plain grant write could stomp a reader that joined the
                # free word in the decision window.  The CAS loses instead
                # (and the key reports blocked).  Remote clients post the
                # whole group's grant CASes in one doorbell.
                if plan:
                    if local:
                        won = [
                            self.mem.cas(p, st.expires, packed,
                                         (token, 0, now + ttl)) == packed
                            for (_k, st, packed, token, _c, _f) in plan
                        ]
                    else:
                        obs = self.mem.post_batch(p, [
                            ("cas", st.expires, packed, (token, 0, now + ttl))
                            for (_k, st, packed, token, _c, _f) in plan
                        ])
                        won = [o == packed
                               for o, (_k, _s, packed, *_r) in zip(obs, plan)]
                    cut = won.index(False) if False in won else len(plan)
                    # Global-order discipline: nothing may be held past the
                    # first loser.  The batch's CASes already executed, so
                    # un-grant any stray winners after the cut (we hold the
                    # only witness to the value we just wrote; only the
                    # vanishing remote-window can beat the rollback, and a
                    # clobbered word is repaired by the next grant).
                    rollback = [
                        ("cas", st.expires, (token, 0, now + ttl), packed)
                        for i, (_k, st, packed, token, _c, _f)
                        in enumerate(plan)
                        if i > cut and won[i]
                    ]
                    if rollback:
                        if local:
                            for _op, reg, exp_v, new_v in rollback:
                                self.mem.cas(p, reg, exp_v, new_v)
                        else:
                            self.mem.post_batch(p, rollback)
                    if cut < len(plan):
                        blocked = True
                    for key, st, packed, token, clobbered, free in plan[:cut]:
                        if clobbered:
                            repairs += 1  # untrusted mirror: repaired
                        elif not free:
                            expirations += 1  # grant over an expired lease
                        granted.append(
                            Lease(key, shard.index, p.pid, token, now + ttl,
                                  ttl, LeaseMode.EXCLUSIVE)
                        )
                        writes += [
                            ("write", st.fence, token),
                            ("write", st.holder, p.pid),
                            ("write", st.intent, _FREE_AT),  # barrier served
                        ]
            finally:
                # The grant writes ride the unlock: applied in place by a
                # local releaser, chained into the tail-drain doorbell by a
                # remote one — still inside the critical section either way.
                shard.alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        with shard._meta:
            shard.grants += len(granted)
            shard.grants_by_mode[LeaseMode.EXCLUSIVE] += len(granted)
            shard.expirations += expirations
            shard.repairs += repairs
            if blocked:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.EXCLUSIVE] += 1
        if armed_drain:
            # The writer just armed a reader-cohort drain barrier and is
            # about to wait outside the CS — the window where its death
            # abandons the barrier (which lapses on its own: it is a
            # deadline, not a lock).
            self._crash_point("drain.mid", p)
        return granted, blocked

    def try_acquire(self, p: Process, key: str, ttl: float,
                    mode: LeaseMode = LeaseMode.EXCLUSIVE) -> Optional[Lease]:
        """One lease-table transaction; non-blocking.

        EXCLUSIVE: grants iff the key is free or its current lease (either
        mode) has expired; a fresh grant always carries a larger fencing
        token.  Returns ``None`` while a live lease exists — *including* the
        caller's own (non-reentrant: a holder extends via :meth:`renew`;
        silently superseding would let one process posing as several clients
        steal its own slots).

        SHARED: grants iff the key is free, expired, or held by a live
        reader cohort with no writer draining it — a single CAS (per
        attempt; a lost race with another shared CAS retries, bounded by
        ``_FAST_ATTEMPTS``), no shard ALock.  Shared joins by the same
        process stack (each join holds one cohort slot and needs its own
        release); a live writer or an armed writer-intent barrier yields
        ``None``.
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        shard = self.shards[self.shard_of(key)]
        if mode == LeaseMode.SHARED:
            return self._shared_acquire(p, shard, key, ttl)
        granted, _ = self._acquire_group(p, shard, (key,), ttl, mode)
        return granted[0] if granted else None

    def acquire(self, p: Process, key: str, ttl: float,
                timeout: Optional[float] = None,
                poll: float = 0.0005,
                mode: LeaseMode = LeaseMode.EXCLUSIVE) -> Lease:
        """Blocking acquire: retry ``try_acquire`` until granted or timeout.

        ``poll`` backs off between attempts — every retry is a full shard
        ALock transaction (remote ops for remote clients), so spinning at
        full rate would burn a core *and* inflate the REMOTE-class telemetry
        with retry traffic.
        """
        deadline = None if timeout is None else self.clock() + timeout
        while True:
            lease = self.try_acquire(p, key, ttl, mode=mode)
            if lease is not None:
                return lease
            if deadline is not None and self.clock() > deadline:
                raise TimeoutError(f"lease on {key!r} not granted in {timeout}s")
            self.sleep(poll)

    def renew(self, p: Process, lease: Lease, ttl: Optional[float] = None) -> Optional[Lease]:
        """Extend a still-valid lease; ``None`` if it was lost (fencing).

        **EXCLUSIVE fast path** (the common case — the holder renews before
        expiry, with its latest lease object): a single fencing-token-checked
        CAS on the expiry register, no shard ALock.  Zero simulated RDMA ops
        for a local holder, exactly one rCAS for a remote holder.  A zombie
        whose key was re-granted always loses the CAS: the register carries
        the new (larger) fence token, and tokens are never reused (no ABA).

        **EXCLUSIVE slow path** (stale lease object, or contention
        diagnosis): the original fully-validated transaction under the shard
        ALock.

        **SHARED**: a read + CAS extending the cohort's expiry horizon — no
        ALock in any case.  Refused while a writer-intent barrier is armed
        (the drain protocol: the reader keeps its slot until its own expiry,
        but cannot extend), after the holder's own ``expires_at`` (a crashed
        reader cannot resurrect its slot late), or when the generation moved
        on (token mismatch).
        """
        ttl = ttl if ttl is not None else lease.ttl
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        if lease.mode == LeaseMode.SHARED:
            return self._shared_renew(p, shard, st, lease, ttl)
        snap = p.counts.as_tuple()
        try:
            now = self.clock()
            if now < lease.expires_at:
                witness = (lease.token, 0, lease.expires_at)
                observed = self.mem.auto_cas(
                    p, st.expires, witness, (lease.token, 0, now + ttl)
                )
                if observed == witness:
                    with shard._meta:
                        shard.fast_renews += 1
                    return Lease(lease.key, lease.shard, lease.holder_pid,
                                 lease.token, now + ttl, ttl,
                                 LeaseMode.EXCLUSIVE)
            shard.alock.lock(p)
            renewed = None
            try:
                now = self.clock()
                holder, (etok, readers, eexp), fence, _barrier = \
                    self._read_key_state(p, shard, st)
                # A clobbered mirror (etok != fence) means the expiry can no
                # longer be trusted: refuse the renewal (conservative — the
                # holder must re-acquire) rather than extend blindly.  A
                # reader count (readers > 0) under our own token means the
                # key was released and re-opened as a reader generation
                # reusing it: our exclusive lease is long gone.
                if (
                    holder == lease.holder_pid
                    and fence == lease.token
                    and etok == fence
                    and readers == 0
                    and _FREE_AT < eexp
                    and now < eexp
                ):
                    # CAS against the read value (the word is CAS-only).
                    if self.mem.auto_cas(
                        p, st.expires, (etok, readers, eexp),
                        (lease.token, 0, now + ttl),
                    ) == (etok, readers, eexp):
                        renewed = Lease(lease.key, lease.shard,
                                        lease.holder_pid, lease.token,
                                        now + ttl, ttl, LeaseMode.EXCLUSIVE)
            finally:
                shard.alock.unlock(p)
            return renewed
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)

    def _shared_renew(self, p: Process, shard: LockShard, st: _KeyState,
                      lease: Lease, ttl: float) -> Optional[Lease]:
        if not self._slot_owned(p, lease.key, lease.token):
            return None  # released/upgraded already: the slot is not ours
        snap = p.counts.as_tuple()
        renewed = None
        intent_block = False
        try:
            for _ in range(_FAST_ATTEMPTS):
                now = self.clock()
                if now >= lease.expires_at:
                    break  # the holder's own slot lapsed: no resurrection
                packed, fence, barrier = self._shared_read(p, shard, st)
                etok, readers, eexp = packed
                if now < barrier:
                    intent_block = True  # writer draining: stop extending
                    break
                if (etok != lease.token or etok != fence or readers <= 0
                        or now >= eexp):
                    break  # generation moved on, clobbered, or expired
                new = (etok, readers, max(eexp, now + ttl))
                if self.mem.auto_cas(p, st.expires, packed, new) == packed:
                    renewed = Lease(lease.key, lease.shard, lease.holder_pid,
                                    etok, now + ttl, ttl, LeaseMode.SHARED)
                    break
                self.mem.yield_point()  # lost to another shared CAS: retry
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if renewed is not None:
            self._slot_extend(p, lease.key, lease.token, renewed.expires_at)
        with shard._meta:
            if renewed is not None:
                shard.shared_renews += 1
            elif intent_block:
                shard.intent_blocks += 1
        return renewed

    def release(self, p: Process, lease: Lease) -> bool:
        """Release iff the lease is still the current grant (token match).

        **EXCLUSIVE fast path**: one fencing-token-checked CAS writes the
        expiry register to ``(token, 0, FREE)`` — no shard ALock, zero RDMA
        ops for a local holder, one rCAS for a remote one.  The stale
        ``holder`` register left behind is harmless: grant decisions key off
        the packed expiry + fence, and the next grant overwrites it.

        **EXCLUSIVE slow path** (stale lease object whose token is still
        current): the fully-validated transaction under the shard ALock.

        **SHARED**: a read + CAS decrementing the cohort count (the last
        reader out writes FREE) — no ALock in any case.  A lapsed shared
        lease (past its own ``expires_at``) returns ``False``: its slot dies
        with the generation, which closes the ABA window where a zombie
        reader could decrement a *successor* generation that reused the
        token.
        """
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        if lease.mode == LeaseMode.SHARED:
            return self._shared_release(p, shard, st, lease)
        snap = p.counts.as_tuple()
        try:
            witness = (lease.token, 0, lease.expires_at)
            observed = self.mem.auto_cas(
                p, st.expires, witness, (lease.token, 0, _FREE_AT)
            )
            if observed == witness:
                with shard._meta:
                    shard.fast_releases += 1
                return True
            shard.alock.lock(p)
            released = False
            writes = None
            try:
                holder, (etok, readers, eexp), fence, _barrier = \
                    self._read_key_state(p, shard, st)
                # Stale (expired and re-granted: the fence moved on), already
                # released (mirror intact at FREE), or superseded by a reader
                # generation reusing our token (readers > 0) ⇒ nothing to do.
                # Releasing the current generation is legal even with a
                # clobbered mirror: the write below re-syncs it.
                if (
                    holder == lease.holder_pid
                    and fence == lease.token
                    and readers == 0
                    and not (etok == fence and eexp <= _FREE_AT)
                ):
                    # CAS against the read value (the word is CAS-only).
                    if self.mem.auto_cas(
                        p, st.expires, (etok, readers, eexp),
                        (lease.token, 0, _FREE_AT),
                    ) == (etok, readers, eexp):
                        writes = [("write", st.holder, _NO_HOLDER)]
                        released = True
            finally:
                shard.alock.unlock(p, piggyback=writes)
            return released
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)

    def _shared_release(self, p: Process, shard: LockShard, st: _KeyState,
                        lease: Lease) -> bool:
        if not self._slot_owned(p, lease.key, lease.token):
            # Double release, or the slot was consumed by an upgrade: the
            # word's count is anonymous, so posting a decrement we do not
            # own would free ANOTHER live reader's slot and let a writer in
            # beside them.  Refuse without touching the word.
            return False
        snap = p.counts.as_tuple()
        released = False
        try:
            for _ in range(_FAST_ATTEMPTS):
                now = self.clock()
                if now >= lease.expires_at:
                    break  # lapsed: the slot dies with the generation (ABA)
                if p.node == shard.home_host:
                    packed = self.mem.read(p, st.expires)
                else:
                    packed = self.mem.rread(p, st.expires)
                etok, readers, eexp = packed
                if etok != lease.token or readers <= 0:
                    break  # the generation moved on: nothing to release
                new = (etok, readers - 1,
                       eexp if readers > 1 else _FREE_AT)
                if self.mem.auto_cas(p, st.expires, packed, new) == packed:
                    released = True
                    break
                self.mem.yield_point()  # lost to another shared CAS: retry
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if released:
            self._slot_consume(p, lease.key, lease.token)
            with shard._meta:
                shard.shared_releases += 1
        return released

    # ------------------------------------------------------ mode transitions
    def upgrade(self, p: Process, lease: Lease,
                ttl: Optional[float] = None) -> Optional[Lease]:
        """SHARED → EXCLUSIVE, iff the caller is the *sole* live reader.

        Runs under the shard ALock (it allocates a token).  With other
        readers present it arms the writer-intent drain barrier (no new
        joins, no renewal extensions) and returns ``None`` — poll until the
        cohort drains.  Two holders upgrading the same key concurrently
        cannot both succeed; bound the polling with a timeout and release on
        failure (the classic S/X upgrade deadlock is the caller's to break).
        The upgraded lease's token is strictly larger than the reader
        generation's, so fencing monotonicity is preserved.
        """
        if lease.mode != LeaseMode.SHARED:
            raise ValueError("upgrade() takes a SHARED lease")
        if not self._slot_owned(p, lease.key, lease.token):
            return None  # released/consumed already: not our slot to trade
        ttl = ttl if ttl is not None else lease.ttl
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.as_tuple()
        upgraded = None
        try:
            now = self.clock()
            if now >= lease.expires_at:
                return None
            shard.alock.lock(p)
            writes: List[tuple] = []
            try:
                now = self.clock()
                _holder, (etok, readers, eexp), fence, _barrier = \
                    self._read_key_state(p, shard, st)
                if (etok == fence == lease.token and readers >= 1
                        and _FREE_AT < eexp and now < eexp
                        and now < lease.expires_at):
                    if readers == 1:  # the sole live reader is us
                        token = fence + 1
                        # CAS, not write: a CS-free join can slip in between
                        # the read and this commit — it must not be stomped
                        # into a phantom reader under our exclusive grant.
                        if self.mem.auto_cas(
                            p, st.expires, (etok, readers, eexp),
                            (token, 0, now + ttl),
                        ) == (etok, readers, eexp):
                            writes = [
                                ("write", st.fence, token),
                                ("write", st.holder, p.pid),
                                ("write", st.intent, _FREE_AT),
                            ]
                            upgraded = Lease(lease.key, lease.shard, p.pid,
                                             token, now + ttl, ttl,
                                             LeaseMode.EXCLUSIVE)
                        else:  # a joiner beat us: drain them first
                            writes = [("write", st.intent, eexp)]
                    else:  # drain the rest of the cohort first
                        writes = [("write", st.intent, eexp)]
            finally:
                shard.alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        if upgraded is not None:
            self._slot_consume(p, lease.key, lease.token)
        with shard._meta:
            if upgraded is not None:
                shard.upgrades += 1
                shard.grants += 1
                shard.grants_by_mode[LeaseMode.EXCLUSIVE] += 1
            else:
                shard.rejects += 1
                shard.rejects_by_mode[LeaseMode.EXCLUSIVE] += 1
        if upgraded is None and writes:
            # The upgrader armed the drain barrier and will poll from
            # outside the CS; its death here leaves the barrier to lapse
            # and its shared slot counted until the slot's own horizon
            # (reclaimable by a restarted incarnation).
            self._crash_point("upgrade.mid", p)
        return upgraded

    def downgrade(self, p: Process, lease: Lease,
                  ttl: Optional[float] = None) -> Optional[Lease]:
        """EXCLUSIVE → SHARED without a window for another writer.

        A single fencing-token-checked CAS turns the writer lease into a
        one-reader cohort that keeps the writer's token (the generation the
        readers share) — zero RDMA ops for a local holder, exactly one rCAS
        for a remote one.  Other readers can join the instant the CAS lands.
        ``None`` if the lease was stale (the witness lost).
        """
        if lease.mode != LeaseMode.EXCLUSIVE:
            raise ValueError("downgrade() takes an EXCLUSIVE lease")
        ttl = ttl if ttl is not None else lease.ttl
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        snap = p.counts.as_tuple()
        downgraded = None
        try:
            now = self.clock()
            if now < lease.expires_at:
                witness = (lease.token, 0, lease.expires_at)
                observed = self.mem.auto_cas(
                    p, st.expires, witness, (lease.token, 1, now + ttl)
                )
                if observed == witness:
                    downgraded = Lease(lease.key, lease.shard, p.pid,
                                       lease.token, now + ttl, ttl,
                                       LeaseMode.SHARED)
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if downgraded is not None:
            self._slot_join(p, lease.key, downgraded.token,
                            downgraded.expires_at)
            with shard._meta:
                shard.downgrades += 1
        return downgraded

    # ------------------------------------------------------ crash recovery
    def reclaim(self, p: Process, lease: Lease,
                ttl: Optional[float] = None) -> Optional[Lease]:
        """Crash-restart re-entry: re-adopt a still-valid lease.

        ``lease`` is the witness a restarted client replayed from its
        ledger (see ``repro.coord.ledger``).  Reclaim never *extends* a
        dead grant's reach: it succeeds only while the grant is still the
        key's live generation, and a lease the world has moved past
        (expired and re-granted, fenced out, cohort gone) returns ``None``
        — the client re-acquires like anyone else.

        **EXCLUSIVE fast path**: one fencing-token-checked CAS against the
        ledger's witness ``(token, 0, expires_at)``, re-timing the lease to
        ``now + ttl`` — zero simulated RDMA ops for a local holder, exactly
        one rCAS for a remote one, same cost shape as a renewal.  This is
        what makes restart re-entry ~three orders cheaper than the TTL
        wedge.

        **EXCLUSIVE word-probe path**: the witness can be stale-LOW (a
        renewal's CAS landed but its ledger record died with the client),
        so a missed fast CAS re-reads the authoritative word and CASes
        against *it* — still CS-free.  Sound for the same reason the
        renewal fast path is: fence tokens are never reused, so a word
        still carrying OUR token with no readers IS our live grant, and
        re-timing it is just a renewal.  Restart recovery therefore costs
        reads and CASes (doorbells), never a shard ALock critical section.
        Past the word's own expiry the lease is dead — reclaim never
        resurrects.

        **SHARED**: the crashed reader's cohort slot is still counted in
        the packed word (nobody else may decrement it — the client-side
        slot ledger forbids it), so reclaim re-adopts the slot under the
        new incarnation and extends the cohort horizon like a renewal,
        gated on the slot's OWN ``expires_at`` (the same no-resurrection
        ABA posture as ``_shared_release``: past its horizon the slot died
        with its generation) and refused while a writer drain barrier is
        armed.

        The reclaimed EXCLUSIVE lease keeps the *original* ``holder_pid``:
        that pid is the grant's identity (the ``holder`` register still
        names it, and pids are never reused), so the slow renew/release
        validations keep working for the new incarnation.  SHARED reclaims
        carry the new pid — cohort slots are owned per live process.
        """
        if ttl is None:
            ttl = lease.ttl
        shard = self.shards[lease.shard]
        st = self._key_state(shard, lease.key)
        if lease.mode == LeaseMode.SHARED:
            return self._shared_reclaim(p, shard, st, lease, ttl)
        snap = p.counts.as_tuple()
        got: Optional[Lease] = None
        fast = False
        try:
            now = self.clock()
            if now < lease.expires_at:
                witness = (lease.token, 0, lease.expires_at)
                observed = self.mem.auto_cas(
                    p, st.expires, witness, (lease.token, 0, now + ttl)
                )
                if observed == witness:
                    got = Lease(lease.key, lease.shard, lease.holder_pid,
                                lease.token, now + ttl, ttl,
                                LeaseMode.EXCLUSIVE)
                    fast = True
            if got is None:
                for _ in range(_FAST_ATTEMPTS):
                    now = self.clock()
                    packed = self.mem.auto_read(p, st.expires)
                    etok, readers, eexp = packed
                    if (etok != lease.token or readers != 0
                            or eexp <= _FREE_AT or now >= eexp):
                        break  # expired, re-granted, or a reader generation
                    if self.mem.auto_cas(
                        p, st.expires, packed, (lease.token, 0, now + ttl)
                    ) == packed:
                        got = Lease(lease.key, lease.shard, lease.holder_pid,
                                    lease.token, now + ttl, ttl,
                                    LeaseMode.EXCLUSIVE)
                        break
                    self.mem.yield_point()  # lost a word race: re-read
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        with shard._meta:
            if got is not None:
                shard.reclaims += 1
                if fast:
                    shard.reclaim_fast += 1
                else:
                    shard.reclaim_slow += 1
            else:
                shard.reclaim_rejects += 1
        return got

    def _shared_reclaim(self, p: Process, shard: LockShard, st: _KeyState,
                        lease: Lease, ttl: float) -> Optional[Lease]:
        snap = p.counts.as_tuple()
        got: Optional[Lease] = None
        try:
            for _ in range(_FAST_ATTEMPTS):
                now = self.clock()
                if now >= lease.expires_at:
                    break  # the slot's horizon passed: it died with the
                    # generation (no resurrection — the ABA guard that
                    # keeps a reclaim from decrementing, later, a
                    # successor generation that reused the token)
                packed, fence, barrier = self._shared_read(p, shard, st)
                etok, readers, eexp = packed
                if now < barrier:
                    break  # writer draining: no extensions, no re-adoption
                if (etok != lease.token or etok != fence or readers <= 0
                        or now >= eexp):
                    break  # generation moved on, clobbered, or expired
                new = (etok, readers, max(eexp, now + ttl))
                if self.mem.auto_cas(p, st.expires, packed, new) == packed:
                    got = Lease(lease.key, lease.shard, p.pid, etok,
                                now + ttl, ttl, LeaseMode.SHARED)
                    break
                self.mem.yield_point()  # lost to another shared CAS: retry
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if got is not None:
            self._slot_join(p, lease.key, got.token, got.expires_at)
        with shard._meta:
            if got is not None:
                shard.reclaims += 1
                shard.reclaim_shared += 1
            else:
                shard.reclaim_rejects += 1
        return got

    def reclaim_orphan(self, p: Process, key: str,
                       dead_pids: Sequence[int],
                       ttl: float) -> Optional[Lease]:
        """Adopt a live EXCLUSIVE grant left by a dead incarnation.

        The one crash window reclaim-by-witness cannot cover: the grant
        CAS committed but the client died before its ledger recorded the
        token (``grant.pre_ledger``, or mid-batch).  The restarted client
        knows only that an *intent* is dangling — but the ``holder``
        register names the grantee, and pids are never reused, so under
        the shard ALock a live word whose holder is one of the caller's
        dead pids is provably the caller's lost grant.  The CAS re-times
        it and the holder register is re-pointed at the new incarnation.

        Probe cost is one CS per dangling intent — proportional to what
        was in flight at the crash, not to the keyspace (the adaptive
        recovery-cost shape of Dhoked & Mittal's RME transformation).
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        dead = set(dead_pids)
        shard = self.shards[self.shard_of(key)]
        st = self._key_state(shard, key)
        snap = p.counts.as_tuple()
        got: Optional[Lease] = None
        writes = None
        try:
            if dead:
                shard.alock.lock(p)
                try:
                    now = self.clock()
                    holder, (etok, readers, eexp), fence, _barrier = \
                        self._read_key_state(p, shard, st)
                    if (
                        holder in dead
                        and etok == fence
                        and readers == 0
                        and _FREE_AT < eexp
                        and now < eexp
                    ):
                        if self.mem.auto_cas(
                            p, st.expires, (etok, readers, eexp),
                            (etok, 0, now + ttl),
                        ) == (etok, readers, eexp):
                            writes = [("write", st.holder, p.pid)]
                            got = Lease(key, shard.index, p.pid, etok,
                                        now + ttl, ttl, LeaseMode.EXCLUSIVE)
                finally:
                    shard.alock.unlock(p, piggyback=writes)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        with shard._meta:
            shard.orphan_probes += 1
            if got is not None:
                shard.orphan_adopts += 1
                shard.reclaims += 1
        return got

    def reconstruct_shard(self, p: Process, shard_index: int,
                          records: Iterable, fence_slack: int = 16,
                          ) -> Dict[str, int]:
        """Audit-and-repair one shard's registers after a home-host restart.

        ``records`` is the merged record stream from surviving clients'
        ledgers (duck-typed: anything with ``op``/``key``/``token``/
        ``expires_at`` — see ``repro.coord.ledger.LedgerRecord``).  For
        every ledgered key homed on this shard, under the shard ALock:

        * **intact** — the fence register matches the word's generation and
          is at least the largest token any ledger has seen: nothing to do.
        * **fence_repaired** — the word still carries a ledger-live lease
          but the fence register lagged (lost with the host): the fence is
          re-seeded from the word, preserving the lease (its holder can
          still reclaim it).
        * **reset** — anything else (word and fence disagree with the
          ledgers): the key is re-seeded FREE under a fence advanced past
          everything observed **plus ``fence_slack``**, covering grants
          that died unrecorded in the pre-ledger window — so no
          post-reconstruction grant can ever reuse a token some downstream
          resource has already honored.

        Returns the per-action counts.  Token monotonicity is the one
        invariant reconstruction must preserve at all costs; availability
        of individual leases is sacrificed whenever the state cannot be
        trusted (a reset key's holder simply re-acquires).
        """
        shard = self.shards[shard_index]
        ledger_max: Dict[str, int] = {}
        grants: Dict[str, Dict[int, tuple]] = {}
        tombs: Dict[str, set] = {}
        for rec in records:
            key = rec.key
            if not key or rec.op not in ("grant", "reclaim", "renew",
                                         "release", "lost"):
                continue
            if self.shard_of(key) != shard_index:
                continue
            if rec.token > ledger_max.get(key, 0):
                ledger_max[key] = rec.token
            if rec.op in ("grant", "reclaim"):
                grants.setdefault(key, {})[rec.token] = (rec.token,
                                                         rec.expires_at)
            elif rec.op == "renew":
                cur = grants.get(key, {}).get(rec.token)
                if cur is not None and rec.expires_at > cur[1]:
                    grants[key][rec.token] = (rec.token, rec.expires_at)
            else:  # release / lost
                tombs.setdefault(key, set()).add(rec.token)
        report = {"intact": 0, "fence_repaired": 0, "reset": 0}
        for key in sorted(ledger_max):
            # The plausibly-live generation: the largest untombstoned grant
            # (cross-ledger merge order is not time order, so selection is
            # by token — tokens ARE the time order).
            live_tok = max(
                (t for t in grants.get(key, {}) if t not in tombs.get(key, set())),
                default=None,
            )
            st = self._key_state(shard, key)
            snap = p.counts.as_tuple()
            writes: List[tuple] = []
            action = "reset"
            try:
                shard.alock.lock(p)
                try:
                    now = self.clock()
                    _holder, (etok, readers, eexp), fence, _barrier = \
                        self._read_key_state(p, shard, st)
                    lmax = ledger_max[key]
                    word_live = _FREE_AT < eexp and now < eexp
                    if etok == fence and fence >= lmax:
                        action = "intact"  # registers survived the restart
                    elif (live_tok is not None and etok == live_tok
                          and word_live and fence <= etok and etok >= lmax):
                        # The word is authoritative for a ledger-live lease;
                        # only the fence register lagged.  Re-seed it from
                        # the word — the lease stays reclaimable.
                        writes = [("write", st.fence, etok)]
                        action = "fence_repaired"
                    else:
                        nf = max(fence, etok, lmax) + fence_slack
                        packed = (etok, readers, eexp)
                        # CAS, not write (the word is CAS-only: a CS-free
                        # shared join can land between read and commit);
                        # a lost race re-reads and retries — the joiner
                        # reused the same untrusted generation, which is
                        # exactly what the reset must displace.
                        for _ in range(_FAST_ATTEMPTS):
                            if self.mem.auto_cas(
                                p, st.expires, packed, (nf, 0, _FREE_AT),
                            ) == packed:
                                writes = [
                                    ("write", st.fence, nf),
                                    ("write", st.holder, _NO_HOLDER),
                                    ("write", st.intent, _FREE_AT),
                                ]
                                break
                            packed = self.mem.auto_read(p, st.expires)
                            self.mem.yield_point()
                finally:
                    shard.alock.unlock(p, piggyback=writes or None)
            finally:
                self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            report[action] += 1
        with shard._meta:
            shard.reconstructions += sum(report.values())
            shard.reconstruct_resets += report["reset"]
        return report

    # --------------------------------------------------------------- batches
    def batch_order(self, keys: Iterable[str]) -> List[str]:
        """The deadlock-avoidance total order: ``(shard_of(key), key)``."""
        return sorted(set(keys), key=lambda k: (self.shard_of(k), k))

    def acquire_batch(self, p: Process, keys: Sequence[str], ttl: float,
                      timeout: Optional[float] = None,
                      poll: float = 0.0005,
                      mode: LeaseMode = LeaseMode.EXCLUSIVE) -> List[Lease]:
        """Acquire every key (deduplicated) in the global key order.

        Keys are grouped by shard (the global order is primary-by-shard, so
        groups are contiguous); EXCLUSIVE groups take each shard's ALock
        **once** for all of that shard's keys — O(distinct shards) critical
        sections instead of O(keys), with the group's register reads and
        writes each coalesced into one doorbell for remote clients — while
        SHARED groups join each key's cohort CS-free.  Deadlock freedom is
        preserved: grants still happen in the global order, and a blocked
        key is waited on *outside* the critical section while holding only
        smaller keys.

        All-or-nothing: ``timeout`` bounds the *whole batch*; on expiry,
        already-granted leases are released and ``TimeoutError`` is raised.
        """
        if ttl <= 0:
            raise ValueError("ttl must be > 0")
        ordered = self.batch_order(keys)
        deadline = None if timeout is None else self.clock() + timeout
        held: List[Lease] = []
        try:
            i, n = 0, len(ordered)
            while i < n:
                shard = self.shards[self.shard_of(ordered[i])]
                j = i + 1
                while j < n and self.shard_of(ordered[j]) == shard.index:
                    j += 1
                group = ordered[i:j]
                start = 0
                while start < len(group):
                    granted, blocked = self._acquire_group(
                        p, shard, group[start:], ttl, mode
                    )
                    held.extend(granted)
                    start += len(granted)
                    if blocked:
                        if deadline is not None and self.clock() > deadline:
                            raise TimeoutError(
                                f"batch lease on {group[start]!r} not granted "
                                f"in {timeout}s"
                            )
                        self.sleep(poll)
                i = j
                if i < n:
                    # Between two shard groups: a prefix of the batch is
                    # held; death here abandons it under a dead pid (the
                    # recoverable client's dangling intents drive the
                    # orphan probe on restart).
                    self._crash_point("batch.mid", p)
        except TimeoutError:
            for lease in held:
                self.release(p, lease)
            raise
        return held

    def release_batch(self, p: Process, leases: Sequence[Lease]) -> int:
        """Release a batch (any order); returns how many were still current.

        Mirrors ``acquire_batch``'s shard grouping: leases are grouped by
        shard, each group's EXCLUSIVE fast-path CASes are coalesced into
        **one doorbell** for remote clients (one posting for the whole
        group instead of one per lease), SHARED releases batch their cohort
        reads and decrement CASes the same way, and whatever falls off the
        fast path is settled under **one** shard ALock critical section per
        group — the exact structure the old per-key loop paid for K times.
        """
        by_shard: Dict[int, List[Lease]] = {}
        for lease in leases:
            by_shard.setdefault(lease.shard, []).append(lease)
        released = 0
        for sidx in sorted(by_shard):
            group = by_shard[sidx]
            shard = self.shards[sidx]
            released += self._release_group(p, shard, group)
        return released

    def _release_group(self, p: Process, shard: LockShard,
                       group: Sequence[Lease]) -> int:
        local = p.node == shard.home_host
        released = 0
        # --- EXCLUSIVE leases: witness CASes, one doorbell for the group.
        excl = [l for l in group if l.mode == LeaseMode.EXCLUSIVE]
        slow: List[Lease] = []
        if excl:
            snap = p.counts.as_tuple()
            nfast = 0
            try:
                sts = [self._key_state(shard, l.key) for l in excl]
                if local:
                    observed = [
                        self.mem.cas(p, st.expires,
                                     (l.token, 0, l.expires_at),
                                     (l.token, 0, _FREE_AT))
                        for st, l in zip(sts, excl)
                    ]
                else:
                    observed = self.mem.post_batch(p, [
                        ("cas", st.expires, (l.token, 0, l.expires_at),
                         (l.token, 0, _FREE_AT))
                        for st, l in zip(sts, excl)
                    ])
                for lease, obs in zip(excl, observed):
                    if obs == (lease.token, 0, lease.expires_at):
                        nfast += 1
                    else:
                        slow.append(lease)
            finally:
                self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
            with shard._meta:
                shard.fast_releases += nfast
            released += nfast
            if slow:
                released += self._release_group_slow(p, shard, slow)
        # --- SHARED leases: cohort reads + decrement CASes, batched.
        shrd = [l for l in group if l.mode == LeaseMode.SHARED]
        if shrd:
            released += self._release_group_shared(p, shard, shrd)
        return released

    def _release_group_slow(self, p: Process, shard: LockShard,
                            group: Sequence[Lease]) -> int:
        """Slow-path releases for one shard, in ONE critical section."""
        states = [self._key_state(shard, l.key) for l in group]
        snap = p.counts.as_tuple()
        local = p.node == shard.home_host
        released = 0
        writes: List[tuple] = []
        try:
            if local:
                shard.alock.lock(p)
                flat = None
            else:
                flat = shard.alock.lock(p, piggyback_reads=[
                    r for st in states
                    for r in (st.holder, st.expires, st.fence)
                ])
            try:
                if flat is None:
                    if local:
                        vals = [(self.mem.read(p, st.holder),
                                 self.mem.read(p, st.expires),
                                 self.mem.read(p, st.fence))
                                for st in states]
                    else:
                        out = self.mem.post_batch(p, [
                            wr for st in states
                            for wr in (("read", st.holder),
                                       ("read", st.expires),
                                       ("read", st.fence))
                        ])
                        vals = [tuple(out[3 * i:3 * i + 3])
                                for i in range(len(states))]
                else:
                    vals = [tuple(flat[3 * i:3 * i + 3])
                            for i in range(len(states))]
                plan = []  # (st, packed-as-read, release tuple)
                for lease, st, (holder, (etok, readers, eexp), fence) in zip(
                        group, states, vals):
                    if (
                        holder == lease.holder_pid
                        and fence == lease.token
                        and readers == 0
                        and not (etok == fence and eexp <= _FREE_AT)
                    ):
                        plan.append((st, (etok, readers, eexp),
                                     (lease.token, 0, _FREE_AT)))
                # Commit by CAS (the word is CAS-only — a CS-free join can
                # land between read and commit); one doorbell for the group.
                if plan:
                    if local:
                        won = [self.mem.cas(p, st.expires, packed, new)
                               == packed for st, packed, new in plan]
                    else:
                        obs = self.mem.post_batch(p, [
                            ("cas", st.expires, packed, new)
                            for st, packed, new in plan
                        ])
                        won = [o == packed
                               for o, (_s, packed, _n) in zip(obs, plan)]
                    for (st, _packed, _new), ok in zip(plan, won):
                        if ok:
                            writes.append(("write", st.holder, _NO_HOLDER))
                            released += 1
            finally:
                shard.alock.unlock(p, piggyback=writes or None)
        finally:
            self._account(shard, p, snap, LeaseMode.EXCLUSIVE)
        return released

    def _release_group_shared(self, p: Process, shard: LockShard,
                              group: Sequence[Lease]) -> int:
        """Batched shared releases: one read doorbell + one CAS doorbell for
        the group's first round; CAS losers retry individually (rare — only
        same-key leases in one batch, or an outside racer)."""
        local = p.node == shard.home_host
        released = 0
        if local:
            for lease in group:
                st = self._key_state(shard, lease.key)
                if self._shared_release(p, shard, st, lease):
                    released += 1
            return released
        snap = p.counts.as_tuple()
        retry: List[Lease] = []
        done: List[Lease] = []
        try:
            now = self.clock()
            # The slot-ledger filter applies batch-wide: a decrement the
            # caller does not own (double release, consumed by an upgrade,
            # or a duplicate of an earlier batch entry) is never posted.
            owned: List[Lease] = []
            counted: Dict[Tuple[str, int], int] = {}
            for lease in group:
                if now >= lease.expires_at:
                    continue
                k = (lease.key, lease.token)
                counted[k] = counted.get(k, 0) + 1
                if counted[k] <= self._slot_count(p, lease.key, lease.token):
                    owned.append(lease)
            pending = [(l, self._key_state(shard, l.key)) for l in owned]
            if pending:
                packeds = self.mem.post_batch(
                    p, [("read", st.expires) for _, st in pending])
                wrs, metas = [], []
                for (lease, st), packed in zip(pending, packeds):
                    etok, readers, eexp = packed
                    if etok != lease.token or readers <= 0:
                        continue  # generation moved on: nothing to release
                    new = (etok, readers - 1,
                           eexp if readers > 1 else _FREE_AT)
                    wrs.append(("cas", st.expires, packed, new))
                    metas.append((lease, packed))
                outs = self.mem.post_batch(p, wrs) if wrs else []
                for (lease, packed), obs in zip(metas, outs):
                    if obs == packed:
                        done.append(lease)
                    else:
                        retry.append(lease)
        finally:
            self._account(shard, p, snap, LeaseMode.SHARED)
        if done:
            for lease in done:
                self._slot_consume(p, lease.key, lease.token)
            with shard._meta:
                shard.shared_releases += len(done)
            released += len(done)
        for lease in retry:
            st = self._key_state(shard, lease.key)
            if self._shared_release(p, shard, st, lease):
                released += 1
        return released

    # ------------------------------------------------------------- telemetry
    def telemetry(self) -> List[Dict]:
        """Per-shard snapshot: placement, grant counters, per-class OpCounts
        (total and per mode)."""
        out = []
        for shard in self.shards:
            with shard._meta:
                out.append({
                    "shard": shard.index,
                    "home_host": shard.home_host,
                    "keys": len(shard.keys),
                    "grants": shard.grants,
                    "rejects": shard.rejects,
                    "grants_shared": shard.grants_by_mode[LeaseMode.SHARED],
                    "grants_exclusive":
                        shard.grants_by_mode[LeaseMode.EXCLUSIVE],
                    "rejects_shared": shard.rejects_by_mode[LeaseMode.SHARED],
                    "rejects_exclusive":
                        shard.rejects_by_mode[LeaseMode.EXCLUSIVE],
                    "expirations": shard.expirations,
                    "fast_renews": shard.fast_renews,
                    "fast_releases": shard.fast_releases,
                    "shared_joins": shard.shared_joins,
                    "shared_renews": shard.shared_renews,
                    "shared_releases": shard.shared_releases,
                    "shared_remote_grants": shard.shared_remote_grants,
                    "shared_acquire_rcas": shard.shared_acquire_rcas,
                    "upgrades": shard.upgrades,
                    "downgrades": shard.downgrades,
                    "intent_blocks": shard.intent_blocks,
                    "repairs": shard.repairs,
                    "reclaims": shard.reclaims,
                    "reclaim_fast": shard.reclaim_fast,
                    "reclaim_slow": shard.reclaim_slow,
                    "reclaim_shared": shard.reclaim_shared,
                    "reclaim_rejects": shard.reclaim_rejects,
                    "orphan_probes": shard.orphan_probes,
                    "orphan_adopts": shard.orphan_adopts,
                    "reconstructions": shard.reconstructions,
                    "reconstruct_resets": shard.reconstruct_resets,
                    "local": shard.stats[LOCAL].snapshot(),
                    "remote": shard.stats[REMOTE].snapshot(),
                    "shared_local":
                        shard.mode_stats[(LeaseMode.SHARED, LOCAL)].snapshot(),
                    "shared_remote":
                        shard.mode_stats[(LeaseMode.SHARED, REMOTE)].snapshot(),
                    "exclusive_local":
                        shard.mode_stats[(LeaseMode.EXCLUSIVE, LOCAL)].snapshot(),
                    "exclusive_remote":
                        shard.mode_stats[(LeaseMode.EXCLUSIVE, REMOTE)].snapshot(),
                })
        return out

    def class_totals(self) -> Dict[int, OpCounts]:
        """Aggregate per-class OpCounts across all shards."""
        totals = {LOCAL: OpCounts(), REMOTE: OpCounts()}
        for shard in self.shards:
            with shard._meta:
                for cls in (LOCAL, REMOTE):
                    totals[cls] = totals[cls] + shard.stats[cls]
        return totals

    def mode_class_totals(self) -> Dict[LeaseMode, Dict[int, OpCounts]]:
        """Aggregate per-(mode, class) OpCounts across all shards."""
        totals = {m: {LOCAL: OpCounts(), REMOTE: OpCounts()}
                  for m in LeaseMode}
        for shard in self.shards:
            with shard._meta:
                for m in LeaseMode:
                    for cls in (LOCAL, REMOTE):
                        totals[m][cls] = (totals[m][cls]
                                          + shard.mode_stats[(m, cls)])
        return totals
