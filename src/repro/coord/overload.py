"""Overload protection: retry budgets, circuit breakers, hedge thresholds.

The paper's asymmetry bounds *per-op* RDMA cost, but nothing bounds
*aggregate* behavior when offered load exceeds a home host's capacity:
individually backoff-limited retries are globally unbudgeted, and one
congested host head-of-line-blocks every client that routes a key there —
the metastable retry-storm collapse Chung & Zamanian observed in RDMA lock
managers (arXiv 1507.03274).  ALock (arXiv 2404.17980) argues the remedy is
a *load-aware client protocol*; this module is that protocol's local state:

* :class:`RetryBudget` — a token bucket per destination host.  Retries (and
  hedges) consume tokens, successes refill them, so a client's aggregate
  retry traffic against one host is bounded no matter how many individual
  ops are each "within their own backoff schedule".
* :class:`CircuitBreaker` — per destination host, trips when the recent
  failure rate crosses a threshold and converts further attempts into
  **fast local refusals** (zero RDMA ops).  After a seeded cooldown one
  half-open trial probes recovery: success closes the breaker, failure
  re-opens it with exponentially longer cooldown.  An open breaker is
  evidence the host is *slow or unreachable from here* — grounds for
  SUSPECT in the membership protocol, never for DEAD (only missed
  heartbeats may kill; see ``repro.coord.membership``).
* :class:`LatencyTracker` — a bounded ring of observed probe latencies per
  destination; its p99 is the hedging threshold (a read-only probe that
  outlives the p99 may be re-posted once, first response wins).

Everything is deterministic: no wall clock (callers pass ``now`` from the
table's injected clock), and the only randomness — half-open cooldown
jitter — comes from a seeded RNG, so two same-seed sim runs trip, refuse,
probe and recover identically.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List

from repro.core import Overloaded

__all__ = ["OverloadPolicy", "RetryBudget", "CircuitBreaker",
           "LatencyTracker", "OverloadControl"]

_INF = float("inf")


@dataclass(frozen=True)
class OverloadPolicy:
    """Tunables for the overload-protection layer (all deterministic)."""

    # Retry budget: a token bucket per destination host.
    budget_capacity: float = 32.0   # max (and initial) tokens
    budget_retry_cost: float = 1.0  # tokens one client-level retry consumes
    budget_refill: float = 0.5      # tokens one success restores
    # Circuit breaker: sliding outcome window per destination host.
    breaker_window: int = 32        # outcomes remembered
    breaker_min_samples: int = 8    # no verdict before this many
    breaker_threshold: float = 0.5  # failure rate that trips the breaker
    breaker_cooldown: float = 2e-3  # OPEN hold before the half-open trial
    breaker_backoff: float = 2.0    # cooldown multiplier per re-trip
    breaker_max_cooldown: float = 32e-3
    # Hedged probes: p99-tracked latency threshold per destination host.
    hedge_quantile: float = 0.99
    hedge_window: int = 64          # latency samples retained
    hedge_min_samples: int = 16     # no hedging before the tracker warms
    hedge_cost: float = 1.0         # budget tokens one hedge consumes


class RetryBudget:
    """Token-bucket retry budget for one destination host.

    Intentionally *not* time-based: tokens are created by successes and
    destroyed by retries, so the steady-state retry rate can never exceed
    ``budget_refill / budget_retry_cost`` retries per success — the
    amplification bound that keeps a congested host's queue from feeding
    itself.
    """

    __slots__ = ("tokens", "capacity", "retry_cost", "refill_amount")

    def __init__(self, policy: OverloadPolicy):
        self.tokens = policy.budget_capacity
        self.capacity = policy.budget_capacity
        self.retry_cost = policy.budget_retry_cost
        self.refill_amount = policy.budget_refill

    def spend(self, cost: float) -> bool:
        """Consume ``cost`` tokens; ``False`` (and no change) if short."""
        if self.tokens < cost:
            return False
        self.tokens -= cost
        return True

    def refill(self) -> None:
        self.tokens = min(self.capacity, self.tokens + self.refill_amount)


class CircuitBreaker:
    """Per-destination breaker: CLOSED → OPEN → HALF_OPEN → CLOSED.

    Outcomes (success/failure of remote attempts against the host) feed a
    sliding window; when at least ``breaker_min_samples`` outcomes exist and
    the failure fraction reaches ``breaker_threshold``, the breaker OPENs:
    :meth:`allow` refuses locally until a seeded cooldown elapses, then
    admits exactly one half-open trial.  The trial's outcome decides:
    success closes the breaker (window reset), failure re-opens it with the
    cooldown doubled (capped).
    """

    __slots__ = ("state", "window", "outcomes", "min_samples", "threshold",
                 "cooldown", "base_cooldown", "backoff", "max_cooldown",
                 "retry_at", "trial_pending", "trips", "_rng")

    def __init__(self, policy: OverloadPolicy, rng: random.Random):
        self.state = "closed"
        self.window = policy.breaker_window
        self.outcomes: List[bool] = []
        self.min_samples = policy.breaker_min_samples
        self.threshold = policy.breaker_threshold
        self.base_cooldown = policy.breaker_cooldown
        self.cooldown = policy.breaker_cooldown
        self.backoff = policy.breaker_backoff
        self.max_cooldown = policy.breaker_max_cooldown
        self.retry_at = 0.0
        self.trial_pending = False
        self.trips = 0
        self._rng = rng

    def _open(self, now: float) -> None:
        self.state = "open"
        self.trips += 1
        self.trial_pending = False
        # Seeded jitter on the half-open instant: a fleet of clients whose
        # breakers tripped together must not re-probe in lockstep.
        self.retry_at = now + self.cooldown * (0.75 + 0.5 * self._rng.random())
        self.cooldown = min(self.cooldown * self.backoff, self.max_cooldown)

    def allow(self, now: float) -> bool:
        """May an attempt against this host proceed at ``now``?"""
        if self.state == "closed":
            return True
        if self.state == "open" and now >= self.retry_at:
            self.state = "half_open"
        if self.state == "half_open" and not self.trial_pending:
            self.trial_pending = True   # exactly one probe tests recovery
            return True
        return False

    def record(self, ok: bool, now: float) -> None:
        """Feed one attempt outcome (the half-open trial resolves here)."""
        if self.state == "half_open":
            self.trial_pending = False
            if ok:
                self.state = "closed"
                self.outcomes.clear()
                self.cooldown = self.base_cooldown
            else:
                self._open(now)
            return
        if self.state == "open":
            return  # refused callers never reached the fabric
        self.outcomes.append(ok)
        if len(self.outcomes) > self.window:
            del self.outcomes[0]
        if len(self.outcomes) >= self.min_samples:
            failures = self.outcomes.count(False)
            if failures / len(self.outcomes) >= self.threshold:
                self._open(now)


class LatencyTracker:
    """Bounded ring of observed latencies; quantile = hedging threshold."""

    __slots__ = ("samples", "window", "quantile", "min_samples", "_pos")

    def __init__(self, policy: OverloadPolicy):
        self.samples: List[float] = []
        self.window = policy.hedge_window
        self.quantile = policy.hedge_quantile
        self.min_samples = policy.hedge_min_samples
        self._pos = 0

    def record(self, dt: float) -> None:
        if len(self.samples) < self.window:
            self.samples.append(dt)
        else:  # ring overwrite, deterministic position
            self.samples[self._pos] = dt
            self._pos = (self._pos + 1) % self.window

    def threshold(self) -> float:
        """The tracked quantile, or +inf while the tracker is cold."""
        if len(self.samples) < self.min_samples:
            return _INF
        ys = sorted(self.samples)
        return ys[min(len(ys) - 1, int(self.quantile * len(ys)))]


class OverloadControl:
    """Per-destination budgets + breakers + latency trackers, one bundle.

    Owned by the lock table (one per table, covering every remote host a
    client can route to) and consulted on the remote paths: breaker check
    before posting, outcome recording after, budget spend per client-level
    retry, hedge admission for read-only probes.  All counters here are the
    *local-refusal* side of the telemetry; the per-shard ``sheds`` /
    ``deadline_exceeded`` counters live on :class:`~repro.coord.LockShard`.
    """

    def __init__(self, policy: OverloadPolicy = None, seed: int = 0):
        self.policy = policy or OverloadPolicy()
        self._rng = random.Random(0x0B0D6E7 * (seed + 1))
        self._budgets: Dict[int, RetryBudget] = {}
        self._breakers: Dict[int, CircuitBreaker] = {}
        self._latency: Dict[int, LatencyTracker] = {}
        self.breaker_refusals = 0
        self.budget_refusals = 0
        self.hedges = 0

    # ------------------------------------------------------------ accessors
    def budget(self, host: int) -> RetryBudget:
        b = self._budgets.get(host)
        if b is None:
            b = self._budgets[host] = RetryBudget(self.policy)
        return b

    def breaker(self, host: int) -> CircuitBreaker:
        b = self._breakers.get(host)
        if b is None:
            b = self._breakers[host] = CircuitBreaker(self.policy, self._rng)
        return b

    def latency(self, host: int) -> LatencyTracker:
        t = self._latency.get(host)
        if t is None:
            t = self._latency[host] = LatencyTracker(self.policy)
        return t

    # ------------------------------------------------------------- protocol
    def admit_remote(self, host: int, now: float) -> None:
        """Gate one remote attempt; raises :class:`Overloaded` when refused
        (a fast local refusal: zero RDMA ops were — and will be — spent)."""
        if not self.breaker(host).allow(now):
            self.breaker_refusals += 1
            raise Overloaded(
                f"circuit breaker open for host {host}", reason="breaker",
                host=host)

    def on_outcome(self, host: int, ok: bool, now: float) -> None:
        """Record one attempt outcome; successes refill the retry budget."""
        self.breaker(host).record(ok, now)
        if ok:
            self.budget(host).refill()

    def spend_retry(self, host: int) -> None:
        """Charge one client-level retry; raises when the budget is dry."""
        b = self.budget(host)
        if not b.spend(b.retry_cost):
            self.budget_refusals += 1
            raise Overloaded(
                f"retry budget exhausted for host {host}", reason="budget",
                host=host)

    def allow_hedge(self, host: int) -> bool:
        """May a read-only probe hedge a second posting?  Hedges ride the
        retry budget (a hedge *is* speculative retry traffic) — no budget,
        no hedge."""
        if not self.budget(host).spend(self.policy.hedge_cost):
            return False
        self.hedges += 1
        return True

    def hedge_threshold(self, host: int) -> float:
        return self.latency(host).threshold()

    def observe_latency(self, host: int, dt: float) -> None:
        self.latency(host).record(dt)

    # ------------------------------------------------------------ telemetry
    def breaker_open(self, host: int) -> bool:
        """Is the breaker for ``host`` currently refusing (OPEN, pre-trial)?
        Read-only: never constructs state for an untracked host."""
        b = self._breakers.get(host)
        return b is not None and b.state != "closed"

    def open_hosts(self) -> List[int]:
        """Hosts whose breakers are not closed — SUSPECT evidence for the
        membership layer (never DEAD: only missed heartbeats may kill)."""
        return sorted(h for h, b in self._breakers.items()
                      if b.state != "closed")

    def breaker_trips(self) -> int:
        return sum(b.trips for b in self._breakers.values())

    def report(self) -> Dict:
        return {
            "breaker_trips": self.breaker_trips(),
            "breaker_refusals": self.breaker_refusals,
            "budget_refusals": self.budget_refusals,
            "hedges": self.hedges,
            "open_hosts": self.open_hosts(),
            "budget_tokens": {h: round(b.tokens, 6)
                              for h, b in sorted(self._budgets.items())},
        }
