"""Coordination service: the sharded asymmetric lock table plus the named
locks, elections and barriers the training control plane is built from.

This is where the paper's primitive earns its keep inside the framework.  A
multi-host training job has exactly the asymmetry the paper models: one host
*owns* a given coordination record (the checkpoint manifest, the membership
epoch — "local" class, fast access), every other host reaches it over the
fabric ("remote" class).  Using ALock means the owning host's control loop
never pays a fabric round-trip, remote hosts pay a small bounded number of
one-sided ops, and the budget guarantees neither class starves the other —
precisely the paper's design goals, applied to checkpoint-writer election and
elastic-membership barriers.

Two tiers of API:

* **Lock table** (:class:`~repro.coord.table.ShardedLockTable`, delegated via
  ``try_acquire`` / ``acquire`` / ``acquire_batch`` / ``release`` / ``renew``
  / ``telemetry``): the scalable path.  The keyspace is sharded over all
  hosts so *every* host is the zero-RDMA local class for its slice, leases
  expire so a crashed holder cannot wedge a shard, and fencing tokens let
  downstream stores reject a dead holder's stale writes.
* **Named locks** (``lock`` / ``elect`` / :class:`Barrier`): small fixed sets
  of control-plane records pinned to an explicit home host — the original
  one-record-per-lock shape, kept for the handful of singleton records
  (membership epoch, barrier generations) where explicit placement beats
  hashed placement.

Hosts are simulated by threads over :class:`repro.core.AsymmetricMemory`; on a
real deployment the same algorithm runs over RDMA verbs (the memory API is the
paper's register model).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

from repro.core import ALock, AsymmetricMemory, OpCounts, Process

from .faults import FaultInjector
from .inflation import InflationPolicy
from .ledger import LedgerStore, RecoverableClient
from .membership import HostMembership, SuspicionPolicy
from .overload import OverloadPolicy
from .pipeline import AsyncClient
from .table import Lease, LeaseMode, ShardedLockTable


class CoordinationService:
    """Sharded lock table + named ALocks + election + barriers."""

    def __init__(
        self,
        num_hosts: int,
        init_budget: int = 4,
        num_shards: Optional[int] = None,
        sched=None,
        clock=None,
        sleep=None,
        yield_point=None,
        fault: Optional[FaultInjector] = None,
        inflation: Optional[InflationPolicy] = None,
        seed: int = 0,
        overload: Optional[OverloadPolicy] = None,
    ):
        self.num_hosts = num_hosts
        # One time source end-to-end: the memory's spin hooks, the table's
        # lease deadlines and the barriers' timeouts all read the same
        # injected clock (and back off through the matching sleep/yield),
        # so the whole service runs unchanged under the sim engine's
        # virtual time.
        self.mem = AsymmetricMemory(
            num_hosts, sched=sched, clock=clock, yield_point=yield_point
        )
        self.table = ShardedLockTable(
            self.mem, num_shards=num_shards, init_budget=init_budget,
            clock=clock, sleep=sleep, name="svc.table", fault=fault,
            inflation=inflation, seed=seed, overload=overload,
        )
        # Durable lease ledgers, keyed by client NAME (the identity that
        # survives a crash) — the restart re-entry API below hands a
        # restarted client its predecessor's ledger to replay.
        self.ledgers = LedgerStore()
        self._locks: Dict[str, ALock] = {}
        self._claims: Dict[str, object] = {}
        self._init_budget = init_budget
        self._guard = threading.Lock()
        # Read-mostly lease cache: (holder pid, key, mode) -> latest Lease.
        # The table's renewal/release fast path CASes the expiry register
        # against the lease's (token, expires_at) witness, so a caller
        # holding a *stale* lease object (e.g. the one acquire returned,
        # after several keepalives) would fall off the fast path.  The cache
        # keeps the freshest witness per holder and substitutes it when the
        # fencing token matches — repeat holders skip the slow ALock
        # transaction (and its table lookups) entirely.  The key includes
        # the lease *mode*: a shared lease and an exclusive lease on the
        # same key are different grants with different witnesses (and a
        # mid-upgrade holder briefly has both).  Entries are dropped on
        # release or any failed renew; leases that silently lapse (a crashed
        # holder never calls back) are swept inside _cache_put once the
        # cache grows past an amortised threshold, so it cannot leak
        # unboundedly.
        self._lease_cache: Dict[tuple, Lease] = {}
        self._cache_sweep_at = self._CACHE_SWEEP

    _CACHE_SWEEP = 1024

    def _cache_put(self, p: Process, lease: Lease) -> None:
        cache = self._lease_cache
        if len(cache) >= self._cache_sweep_at:
            now = self.table.clock()
            # Keep anything not yet a full TTL past expiry: a just-expired
            # witness can still serve the slow path's diagnosis.
            stale = [k for k, l in list(cache.items())
                     if now >= l.expires_at + l.ttl]
            for k in stale:
                cache.pop(k, None)
            # Amortise: next sweep only after the surviving (live) set could
            # have doubled, so steady-state puts stay O(1) even with >1024
            # live leases (a sweep that evicts nothing doesn't rerun per put).
            self._cache_sweep_at = max(self._CACHE_SWEEP, 2 * len(cache))
        cache[(p.pid, lease.key, lease.mode)] = lease

    def host_process(self, host: int) -> Process:
        """One coordination process per host (call once per host thread)."""
        return self.mem.spawn(host)

    # ------------------------------------------------------------ lock table
    def shard_of(self, key: str) -> int:
        return self.table.shard_of(key)

    def home_of(self, key: str) -> int:
        return self.table.home_of(key)

    def try_acquire(self, p: Process, key: str, ttl: float,
                    mode: LeaseMode = LeaseMode.EXCLUSIVE) -> Optional[Lease]:
        lease = self.table.try_acquire(p, key, ttl, mode=mode)
        if lease is not None:
            self._cache_put(p, lease)
        return lease

    def acquire(self, p: Process, key: str, ttl: float,
                timeout: Optional[float] = None,
                mode: LeaseMode = LeaseMode.EXCLUSIVE,
                deadline: Optional[float] = None,
                priority: int = 0) -> Lease:
        lease = self.table.acquire(p, key, ttl, timeout=timeout, mode=mode,
                                   deadline=deadline, priority=priority)
        self._cache_put(p, lease)
        return lease

    def acquire_batch(self, p: Process, keys: Sequence[str], ttl: float,
                      timeout: Optional[float] = None,
                      mode: LeaseMode = LeaseMode.EXCLUSIVE,
                      deadline: Optional[float] = None) -> List[Lease]:
        leases = self.table.acquire_batch(p, keys, ttl, timeout=timeout,
                                          mode=mode, deadline=deadline)
        for lease in leases:
            self._cache_put(p, lease)
        return leases

    def _freshest(self, p: Process, lease: Lease, evict: bool) -> Lease:
        """Substitute the cached latest witness for the same grant."""
        ck = (p.pid, lease.key, lease.mode)
        cached = self._lease_cache.get(ck)
        if cached is not None and cached.token == lease.token:
            # Same grant: use the freshest witness (keeps the CAS fast path
            # hot).  A token mismatch is an older grant's stale object —
            # leave the live grant's cache entry alone.
            if evict:
                self._lease_cache.pop(ck, None)
            return cached
        return lease

    def release(self, p: Process, lease: Lease,
                deadline: Optional[float] = None) -> bool:
        return self.table.release(p, self._freshest(p, lease, evict=True),
                                  deadline=deadline)

    def release_batch(self, p: Process, leases: Sequence[Lease]) -> int:
        """Witness-corrected batch release, shard-grouped by the table
        (one doorbell per shard group of fast-path CASes, at most one
        ALock critical section per group for the slow-path remainder)."""
        fixed = [self._freshest(p, lease, evict=True) for lease in leases]
        return self.table.release_batch(p, fixed)

    def renew(self, p: Process, lease: Lease,
              ttl: Optional[float] = None,
              deadline: Optional[float] = None) -> Optional[Lease]:
        """Renew via the table's fast path, witness-corrected by the cache.

        A stale lease *object* (same fencing token, older ``expires_at``) is
        silently refreshed to the cached latest before the CAS, so repeat
        holders stay on the zero-ALock fast path no matter which of their
        lease objects they pass in.  A token mismatch is never refreshed —
        that is a different grant and must fail fencing validation.
        """
        lease = self._freshest(p, lease, evict=False)
        renewed = self.table.renew(p, lease, ttl, deadline=deadline)
        if renewed is None:
            self._lease_cache.pop((p.pid, lease.key, lease.mode), None)
        else:
            self._cache_put(p, renewed)
        return renewed

    def upgrade(self, p: Process, lease: Lease,
                ttl: Optional[float] = None) -> Optional[Lease]:
        """SHARED → EXCLUSIVE via the table (sole live reader only); the
        cache swaps the shared entry for the new exclusive grant."""
        lease = self._freshest(p, lease, evict=False)
        upgraded = self.table.upgrade(p, lease, ttl)
        if upgraded is not None:
            self._lease_cache.pop((p.pid, lease.key, lease.mode), None)
            self._cache_put(p, upgraded)
        return upgraded

    def downgrade(self, p: Process, lease: Lease,
                  ttl: Optional[float] = None) -> Optional[Lease]:
        """EXCLUSIVE → SHARED via the table's single-CAS transition; the
        cache swaps the exclusive entry for the new shared grant."""
        lease = self._freshest(p, lease, evict=False)
        downgraded = self.table.downgrade(p, lease, ttl)
        if downgraded is not None:
            self._lease_cache.pop((p.pid, lease.key, lease.mode), None)
            self._cache_put(p, downgraded)
        return downgraded

    # --------------------------------------------------- optimistic read path
    def read_optimistic(self, p: Process, key: str,
                        deadline: Optional[float] = None):
        """Lease-free seqlock read of ``key``'s published payload: 0 RDMA
        for home readers, one doorbell (4 rREADs, 0 CAS) for remote
        readers.  Returns ``(value, publish_token)``; falls back to a
        transient shared lease after bounded instability."""
        return self.table.read_optimistic(p, key, deadline=deadline)

    def publish(self, p: Process, lease: Lease, value,
                deadline: Optional[float] = None) -> bool:
        """Fenced publish of ``value`` under a live EXCLUSIVE ``lease`` so
        optimistic readers can observe it (witness-corrected first, so a
        stale lease object still fences correctly)."""
        return self.table.publish(p, self._freshest(p, lease, evict=False),
                                  value, deadline=deadline)

    def async_client(self, p: Process, flush_ops: int = 8,
                     quantum: float = 100e-6) -> AsyncClient:
        """A per-process futures pipeline over the table: enqueues remote
        ops per destination host and flushes one ``post_batch`` posting per
        scheduling quantum (PR 9 hedged probes from ``p`` ride its
        flushes)."""
        return AsyncClient(self.table, p, flush_ops=flush_ops,
                           quantum=quantum)

    def note_renewed(self, p: Process, lease: Lease,
                     renewed: Optional[Lease]) -> None:
        """Lease-cache maintenance for a renew performed *outside*
        :meth:`renew` — e.g. one that rode an :class:`AsyncClient` flush.
        Keeps later witness-checked releases on the fast path."""
        if renewed is None:
            self._lease_cache.pop((p.pid, lease.key, lease.mode), None)
        else:
            self._cache_put(p, renewed)

    # -------------------------------------------------------- crash recovery
    def reclaim(self, p: Process, lease: Lease,
                ttl: Optional[float] = None,
                deadline: Optional[float] = None) -> Optional[Lease]:
        """Crash-restart re-entry for one lease (see the table's docstring);
        a successful reclaim primes the cache with the fresh witness."""
        got = self.table.reclaim(p, lease, ttl, deadline=deadline)
        if got is not None:
            self._cache_put(p, got)
        else:
            self._lease_cache.pop((p.pid, lease.key, lease.mode), None)
        return got

    def recoverable(self, name: str, p: Process) -> RecoverableClient:
        """A ledger-writing lease client under the durable identity
        ``name``.  First start of an identity; after a crash, use
        :meth:`restart` instead."""
        return RecoverableClient(self.table, p, self.ledgers.ledger(name))

    def restart(self, name: str, p: Process
                ) -> tuple:
        """Crash-restart re-entry for the client identity ``name``: rebind
        its ledger to the new incarnation ``p``, replay it, and reclaim
        every still-valid lease.  Returns ``(client, reclaimed)``; the
        reclaimed leases are primed into the lease cache."""
        client = RecoverableClient(self.table, p, self.ledgers.ledger(name))
        reclaimed = client.restart(p)
        for lease in reclaimed:
            self._cache_put(p, lease)
        return client, reclaimed

    # --------------------------------------------------- failover / takeover
    def membership(self, host: int,
                   policy: Optional[SuspicionPolicy] = None,
                   ) -> HostMembership:
        """This host's membership agent: its heartbeat lease (ledgered under
        the durable identity ``member.h<host>``, so member shards survive
        takeovers with their fencing intact), its suspicion estimator, and
        the partition-guard attestation.  One per host."""
        return HostMembership(
            self.table, self.mem, host, self.num_hosts, policy=policy,
            ledger=self.ledgers.ledger(f"member.h{host}"))

    def takeover_shard(self, p: Process, shard_index: int,
                       membership: Optional[HostMembership] = None,
                       fence_slack: int = 16) -> Optional[Dict[str, int]]:
        """Epoch-fenced takeover of ``shard_index`` onto ``p``'s host,
        rebuilt from the merged stream of ALL ledgers in the service's
        store (see :meth:`ShardedLockTable.takeover_shard`)."""
        return self.table.takeover_shard(
            p, shard_index, self.ledgers.all_records(),
            membership=membership, fence_slack=fence_slack)

    def shards_homed_on(self, host: int) -> List[int]:
        """The shard indices currently homed on ``host`` (a takeover's
        work list when ``host`` is declared dead)."""
        return [s.index for s in self.table.shards if s.home_host == host]

    def telemetry(self) -> List[Dict]:
        return self.table.telemetry()

    def class_totals(self) -> Dict[int, OpCounts]:
        return self.table.class_totals()

    def hot_keys(self, k: int = 10) -> List[List]:
        return self.table.hot_keys(k)

    def inflation_log(self) -> List[List]:
        return self.table.inflation_log()

    def overload_report(self) -> Optional[Dict]:
        """The overload layer's breaker/budget/hedge telemetry, or ``None``
        when the service was built without an :class:`OverloadPolicy`."""
        ctl = self.table.overload
        return None if ctl is None else ctl.report()

    # ------------------------------------------------------------ named locks
    def lock(self, name: str, home_host: int = 0) -> ALock:
        """A singleton control-plane lock pinned to an explicit home host."""
        with self._guard:
            lk = self._locks.get(name)
            if lk is None:
                lk = ALock(
                    self.mem, home_host, self._init_budget, name=f"svc.{name}"
                )
                self._locks[name] = lk
            assert lk.home_node == home_host, f"lock {name} homed elsewhere"
            return lk

    # ------------------------------------------------------------- election
    def elect(self, name: str, p: Process, epoch: int, home_host: int = 0) -> bool:
        """First-past-the-post election for ``epoch`` (e.g. checkpoint writer).

        Exactly one caller per epoch returns True.  The claim register lives on
        ``home_host``; the ALock around it gives each class its cost-optimal
        path per the paper.
        """
        lk = self.lock(name, home_host)
        key = f"svc.{name}.claim"
        with self._guard:
            reg = self._claims.get(key)
            if reg is None:
                reg = self.mem.alloc(home_host, key, -1)
                self._claims[key] = reg
        with lk.guard(p):
            cur = self.mem.auto_read(p, reg)
            if cur < epoch:
                self.mem.auto_write(p, reg, epoch)
                return True
            return False


class Barrier:
    """Sense-reversing barrier whose count register is guarded by an ALock.

    Used for elastic-membership epochs: all surviving hosts must arrive before
    the job re-meshes.  The count update runs in an ALock critical section
    (read-modify-write of a shared record under operation asymmetry — the
    exact situation where a naive mixed CAS would be unsound, Table 1).
    """

    def __init__(self, svc: CoordinationService, name: str, parties: int, home_host: int = 0):
        self.svc = svc
        self.parties = parties
        self.lock = svc.lock(f"{name}.bar", home_host)
        self.count = svc.mem.alloc(home_host, f"{name}.count", 0)
        self.generation = svc.mem.alloc(home_host, f"{name}.gen", 0)

    def wait(self, p: Process, timeout: float = 30.0) -> int:
        mem = self.svc.mem
        with self.lock.guard(p):
            gen = mem.auto_read(p, self.generation)
            n = mem.auto_read(p, self.count) + 1
            if n == self.parties:
                mem.auto_write(p, self.count, 0)
                mem.auto_write(p, self.generation, gen + 1)
                return gen
            mem.auto_write(p, self.count, n)
        # The deadline runs on the *table's* clock, not a hardcoded
        # time.monotonic: when the service was built with an injected clock
        # (tests' FakeClock, the sim engine's virtual clock), mixing time
        # bases would make the timeout fire never — or immediately.
        clock = self.svc.table.clock
        deadline = clock() + timeout
        while mem.auto_read(p, self.generation) == gen:
            if clock() > deadline:
                raise TimeoutError(f"barrier timeout (gen {gen}, {n}/{self.parties})")
            mem.yield_point()
        return gen
