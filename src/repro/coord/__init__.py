"""Host-level coordination built on the paper's ALock (control plane).

``ShardedLockTable`` spreads lock shards over every host so the paper's
per-class cost optimality covers the whole keyspace; ``CoordinationService``
wraps it together with named locks, elections and barriers.
"""

from .faults import CRASH_POINTS, ClientCrash, FaultInjector  # noqa: F401
from .inflation import ContentionEstimator, InflationPolicy  # noqa: F401
from .ledger import (LeaseLedger, LedgerRecord, LedgerStore,  # noqa: F401
                     LedgerView, RecoverableClient, replay_records)
from .service import Barrier, CoordinationService  # noqa: F401
from .table import (Lease, LeaseMode, LockShard, ShardedLockTable,  # noqa: F401
                    stable_key_hash)
