"""Host-level coordination built on the paper's ALock (control plane).

``ShardedLockTable`` spreads lock shards over every host so the paper's
per-class cost optimality covers the whole keyspace; ``CoordinationService``
wraps it together with named locks, elections and barriers.  The failover
stack (``membership`` + ``takeover_shard``) keeps the table self-healing:
lease-based heartbeats detect dead homes and the deterministic successor
re-homes their shards under an epoch fence.
"""

from .faults import (CRASH_POINTS, FABRIC_POINTS, ClientCrash,  # noqa: F401
                     FaultInjector)
from .inflation import ContentionEstimator, InflationPolicy  # noqa: F401
from .ledger import (LeaseLedger, LedgerRecord, LedgerStore,  # noqa: F401
                     LedgerView, RecoverableClient, replay_records)
from .membership import (ALIVE, DEAD, SUSPECT, HostMembership,  # noqa: F401
                         SuspicionEstimator, SuspicionPolicy, member_key_for)
from .overload import (CircuitBreaker, LatencyTracker,  # noqa: F401
                       OverloadControl, OverloadPolicy, RetryBudget)
from .pipeline import AsyncClient, PipelineFuture  # noqa: F401
from .service import Barrier, CoordinationService  # noqa: F401
from .table import (Lease, LeaseMode, LockShard, ShardedLockTable,  # noqa: F401
                    forwarded_home, stable_key_hash)
