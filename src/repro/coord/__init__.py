"""Host-level coordination built on the paper's ALock (control plane)."""

from .service import Barrier, CoordinationService  # noqa: F401
