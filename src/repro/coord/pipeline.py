"""Futures-based async client pipeline: cross-call doorbell coalescing.

PR 2 taught single table transactions to post their WR lists in one
doorbell (:meth:`~repro.core.AsymmetricMemory.post_batch`); this module
generalises that from *per-call* to *cross-call* batching, the load-aware
client direction the RDMA lock-service literature argues for.  An
:class:`AsyncClient` exposes futures-based ``acquire`` / ``renew`` /
``release`` / ``read_optimistic``: each call enqueues a work request on a
per-destination-host queue and returns a :class:`PipelineFuture`; the
queue flushes as **one mixed** ``post_batch`` posting per host — seqlock
read sets, renewal witness CASes and release witness CASes legally share
a WR list because a posting targets one node and executes its entries in
order — so N client calls cost one doorbell instead of N.

Flush triggers (the "scheduling quantum"):

* **size** — a host queue reaching ``flush_ops`` entries flushes at
  enqueue time;
* **deadline** — :meth:`poll` flushes any queue whose oldest entry has
  waited longer than ``quantum`` on the table's (virtual or wall) clock;
* **explicit** — :meth:`flush` drains everything, e.g. at client exit.

PR 9 overload semantics are preserved *per op*: remote enqueues pass the
destination's admission gate, per-op absolute deadlines are checked at
enqueue and again at flush (an expired op fails its future with
:class:`~repro.core.DeadlineExceeded` instead of posting doomed work),
and an optimistic read re-enqueued after an unstable snapshot spends the
destination's retry budget exactly like a blocking acquire's retry round.

Ops whose destination is the caller's own host never enqueue: they run
inline at call time (the home class pays zero simulated RDMA either way,
and delaying a free operation buys nothing).  Multi-step operations that
cannot ride a single WR entry (exclusive/shared acquires, slow-path
renews/releases, fallback reads) execute inline at flush time, so the
futures API stays uniform while the fast paths get the batching.

The table's hedged probes also ride the pipeline (:meth:`ride_read`):
a hedge admitted by the retry budget is appended to the probed host's
queue and flushed immediately — it shares the posting with whatever was
queued instead of paying its own doorbell (see ``table._probe``).

Determinism: queues are plain FIFOs, hosts flush in sorted order, and
every time source is the table's injected clock — two same-seed sim runs
produce byte-identical counters (the CI ``read-pipeline-smoke`` gate).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core import DeadlineExceeded, Process, RemoteTimeout

from .table import (LeaseMode, Lease, ShardedLockTable, _OPT_ATTEMPTS,
                    _enc)


class PipelineFuture:
    """Resolution slot for one pipelined op.

    Not thread-aware: a pipeline belongs to one coordination process (the
    spawn contract makes a pid single-threaded), so the future resolves
    during that process's own ``poll``/``flush`` calls.  ``result()`` on
    an unresolved future raises — flush first.
    """

    __slots__ = ("_done", "_value", "_exc")

    def __init__(self):
        self._done = False
        self._value = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done

    def result(self):
        if not self._done:
            raise RuntimeError(
                "pipeline future unresolved: flush() or poll() the client")
        if self._exc is not None:
            raise self._exc
        return self._value

    def exception(self) -> Optional[BaseException]:
        return self._exc if self._done else None

    def _resolve(self, value) -> None:
        self._done = True
        self._value = value

    def _fail(self, exc: BaseException) -> None:
        self._done = True
        self._exc = exc


class _Op:
    """One queued work request (kind: read | renew | release | acquire |
    rawread)."""

    __slots__ = ("kind", "key", "lease", "ttl", "mode", "deadline",
                 "future", "attempts", "reg", "enq_at")

    def __init__(self, kind, future, enq_at, key=None, lease=None, ttl=None,
                 mode=None, deadline=None, reg=None):
        self.kind = kind
        self.future = future
        self.enq_at = enq_at
        self.key = key
        self.lease = lease
        self.ttl = ttl
        self.mode = mode
        self.deadline = deadline
        self.reg = reg
        self.attempts = 0


class AsyncClient:
    """Per-process async pipeline over one :class:`ShardedLockTable`.

    ``flush_ops`` is the size trigger (a host queue this long flushes at
    enqueue); ``quantum`` is the deadline trigger (``poll`` flushes any
    queue whose head has waited this long).  Both run on the table's
    injected clock.
    """

    def __init__(self, table: ShardedLockTable, p: Process,
                 flush_ops: int = 8, quantum: float = 100e-6):
        if flush_ops <= 0:
            raise ValueError("flush_ops must be > 0")
        self.table = table
        self.p = p
        self.flush_ops = flush_ops
        self.quantum = quantum
        self._q: Dict[int, List[_Op]] = {}
        #: flushes = postings sent; flushed_ops = ops resolved off queues;
        #: inline_ops = multi-step ops run at flush; hedge_rides = hedges
        #: that shared a posting with queued work.
        self.stats = {"flushes": 0, "flushed_ops": 0, "inline_ops": 0,
                      "reads_batched": 0, "renews_batched": 0,
                      "releases_batched": 0, "hedge_rides": 0}
        table.attach_pipeline(p, self)

    # ------------------------------------------------------------- enqueue
    def _home_of_key(self, key: str) -> int:
        return self.table.shards[self.table.shard_of(key)].home_host

    def _enq(self, host: int, op: _Op) -> None:
        q = self._q.setdefault(host, [])
        q.append(op)
        if len(q) >= self.flush_ops:
            self._flush_host(host)

    def _gate(self, host: int, fut: PipelineFuture) -> bool:
        """PR 9 admission at enqueue: a remote op whose destination sheds
        fails its future immediately — zero fabric ops, same posture as
        try_acquire's gate."""
        ctl = self.table.overload
        if ctl is None or self.p.node == host:
            return True
        try:
            ctl.admit_remote(host, self.table.clock())
        except Exception as exc:  # Overloaded (typed in repro.core)
            fut._fail(exc)
            return False
        return True

    def read_optimistic(self, key: str,
                        deadline: Optional[float] = None) -> PipelineFuture:
        """Pipelined seqlock read; resolves to ``(value, publish_token)``,
        or to ``None`` when a live writer holds the key (re-issue after a
        backoff — the table never waits out a holder internally).

        Home keys resolve inline (0 RDMA, nothing to batch); remote keys
        enqueue one 4-entry WR read set that rides the host's next flush
        — N reads to one host cost ONE doorbell and zero CAS.
        """
        fut = PipelineFuture()
        home = self._home_of_key(key)
        if self.p.node == home:
            try:
                fut._resolve(self.table.read_optimistic(
                    self.p, key, deadline=deadline))
            except Exception as exc:
                fut._fail(exc)
            return fut
        if self._gate(home, fut):
            self._enq(home, _Op("read", fut, self.table.clock(), key=key,
                                deadline=deadline))
        return fut

    def acquire(self, key: str, ttl: float,
                mode: LeaseMode = LeaseMode.EXCLUSIVE,
                deadline: Optional[float] = None) -> PipelineFuture:
        """Pipelined non-blocking acquire; resolves to a Lease or None.

        A lease grant is a multi-step transaction (CS engagement or a
        shared join loop), so it executes inline at flush time — the
        pipeline contributes latency batching and the shared admission
        gate, not WR merging, for this op kind.
        """
        fut = PipelineFuture()
        home = self._home_of_key(key)
        if self.p.node == home:
            try:
                fut._resolve(self.table.try_acquire(self.p, key, ttl,
                                                    mode=mode))
            except Exception as exc:
                fut._fail(exc)
            return fut
        # No enqueue-time gate: try_acquire runs the PR 9 admission gate
        # itself at flush time (gating here too would consume a half-open
        # breaker trial twice for one attempt).
        self._enq(home, _Op("acquire", fut, self.table.clock(), key=key,
                            ttl=ttl, mode=mode, deadline=deadline))
        return fut

    def renew(self, lease: Lease, ttl: Optional[float] = None,
              deadline: Optional[float] = None) -> PipelineFuture:
        """Pipelined renew; resolves to the renewed Lease or None.

        An EXCLUSIVE renewal is a single witness CAS, so it rides the
        flush posting as one WR; SHARED (multi-step) renews run inline at
        flush.
        """
        fut = PipelineFuture()
        home = self.table.shards[lease.shard].home_host
        if self.p.node == home:
            try:
                fut._resolve(self.table.renew(self.p, lease, ttl,
                                              deadline=deadline))
            except Exception as exc:
                fut._fail(exc)
            return fut
        if self._gate(home, fut):
            self._enq(home, _Op("renew", fut, self.table.clock(),
                                lease=lease, ttl=ttl, deadline=deadline))
        return fut

    def release(self, lease: Lease,
                deadline: Optional[float] = None) -> PipelineFuture:
        """Pipelined release; resolves to True iff the lease was current.

        EXCLUSIVE fast-path releases ride the flush as one witness-CAS WR
        (so a release shares a doorbell with queued reads/renews); misses
        and SHARED releases settle inline through the table's slow paths.
        """
        fut = PipelineFuture()
        home = self.table.shards[lease.shard].home_host
        if self.p.node == home:
            try:
                fut._resolve(self.table.release(self.p, lease))
            except Exception as exc:
                fut._fail(exc)
            return fut
        if self._gate(home, fut):
            self._enq(home, _Op("release", fut, self.table.clock(),
                                lease=lease, deadline=deadline))
        return fut

    # ------------------------------------------------------------ flushing
    def pending(self) -> int:
        return sum(len(q) for q in self._q.values())

    def poll(self) -> None:
        """Deadline-triggered flush: drain every host queue whose oldest
        entry has waited at least one quantum (or that hit the size
        trigger between enqueues)."""
        now = self.table.clock()
        for host in sorted(self._q):
            q = self._q.get(host)
            if q and (len(q) >= self.flush_ops
                      or now - q[0].enq_at >= self.quantum):
                self._flush_host(host)

    def flush(self) -> None:
        """Explicit flush of every host queue (e.g. client shutdown)."""
        for host in sorted(self._q):
            self._flush_host(host)

    def sync(self, fut: PipelineFuture):
        """Settle ``fut`` now: flush if it is still queued, then return
        its result (re-raising its failure) — the bridge for blocking
        call sites like ``BatchAdmission.keepalive``."""
        if not fut.done():
            self.flush()
        return fut.result()

    def ride_read(self, reg):
        """Hedge transport (see ``table._probe``): append one idempotent
        read for ``reg`` to its host's queue and flush that host NOW —
        the hedge shares the posting with any queued work instead of
        posting its own doorbell.  Blocking: returns the read value.
        The caller's own op accounting covers the posting (account=False),
        so the hedge is never double-counted."""
        fut = PipelineFuture()
        host = reg.node
        if self._q.get(host):
            self.stats["hedge_rides"] += 1
        self._q.setdefault(host, []).append(
            _Op("rawread", fut, self.table.clock(), reg=reg))
        self._flush_host(host, account=False)
        return fut.result()

    def _flush_host(self, host: int, account: bool = True) -> None:
        q = self._q.pop(host, None)
        if not q:
            return
        table, p = self.table, self.p
        now = table.clock()
        wrs: List[tuple] = []
        spans: List[Tuple[_Op, int, object]] = []  # (op, n_wrs, ctx)
        inline: List[_Op] = []
        requeue: List[_Op] = []
        for op in q:
            if op.deadline is not None and now >= op.deadline:
                self._fail_deadline(op)
                continue
            if op.kind == "read":
                shard = table.shards[table.shard_of(op.key)]
                if shard.home_host != host:
                    inline.append(op)  # re-homed mid-queue: settle inline
                    continue
                st = table._key_state(shard, op.key)
                wrs.extend(table._opt_read_wrs(st))
                spans.append((op, 4, shard))
            elif op.kind == "renew" and self._fast_renewable(op, now):
                lease, ttl = op.lease, (op.ttl if op.ttl is not None
                                        else op.lease.ttl)
                st = table._key_state(table.shards[lease.shard], lease.key)
                witness = lease.witness()
                wrs.append(("cas", st.expires, witness,
                            (lease.token, _enc(0, lease.inflated),
                             now + ttl)))
                spans.append((op, 1, (witness, now + ttl, ttl)))
            elif op.kind == "release" and self._fast_releasable(op):
                lease = op.lease
                st = table._key_state(table.shards[lease.shard], lease.key)
                witness = lease.witness()
                wrs.append(("cas", st.expires, witness,
                            (lease.token, _enc(0, lease.inflated), 0.0)))
                spans.append((op, 1, witness))
            elif op.kind == "rawread":
                wrs.append(("read", op.reg))
                spans.append((op, 1, None))
            else:
                inline.append(op)
        if wrs:
            snap = p.counts.as_tuple()
            vals = None
            try:
                vals = table.mem.post_batch(p, wrs)
            except RemoteTimeout as exc:
                for op, _n, _ctx in spans:
                    op.future._fail(exc)
            finally:
                if account:
                    # One merged posting, accounted once — to the first
                    # spanned op's shard (same host, same class; rawread
                    # hedges are covered by their caller's own window).
                    ashard = next((c for o, _n, c in spans
                                   if o.kind == "read"), None)
                    if ashard is None:
                        for o, _n, _c in spans:
                            if o.lease is not None:
                                ashard = table.shards[o.lease.shard]
                                break
                    if ashard is not None:
                        table._account(ashard, p, snap, LeaseMode.SHARED)
            self.stats["flushes"] += 1
            if vals is not None:
                off = 0
                for op, n, ctx in spans:
                    chunk = vals[off:off + n]
                    off += n
                    self._demux(op, chunk, ctx, now, requeue)
                self.stats["flushed_ops"] += len(spans)
        for op in inline:
            self._run_inline(op)
            self.stats["inline_ops"] += 1
        for op in requeue:
            self._enq(host, op)

    # ------------------------------------------------------------- helpers
    def _fast_renewable(self, op: _Op, now: float) -> bool:
        lease = op.lease
        return (lease.mode == LeaseMode.EXCLUSIVE
                and now < lease.expires_at)

    def _fast_releasable(self, op: _Op) -> bool:
        return op.lease.mode == LeaseMode.EXCLUSIVE

    def _fail_deadline(self, op: _Op) -> None:
        table = self.table
        shard = (table.shards[op.lease.shard] if op.lease is not None
                 else table.shards[table.shard_of(op.key)])
        with shard._meta:
            shard.deadline_exceeded += 1
        op.future._fail(DeadlineExceeded(
            f"pipelined {op.kind} of "
            f"{(op.key or op.lease.key)!r}: deadline passed"))

    def _demux(self, op: _Op, chunk: list, ctx, now: float,
               requeue: List[_Op]) -> None:
        table, p = self.table, self.p
        if op.kind == "rawread":
            op.future._resolve(chunk[0])
            return
        if op.kind == "read":
            shard = ctx
            w1, payload, w2, barrier = chunk
            verdict, out = table._opt_read_verdict(now, w1, payload, w2,
                                                   barrier)
            if verdict == "ok":
                with shard._meta:
                    shard.opt_reads += 1
                self.stats["reads_batched"] += 1
                op.future._resolve(out)
                return
            with shard._meta:
                if verdict == "forward":
                    shard.opt_read_fwd += 1
                else:
                    shard.opt_read_retries += 1
            op.attempts += 1
            if op.attempts >= _OPT_ATTEMPTS:
                # Bounded failures: degrade to the shared-lease fallback,
                # inline (multi-step), same as the blocking read path.
                # A refused join (live writer) resolves the future to
                # None — the caller re-issues, same retry contract as
                # the blocking read and try_acquire.
                with shard._meta:
                    shard.opt_read_fallbacks += 1
                try:
                    op.future._resolve(table._opt_read_fallback(
                        p, op.key, 1.0))
                except Exception as exc:
                    op.future._fail(exc)
                return
            # Retry rides the NEXT flush; each re-enqueue spends the
            # destination's retry budget like a blocking retry round.
            ctl = table.overload
            if ctl is not None:
                try:
                    ctl.spend_retry(shard.home_host)
                except Exception as exc:
                    op.future._fail(exc)
                    return
            op.enq_at = now
            requeue.append(op)
            return
        if op.kind == "renew":
            witness, new_exp, ttl = ctx
            lease = op.lease
            if chunk[0] == witness:
                shard = table.shards[lease.shard]
                with shard._meta:
                    shard.fast_renews += 1
                self.stats["renews_batched"] += 1
                op.future._resolve(Lease(
                    lease.key, lease.shard, lease.holder_pid, lease.token,
                    new_exp, ttl, LeaseMode.EXCLUSIVE, lease.inflated))
            else:
                # Witness missed inside the posting: settle through the
                # table's fully validated slow path.
                try:
                    op.future._resolve(table.renew(p, lease, op.ttl))
                except Exception as exc:
                    op.future._fail(exc)
            return
        if op.kind == "release":
            witness = ctx
            lease = op.lease
            if chunk[0] == witness:
                shard = table.shards[lease.shard]
                with shard._meta:
                    shard.fast_releases += 1
                self.stats["releases_batched"] += 1
                if lease.inflated:
                    st = table._key_state(shard, lease.key)
                    table._inflated_handoff(p, shard, st, lease.key, lease)
                op.future._resolve(True)
            else:
                try:
                    op.future._resolve(table.release(p, lease))
                except Exception as exc:
                    op.future._fail(exc)
            return
        raise AssertionError(f"unknown op kind {op.kind!r}")

    def _run_inline(self, op: _Op) -> None:
        table, p = self.table, self.p
        try:
            if op.kind == "acquire":
                op.future._resolve(table.try_acquire(p, op.key, op.ttl,
                                                     mode=op.mode))
            elif op.kind == "read":
                op.future._resolve(table.read_optimistic(
                    p, op.key, deadline=op.deadline))
            elif op.kind == "renew":
                op.future._resolve(table.renew(p, op.lease, op.ttl,
                                               deadline=op.deadline))
            elif op.kind == "release":
                op.future._resolve(table.release(p, op.lease))
            else:
                raise AssertionError(f"unknown op kind {op.kind!r}")
        except Exception as exc:
            op.future._fail(exc)
