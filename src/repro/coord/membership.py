"""Lease-based cluster membership: heartbeats in the lock table itself.

Failure detection needs no new machinery when the cluster already runs a
lease service — a host's liveness *is* a lease.  Every host holds an
exclusive lease on its own **member key**, chosen (by salted search, the
same trick the benchmarks use) to hash to a shard homed on that host, so
the heartbeat renewal rides the paper's asymmetric fast path: **0 RDMA
ops** for the owner (local CAS on its own word), and any observer can read
the word with **1 remote read** — or :meth:`~repro.core.memory.
AsymmetricMemory.probe`, which returns :data:`~repro.core.memory.TIMEOUT`
instead of blocking when the fabric has eaten the host.

Detection is *sliding-window suspicion*, the same two-bucket estimator
shape as :class:`~repro.coord.inflation.ContentionEstimator`: each monitor
sweep probes every member word and notes a **miss** (expired word, or
probe timeout) or a **beat** (live word) into per-host buckets.  The
windowed miss rate drives a three-state verdict with hysteresis:

    ALIVE --[windowed misses ≥ suspect_misses]--> SUSPECT
    SUSPECT --[dead_misses CONSECUTIVE misses AND missing ≥ ttl]--> DEAD
    SUSPECT/DEAD --[recover_beats consecutive beats]--> ALIVE

(The windowed rate drives suspicion; the DEAD escalation is a streak —
a monitor whose sweep cycle stretches under probe timeouts must not have
its evidence decay out of the window faster than it accumulates.)

Successor choice is deterministic rank order: the successor of host *h* is
the next non-DEAD host after *h* (mod ``num_hosts``), so every observer
that agrees on the verdict vector agrees on who takes over — no election
round, no extra RDMA.

**Partition guard** (the rule that keeps a minority island from serving
stale grants): a monitor sweep that observes a live *majority* of member
words at time *t* attests the local host may serve until ``t +
guard_ttl``.  Because ``guard_ttl`` is strictly less than the time it
takes the majority side to declare a host DEAD (``ttl`` plus the suspicion
window), a partitioned minority's attestation lapses — and it degrades to
read-only lease validation — **before** any majority-side successor can
win a takeover.  That ordering is the safety argument (the classic
lease-based fencing discipline); ``docs/recovery.md`` has the proof
sketch.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Generator, List, Optional, Tuple

from ..core.memory import TIMEOUT, AsymmetricMemory, Process
from .ledger import LeaseLedger, RecoverableClient
from .table import ShardedLockTable, stable_key_hash

__all__ = [
    "ALIVE",
    "DEAD",
    "SUSPECT",
    "HostMembership",
    "SuspicionEstimator",
    "SuspicionPolicy",
    "member_key_for",
]

# Verdicts, ordered by severity.
ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"


def member_key_for(table: ShardedLockTable, host: int,
                   num_hosts: int) -> str:
    """The member key for ``host``: a salted key that hashes to a shard
    homed on ``host`` itself, so the owner's heartbeat renewal is the
    0-RDMA local fast path.  Deterministic (first salt that lands)."""
    for salt in range(1 << 16):
        key = f"member/{host}/{salt}"
        s = stable_key_hash(key) % table.num_shards
        if s % num_hosts == host:
            return key
    raise RuntimeError(f"no member key found for host {host}")  # pragma: no cover


@dataclass
class SuspicionPolicy:
    """Tunables for the suspicion estimator and the partition guard.

    ``ttl`` is the member-lease TTL (seconds of virtual time); everything
    else is derived from it by default so a single knob scales the whole
    detector.  ``guard_ttl`` must undercut the detection time — the
    constructor enforces the fencing inequality."""

    ttl: float = 5e-3
    #: Heartbeat renew period; must leave slack under ``ttl``.
    beat_every: float = 0.0
    #: Monitor sweep period.
    sweep_every: float = 0.0
    #: Sliding-window width for the two-bucket miss estimator.
    window: float = 0.0
    #: Windowed misses at which a host becomes SUSPECT.
    suspect_misses: float = 2.0
    #: CONSECUTIVE misses at which SUSPECT escalates to DEAD (the host
    #: must also have been missing for at least ``ttl``).
    dead_misses: float = 4.0
    #: Consecutive live beats that clear SUSPECT/DEAD back to ALIVE.
    recover_beats: int = 3
    #: How long one majority attestation permits serving.
    guard_ttl: float = 0.0

    def __post_init__(self) -> None:
        if self.ttl <= 0:
            raise ValueError("ttl must be positive")
        if not self.beat_every:
            self.beat_every = self.ttl / 4.0
        if not self.sweep_every:
            self.sweep_every = self.ttl / 4.0
        if not self.window:
            self.window = 2.0 * self.ttl
        if not self.guard_ttl:
            self.guard_ttl = self.ttl
        if self.beat_every >= self.ttl:
            raise ValueError("beat_every must undercut ttl")
        if self.sweep_every > self.ttl:
            raise ValueError("sweep_every must not exceed ttl")
        if self.suspect_misses <= 0 or self.dead_misses < self.suspect_misses:
            raise ValueError("need 0 < suspect_misses <= dead_misses")
        if self.recover_beats < 1:
            raise ValueError("recover_beats must be >= 1")
        # The fencing inequality: a minority's attestation must lapse
        # before the majority side can possibly declare it DEAD.  A DEAD
        # verdict needs the host missing for >= ttl measured from its
        # FIRST missed probe (strictly after the cut began), so any
        # guard_ttl <= ttl lapses the island's attestation first.
        if self.guard_ttl > self.ttl:
            raise ValueError(
                f"guard_ttl ({self.guard_ttl:g}) must not exceed ttl "
                f"({self.ttl:g}) — the attestation must lapse before any "
                f"observer can reach a DEAD verdict")


class _HostHeat:
    """Two-bucket sliding-window miss counter for one monitored host —
    the same shape as ``ContentionEstimator._KeyHeat``, with a beat
    streak for hysteresis bolted on."""

    __slots__ = ("bucket", "count", "prev", "beats", "streak", "verdict",
                 "expired_since", "died_at")

    def __init__(self) -> None:
        self.bucket = -1        # window index of `count`
        self.count = 0.0        # misses in the current window
        self.prev = 0.0         # misses in the previous window
        self.beats = 0          # consecutive live beats
        self.streak = 0         # consecutive misses
        self.verdict = ALIVE
        self.expired_since: Optional[float] = None
        self.died_at: Optional[float] = None


class SuspicionEstimator:
    """Windowed miss-rate failure detector with hysteresis.

    Feed it one observation per monitored host per sweep — :meth:`beat`
    for a live word, :meth:`miss` for an expired word or probe timeout —
    and read the verdict back.  Misses age out on the two-bucket window
    (current bucket plus a linearly-decayed share of the previous one), so
    a burst of losses long past does not keep a host SUSPECT forever."""

    def __init__(self, policy: Optional[SuspicionPolicy] = None) -> None:
        self.policy = policy or SuspicionPolicy()
        self._heat: Dict[int, _HostHeat] = {}
        #: Verdict transitions: (t, host, old, new), for the event log.
        self.transitions: List[Tuple[float, int, str, str]] = []

    # ---------------------------------------------------------- internals
    def _entry(self, host: int) -> _HostHeat:
        h = self._heat.get(host)
        if h is None:
            h = self._heat[host] = _HostHeat()
        return h

    @staticmethod
    def _shift(h: _HostHeat, b: int) -> None:
        if b != h.bucket:
            h.prev = h.count if b == h.bucket + 1 else 0.0
            h.count = 0.0
            h.bucket = b

    def _rate(self, h: _HostHeat, now: float) -> float:
        w = self.policy.window
        b = int(now / w)
        self._shift(h, b)
        frac = now / w - b
        return h.count + h.prev * (1.0 - frac)

    def _set(self, h: _HostHeat, host: int, verdict: str,
             now: float) -> None:
        if verdict != h.verdict:
            self.transitions.append((round(now, 9), host, h.verdict, verdict))
            h.verdict = verdict

    # -------------------------------------------------------- observation
    def beat(self, host: int, now: float) -> str:
        """A sweep saw a live, unexpired member word for ``host``."""
        h = self._entry(host)
        self._shift(h, int(now / self.policy.window))
        h.expired_since = None
        h.streak = 0
        h.beats += 1
        if h.verdict != ALIVE and h.beats >= self.policy.recover_beats:
            h.died_at = None
            self._set(h, host, ALIVE, now)
        return h.verdict

    def miss(self, host: int, now: float, expired: bool) -> str:
        """A sweep saw an expired word (``expired=True``) or the probe
        timed out entirely (``expired=False`` — the fabric ate it).

        Either flavour starts the DEAD-eligibility clock: a dead host's
        member word is *unreachable*, not observably expired, so the
        streak start (``expired_since``) marks the first miss of the
        current uninterrupted run — after ``ttl`` of continuous missing
        the member lease has lapsed whichever flavour we saw.  (A host
        that is alive behind a cut keeps renewing locally; the
        successor's :meth:`HostMembership.confirm_dead` re-probe after
        the heal is what catches that race.)"""
        h = self._entry(host)
        h.beats = 0
        h.streak += 1
        if h.expired_since is None:
            h.expired_since = now
        b = int(now / self.policy.window)
        self._shift(h, b)
        h.count += 1.0
        rate = h.count + h.prev * (1.0 - (now / self.policy.window - b))
        p = self.policy
        if h.verdict == ALIVE and rate >= p.suspect_misses:
            self._set(h, host, SUSPECT, now)
        # DEAD is a streak, not a windowed rate: under probe timeouts the
        # sweep cycle stretches, and windowed evidence would decay as fast
        # as it accrues.  The duration term anchors the fencing proof —
        # it is measured from the first miss, strictly after any cut.
        if (h.verdict == SUSPECT and h.streak >= p.dead_misses
                and now - h.expired_since >= p.ttl):
            h.died_at = now
            self._set(h, host, DEAD, now)
        return h.verdict

    def suspect(self, host: int, now: float) -> str:
        """Out-of-band SUSPECT evidence — e.g. an open circuit breaker at
        the overload layer, meaning the host is slow or unreachable *from
        here*.  Marks an ALIVE host SUSPECT and nothing more: it feeds
        neither the miss streak nor the DEAD-eligibility clock, so breaker
        evidence can never escalate to DEAD (only missed heartbeats may
        kill — an overloaded-but-alive host must not lose its shards to a
        takeover it would immediately contest)."""
        h = self._entry(host)
        if h.verdict == ALIVE:
            h.beats = 0
            self._set(h, host, SUSPECT, now)
        return h.verdict

    # ------------------------------------------------------------- verdict
    def verdict(self, host: int) -> str:
        h = self._heat.get(host)
        return h.verdict if h is not None else ALIVE

    def rate(self, host: int, now: float) -> float:
        h = self._heat.get(host)
        return self._rate(h, now) if h is not None else 0.0

    def died_at(self, host: int) -> Optional[float]:
        h = self._heat.get(host)
        return h.died_at if h is not None else None


class HostMembership:
    """One host's view of the cluster: its own heartbeat lease, its
    monitor's suspicion estimator, and the partition-guard attestation.

    Built per host by :meth:`~repro.coord.service.CoordinationService.
    membership`.  The heartbeat and monitor loops are sim-task generators
    (:meth:`heartbeat_task`, :meth:`monitor_task`) so workloads spawn them
    alongside client fleets; threaded callers can drive :meth:`beat_once`
    and :meth:`sweep_once` directly."""

    def __init__(self, table: ShardedLockTable, mem: AsymmetricMemory,
                 host: int, num_hosts: int,
                 policy: Optional[SuspicionPolicy] = None,
                 ledger: Optional[LeaseLedger] = None) -> None:
        self.table = table
        self.mem = mem
        self.host = int(host)
        self.num_hosts = int(num_hosts)
        self.policy = policy or SuspicionPolicy()
        self.estimator = SuspicionEstimator(self.policy)
        #: member key per host, identical on every observer (pure hash).
        self.member_keys: Tuple[str, ...] = tuple(
            member_key_for(table, h, num_hosts) for h in range(num_hosts))
        self.p: Process = mem.spawn(self.host)
        self.ledger = ledger if ledger is not None else LeaseLedger(
            f"member.h{self.host}")
        self.client = RecoverableClient(table, self.p, self.ledger)
        self._lease = None
        #: latest majority attestation time (None = never attested).
        self.attested_at: Optional[float] = None
        #: sweeps that saw a live majority / that did not.
        self.attestations = 0
        self.quorum_losses = 0
        #: serve-permission refusals observed via :meth:`can_serve`.
        self.guard_blocks = 0
        self.stopped = False

    # ---------------------------------------------------------- heartbeat
    def beat_once(self) -> bool:
        """Acquire or renew this host's member lease.  Returns whether the
        lease is held after the call.  Renewal is the owner-local fast
        path: the member key's shard is homed here by construction."""
        key = self.member_keys[self.host]
        ttl = self.policy.ttl
        if self._lease is not None:
            renewed = self.client.renew(self._lease, ttl)
            if renewed is not None:
                self._lease = renewed
                return True
            self._lease = None
        lease = self.client.try_acquire(key, ttl)
        if lease is not None:
            self._lease = lease
            return True
        return False

    def heartbeat_task(self) -> Generator:
        """Sim task: renew the member lease every ``beat_every``."""
        while not self.stopped:
            self.beat_once()
            yield self.policy.beat_every

    # ------------------------------------------------------------ monitor
    def sweep_once(self) -> Dict[int, str]:
        """Probe every member word once and feed the estimator; refresh
        the majority attestation if enough words were live.  Returns the
        verdict vector."""
        now = self.table.clock()
        live = 0
        for h in range(self.num_hosts):
            if h == self.host:
                # Our own beat is ground truth; no self-probe.
                self.estimator.beat(h, now)
                live += 1
                continue
            key = self.member_keys[h]
            shard = self.table.shards[self.table.shard_of(key)]
            st = self.table._key_state(shard, key)
            word = self.mem.probe(self.p, st.expires)
            if word is TIMEOUT:
                self.estimator.miss(h, now, expired=False)
                continue
            _tok, _readers, expires_at = word
            if expires_at > now:
                self.estimator.beat(h, now)
                live += 1
            else:
                self.estimator.miss(h, now, expired=True)
        # Overload composition: an open breaker (the table's overload layer
        # refusing a host it found slow/timing out from here) is SUSPECT
        # evidence — and only that.  It never feeds the miss streak or the
        # DEAD clock, and quorum attestation above runs on probe ground
        # truth alone (a congested majority must still attest).
        ctl = self.table.overload
        if ctl is not None:
            for h in ctl.open_hosts():
                if h != self.host and 0 <= h < self.num_hosts:
                    self.estimator.suspect(h, now)
        if 2 * live > self.num_hosts:
            self.attested_at = now
            self.attestations += 1
        else:
            self.quorum_losses += 1
        return {h: self.estimator.verdict(h) for h in range(self.num_hosts)}

    def monitor_task(self) -> Generator:
        """Sim task: sweep every ``sweep_every``."""
        while not self.stopped:
            self.sweep_once()
            yield self.policy.sweep_every

    # ----------------------------------------------------- partition guard
    def can_serve(self) -> bool:
        """Forward-valid quorum attestation: True iff a sweep observed a
        live majority within the last ``guard_ttl``.  A minority island's
        attestation lapses before the majority can declare it dead, so
        refusing to serve here is exactly the fencing rule."""
        now = self.table.clock()
        ok = (self.attested_at is not None
              and now - self.attested_at < self.policy.guard_ttl)
        if not ok:
            self.guard_blocks += 1
        return ok

    # ----------------------------------------------------------- successor
    def live_hosts(self) -> List[int]:
        return [h for h in range(self.num_hosts)
                if self.estimator.verdict(h) != DEAD]

    def successor(self, dead_host: int) -> Optional[int]:
        """Deterministic takeover rank: the first non-DEAD host after
        ``dead_host`` in ring order.  Every observer with the same verdict
        vector picks the same successor."""
        for step in range(1, self.num_hosts):
            h = (dead_host + step) % self.num_hosts
            if self.estimator.verdict(h) != DEAD:
                return h
        return None

    def is_successor(self, dead_host: int) -> bool:
        return self.successor(dead_host) == self.host

    # ------------------------------------------------------------ takeover
    def confirm_dead(self, host: int) -> bool:
        """Post-verdict re-probe of the dead host's member word, run by
        the successor *after* winning the epoch CAS: a live unexpired word
        means the host came back (or was never gone — we were on the wrong
        side of a heal) and the takeover must abort.  TIMEOUT or an
        expired word confirms."""
        key = self.member_keys[host]
        shard = self.table.shards[self.table.shard_of(key)]
        st = self.table._key_state(shard, key)
        word = self.mem.probe(self.p, st.expires)
        if word is TIMEOUT:
            return True
        _tok, _readers, expires_at = word
        return expires_at <= self.table.clock()

    def stop(self) -> None:
        self.stopped = True
