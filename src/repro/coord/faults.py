"""Deterministic fault injection: labeled crash points for the lease stack.

Recovery code is only as trustworthy as the crashes it has survived, and
real crashes land in the narrowest windows — after a grant CAS commits but
before the client's ledger records it, between two shard groups of a batch,
while a writer's drain barrier is armed.  This module makes those windows
*first-class*: the lock table and the recoverable client wrapper call
:meth:`FaultInjector.crash_point` at each labeled window, and an armed
injector raises :class:`ClientCrash` there — synchronously, mid-protocol,
exactly where a kill -9 would land.

Two trigger styles, both deterministic:

* :meth:`FaultInjector.at` — "crash the *nth* arrival at this label"
  (optionally filtered to one pid).  The crash-point matrix test arms one
  label per case and proves recovery from every window.
* :meth:`FaultInjector.seeded` — a seeded Bernoulli draw per arrival, for
  crash *storms*: same seed ⇒ the same crashes at the same arrivals, so a
  CI rerun is byte-identical.

Every firing is appended to :attr:`FaultInjector.fired` (label, pid,
arrival index) — the determinism gate diffs this log across same-seed runs.

Crash points sit **outside** ALock critical sections by design: a lease
holder may die at any of them and the shard stays serviceable (leases
expire; the CS itself is never wedged).  The catalog is
:data:`CRASH_POINTS`; ``docs/recovery.md`` documents what each window
leaves behind and how restart recovery repairs it.
"""

from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

__all__ = ["CRASH_POINTS", "FABRIC_POINTS", "ClientCrash", "FaultInjector"]


# The labeled windows, in protocol order.  Each names the state a crash
# there abandons (see docs/recovery.md for the per-window recovery story):
#
#   ledger.post_intent — the write-ahead intent is durable, the grant CAS
#       has not run: restart finds a dangling intent and probes the word.
#   grant.pre_ledger   — the grant CAS committed, the grant record did not:
#       the lease exists under a dead pid with no ledger witness; restart's
#       orphan probe adopts it via the holder register + fence check.
#   renew.pre_cas      — a renewal was requested but never reached the word.
#   renew.pre_ledger   — the renewal CAS landed, the ledger still holds the
#       older witness: reclaim's fast CAS misses and the slow path
#       revalidates against the (fresher) word.
#   release.pre_cas    — a release never reached the word: the ledger says
#       held, the word agrees — reclaim succeeds, the lease outlives the
#       crash (safe: it was never released).
#   release.pre_ledger — the release CAS landed, the tombstone did not: the
#       ledger over-claims and reclaim fails cleanly (fence/word mismatch).
#   batch.mid          — between two shard groups of acquire_batch: a prefix
#       of the batch is held by a dead pid, unrecorded; dangling intents
#       drive the orphan probe, key by key.
#   drain.mid          — a writer died right after arming a reader-cohort
#       drain barrier: the barrier lapses on its own (it is a deadline).
#   upgrade.mid        — an upgrader died after arming the drain barrier
#       mid-upgrade; its shared slot is still counted and reclaimable.
#   inflate.mid        — the waiter that swung a key into queued (inflated)
#       mode died right after the mode CAS: the key stays inflated with a
#       queue the dead pid never joined — it serves through the inflated
#       path and deflates when cool (no fencing state was abandoned).
#   deflate.mid        — an inflated-mode holder died after its release CAS
#       but before passing the queue on: its cohort's head never gets the
#       handoff, distrusts the queue after the staleness deadline, and
#       bypasses to the word (the bypass grant deflates the key).
CRASH_POINTS = (
    "ledger.post_intent",
    "grant.pre_ledger",
    "renew.pre_cas",
    "renew.pre_ledger",
    "release.pre_cas",
    "release.pre_ledger",
    "batch.mid",
    "drain.mid",
    "upgrade.mid",
    "inflate.mid",
    "deflate.mid",
)

# Fabric-side labeled points: message-loss windows rather than process-death
# windows.  They arm through the same one-shot / seeded machinery but are
# *decisions*, not crashes — the fabric asks :meth:`FaultInjector.
# fabric_point` whether to lose/duplicate/delay a specific posting, and the
# poster survives (timeout + bounded retry).  This is what lets the crash
# matrix cross host-crash cells with message-loss cells: one injector arms
# ``release.pre_cas`` AND ``fabric.drop`` and both land deterministically.
#
#   fabric.drop  — the posting is lost; the poster discovers it at the op
#       timeout and reposts on the seeded backoff schedule.
#   fabric.dup   — the posting is delivered twice (at-least-once delivery);
#       reads/writes are idempotent and a duplicated CAS observes its own
#       swap, so the CAS-only lease word absorbs it.
#   fabric.delay — the posting is delivered late (extra latency, no loss).
#   fabric.congest — the destination host is congested for this posting: it
#       is delivered, but only after one full congestion quantum of queueing
#       delay, as if the host's receive queue were at capacity.  Forces the
#       overload machinery (deadline sheds, breaker trips, hedged probes)
#       onto a specific posting without needing a whole storm.
FABRIC_POINTS = (
    "fabric.drop",
    "fabric.dup",
    "fabric.delay",
    "fabric.congest",
)

_ALL_POINTS = frozenset(CRASH_POINTS) | frozenset(FABRIC_POINTS)


class ClientCrash(Exception):
    """The injected process death.  Raised at a crash point (synchronously,
    by an armed :class:`FaultInjector`) or thrown into a sim task by
    :meth:`~repro.sim.SimEngine.kill` (asynchronously, at the task's next
    dispatch).  Client code treats it the way a supervisor treats a dead
    worker: abandon all in-memory state, restart, replay the ledger."""

    def __init__(self, label: str, pid: Optional[int] = None):
        super().__init__(f"injected crash at {label!r}"
                         + (f" (pid {pid})" if pid is not None else ""))
        self.label = label
        self.pid = pid


class FaultInjector:
    """Arms crash points with deterministic triggers.

    Thread-compatible in the same sense as the shard telemetry: arrivals
    are counted under no lock (sim steps are atomic; the threaded stress
    tests arm pid-filtered one-shots, which fire exactly once per filter
    regardless of interleaving — the ``nth`` comparison is on the filter's
    own monotone counter).
    """

    def __init__(self) -> None:
        # label -> total arrivals observed (armed or not).
        self.hits: Dict[str, int] = {}
        # Firing log: (label, pid, arrival index at that label).
        self.fired: List[Tuple[str, int, int]] = []
        # One-shot triggers: (label, pid-or-None) -> arrival number to kill.
        self._oneshots: Dict[Tuple[str, Optional[int]], int] = {}
        # Per-filter arrival counters (pid-filtered triggers count their own
        # arrivals; the global `hits` counts everyone's).
        self._filter_hits: Dict[Tuple[str, Optional[int]], int] = {}
        self._rng: Optional[random.Random] = None
        self._prob = 0.0
        self._labels: Optional[frozenset] = None

    # ------------------------------------------------------------- arming
    def at(self, label: str, nth: int = 1,
           pid: Optional[int] = None) -> "FaultInjector":
        """Crash the ``nth`` arrival at ``label`` (1-based), optionally only
        counting arrivals by ``pid``.  Returns self for chaining."""
        if label not in _ALL_POINTS:
            raise ValueError(f"unknown crash point {label!r}")
        if nth < 1:
            raise ValueError("nth is 1-based")
        self._oneshots[(label, pid)] = nth
        return self

    @classmethod
    def seeded(cls, seed: int, prob: float,
               labels: Optional[Tuple[str, ...]] = None) -> "FaultInjector":
        """A crash storm: every arrival at an armed label dies with
        probability ``prob``, drawn from a dedicated seeded stream (the
        schedule depends only on ``seed`` and the arrival order, which the
        sim engine already makes deterministic)."""
        fi = cls()
        fi._rng = random.Random(0x9E3779B1 * (seed + 1))
        fi._prob = float(prob)
        if labels is not None:
            for lab in labels:
                if lab not in _ALL_POINTS:
                    raise ValueError(f"unknown crash point {lab!r}")
            fi._labels = frozenset(labels)
        return fi

    # ------------------------------------------------------------- firing
    def crash_point(self, label: str, pid: int) -> None:
        """Called by instrumented code at each labeled window; raises
        :class:`ClientCrash` when a trigger matches, else returns."""
        n = self.hits.get(label, 0) + 1
        self.hits[label] = n
        for filt in ((label, None), (label, pid)):
            want = self._oneshots.get(filt)
            if want is None:
                continue
            fn = self._filter_hits.get(filt, 0) + 1
            self._filter_hits[filt] = fn
            if fn == want:
                del self._oneshots[filt]
                self.fired.append((label, pid, n))
                raise ClientCrash(label, pid)
        if (self._rng is not None and self._prob > 0.0
                and (self._labels is None or label in self._labels)
                and self._rng.random() < self._prob):
            self.fired.append((label, pid, n))
            raise ClientCrash(label, pid)

    def fabric_point(self, label: str, pid: int) -> bool:
        """Called by a lossy fabric for each remote posting; returns whether
        the labeled fault (``fabric.drop`` / ``fabric.dup`` /
        ``fabric.delay``) fires on this posting.

        Same counters and ``fired`` log as :meth:`crash_point`, but the
        trigger is a *decision* — the posting is lost/duplicated/delayed and
        the poster rides its retry schedule instead of dying.  Seeded storms
        only reach fabric points when their ``labels`` name them explicitly:
        an unscoped storm (``labels=None``) keeps its historical meaning of
        "crash storm over the crash points" and never eats postings.
        """
        n = self.hits.get(label, 0) + 1
        self.hits[label] = n
        for filt in ((label, None), (label, pid)):
            want = self._oneshots.get(filt)
            if want is None:
                continue
            fn = self._filter_hits.get(filt, 0) + 1
            self._filter_hits[filt] = fn
            if fn == want:
                del self._oneshots[filt]
                self.fired.append((label, pid, n))
                return True
        if (self._rng is not None and self._prob > 0.0
                and self._labels is not None and label in self._labels
                and self._rng.random() < self._prob):
            self.fired.append((label, pid, n))
            return True
        return False
