"""Persistent lease ledgers: crash-restart re-entry for lease holders.

A lease holder that crashes today wedges its keys for a full TTL — the
leases are correct (fencing keeps the zombie out) but the *restarted*
process rejoins amnesiac and waits the wedge out like a stranger.  This
module gives each client a durable, append-only **lease ledger**: a record
per protocol transition (intent, grant, renew, release), replayable into
the set of leases the client plausibly still holds.  A restarted client
replays its ledger and *reclaims* each still-valid lease with a
fencing-checked CAS (see :meth:`~repro.coord.ShardedLockTable.reclaim`)
instead of waiting out the TTL — recovery cost proportional to the leases
in flight at the crash, not to the keyspace (the Dhoked & Mittal
"adaptive to failures" shape, transplanted to leases).

Write-ahead discipline
----------------------

:class:`RecoverableClient` writes an ``intent`` record *before* the grant
CAS and a ``grant`` record *after* it, so a crash in either window leaves
a recoverable trail:

* crash after intent, before the CAS: restart finds a **dangling intent**
  and probes the word (:meth:`~repro.coord.ShardedLockTable.reclaim_orphan`)
  — if the grant never happened the probe finds a stranger and resolves
  the intent; nothing is leaked.
* crash after the CAS, before the grant record: the lease exists under a
  dead pid with no ledger witness.  The dangling intent still names the
  key, and the ``session`` records name every pid this client ever ran
  as — the orphan probe recognises the word's holder as one of its own
  dead incarnations (pids are never reused) and adopts the grant.

Replay is a pure fold over the records: calling it twice gives the same
view, and re-appending the most recent record (the crash-retry window —
a client that died before learning its append landed re-appends on
restart) leaves the view unchanged.

Durability is modeled, not simulated: records append to an in-memory list
(the sim's "persistent disk"), with JSONL dump/load for real processes —
:class:`LedgerStore` keys ledgers by client name so a *restarted* client
(new pid, same name) finds its predecessor's records, which is exactly the
crash model.
"""

from __future__ import annotations

import json
import warnings
from dataclasses import asdict, dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import Process

from .table import Lease, LeaseMode, ShardedLockTable

__all__ = ["LedgerRecord", "LedgerView", "LeaseLedger", "LedgerStore",
           "RecoverableClient"]

# Record ops, in the protocol's vocabulary:
#   session — a (re)start: names the pid this client now runs as.
#   intent  — write-ahead marker, appended BEFORE the grant CAS.
#   grant   — a lease was granted (or adopted by reclaim/orphan probe).
#   renew   — the lease's witness moved to a later expiry.
#   release — the lease was released (tombstone).
#   lost    — restart observed the lease dead/fenced-out (tombstone).
#   resolve — an intent's outcome is settled (granted, rejected, or probed).
_OPS = ("session", "intent", "grant", "reclaim", "renew", "release", "lost",
        "resolve")


@dataclass(frozen=True)
class LedgerRecord:
    """One append-only ledger entry.  ``seq`` orders records within one
    ledger; lease-carrying ops snapshot the full fast-path witness
    (token, expires_at) so replay can hand reclaim a CAS-ready lease."""

    seq: int
    op: str
    key: str = ""
    shard: int = -1
    token: int = 0
    mode: int = int(LeaseMode.EXCLUSIVE)
    expires_at: float = 0.0
    ttl: float = 0.0
    pid: int = -1
    # The word's inflation mode bit at record time (int for JSONL
    # stability): reclaim's fast-path witness must encode it or a reclaim
    # of an inflated-mode grant would never match the word.
    inflated: int = 0

    def as_lease(self) -> Lease:
        return Lease(self.key, self.shard, self.pid, self.token,
                     self.expires_at, self.ttl, LeaseMode(self.mode),
                     bool(self.inflated))


@dataclass
class LedgerView:
    """The replayed state: what this client plausibly still holds.

    ``live`` maps key → the latest unreleased grant/renew record;
    ``intents`` maps key → a dangling intent (written, never resolved);
    ``pids`` lists every pid the client has run as, oldest first.
    """

    live: Dict[str, LedgerRecord]
    intents: Dict[str, LedgerRecord]
    pids: List[int]


class LeaseLedger:
    """Append-only, replayable record list for ONE client identity."""

    def __init__(self, name: str):
        self.name = name
        self.records: List[LedgerRecord] = []
        self._seq = 0

    def append(self, op: str, *, key: str = "", shard: int = -1,
               token: int = 0, mode: int = int(LeaseMode.EXCLUSIVE),
               expires_at: float = 0.0, ttl: float = 0.0,
               pid: int = -1, inflated: int = 0) -> LedgerRecord:
        if op not in _OPS:
            raise ValueError(f"unknown ledger op {op!r}")
        rec = LedgerRecord(self._seq, op, key, shard, token, int(mode),
                           expires_at, ttl, pid, int(inflated))
        self._seq += 1
        self.records.append(rec)
        return rec

    def append_lease(self, op: str, lease: Lease) -> LedgerRecord:
        return self.append(op, key=lease.key, shard=lease.shard,
                           token=lease.token, mode=int(lease.mode),
                           expires_at=lease.expires_at, ttl=lease.ttl,
                           pid=lease.holder_pid,
                           inflated=int(lease.inflated))

    # -------------------------------------------------------------- replay
    def replay(self) -> LedgerView:
        """Pure fold of the records into the client's plausible holdings."""
        return replay_records(self.records)

    # --------------------------------------------------------- persistence
    def dump_jsonl(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            for rec in self.records:
                f.write(json.dumps(asdict(rec), sort_keys=True) + "\n")

    @classmethod
    def load_jsonl(cls, path: str, name: Optional[str] = None) -> "LeaseLedger":
        """Load a dumped ledger, tolerating a **torn tail**.

        A crash mid-append leaves the final line truncated (or a final
        newline missing entirely) — the exact artifact this module's crash
        model produces on a real disk.  A corrupt LAST non-empty line is
        therefore truncated away with a warning: the write-ahead discipline
        already covers the loss (the record that tore was the one being
        written at the crash; its intent precedes it, so restart's orphan
        probe settles the key).  Corruption anywhere *before* the tail has
        no such excuse — an append-only file does not tear in the middle —
        and raises ``ValueError``: that file is damaged, not torn.
        """
        led = cls(name or path)
        with open(path, "r", encoding="utf-8") as f:
            lines = f.read().split("\n")
        # Indices of non-empty lines; only the LAST one may be torn.
        body = [(i, ln) for i, ln in enumerate(lines) if ln.strip()]
        for pos, (i, line) in enumerate(body):
            try:
                rec = LedgerRecord(**json.loads(line))
            except (ValueError, TypeError) as exc:
                # json decode errors are ValueError; unexpected/missing
                # fields surface as TypeError from the dataclass ctor.
                if pos == len(body) - 1:
                    warnings.warn(
                        f"{path}: torn final ledger record (line {i + 1}) "
                        f"truncated: {exc}", RuntimeWarning, stacklevel=2)
                    break
                raise ValueError(
                    f"{path}: corrupt ledger record mid-file "
                    f"(line {i + 1}): {exc}") from exc
            led.records.append(rec)
        led._seq = (led.records[-1].seq + 1) if led.records else 0
        return led


def replay_records(records: Iterable[LedgerRecord]) -> LedgerView:
    """The replay fold, usable on any record stream (e.g. a merged stream
    from several surviving ledgers during shard reconstruction).

    Idempotent: a pure function of the record sequence, and re-appending
    the most recent record leaves the view unchanged (grant/renew/session
    overwrite with equal content; release/lost/resolve tombstone an
    already-tombstoned key harmlessly).
    """
    live: Dict[str, LedgerRecord] = {}
    intents: Dict[str, LedgerRecord] = {}
    pids: List[int] = []
    for rec in records:
        if rec.op == "session":
            if not pids or pids[-1] != rec.pid:
                pids.append(rec.pid)
        elif rec.op == "intent":
            intents[rec.key] = rec
        elif rec.op in ("grant", "reclaim"):
            live[rec.key] = rec
            intents.pop(rec.key, None)
        elif rec.op == "renew":
            cur = live.get(rec.key)
            # A renewal only refreshes the grant it belongs to; a renew
            # record for an unknown/other-token grant is ignored (tolerant
            # of records lost in the crash windows).
            if cur is not None and cur.token == rec.token:
                live[rec.key] = rec
        elif rec.op in ("release", "lost"):
            cur = live.get(rec.key)
            if cur is not None and cur.token == rec.token:
                del live[rec.key]
            intents.pop(rec.key, None)
        elif rec.op == "resolve":
            intents.pop(rec.key, None)
    return LedgerView(live=live, intents=intents, pids=pids)


class LedgerStore:
    """Ledgers keyed by *client name* — the identity that survives a crash.

    A restarted client asks the store for its name and gets its
    predecessor's ledger back; that handoff IS the modeled durability.
    """

    def __init__(self) -> None:
        self._ledgers: Dict[str, LeaseLedger] = {}

    def ledger(self, name: str) -> LeaseLedger:
        led = self._ledgers.get(name)
        if led is None:
            led = self._ledgers[name] = LeaseLedger(name)
        return led

    def names(self) -> List[str]:
        return sorted(self._ledgers)

    def all_records(self) -> List[LedgerRecord]:
        """Every surviving ledger's records (reconstruction input)."""
        out: List[LedgerRecord] = []
        for name in self.names():
            out.extend(self._ledgers[name].records)
        return out


class RecoverableClient:
    """A lease client that writes the ledger protocol and can restart.

    Wraps a :class:`~repro.coord.ShardedLockTable` (or anything exposing
    its lease API plus ``reclaim``/``reclaim_orphan``/``_crash_point`` —
    a :class:`~repro.coord.CoordinationService` passes its ``.table``).
    All lease operations go through here so every transition lands in the
    ledger; :meth:`restart` is the crash-recovery entry point.
    """

    def __init__(self, table: ShardedLockTable, p: Process,
                 ledger: LeaseLedger):
        self.table = getattr(table, "table", table)
        self.p = p
        self.ledger = ledger
        self.ledger.append("session", pid=p.pid)

    # ------------------------------------------------------------- helpers
    def _cp(self, label: str) -> None:
        self.table._crash_point(label, self.p)

    # ------------------------------------------------------------ lease API
    def try_acquire(self, key: str, ttl: float,
                    mode: LeaseMode = LeaseMode.EXCLUSIVE) -> Optional[Lease]:
        self.ledger.append("intent", key=key, mode=int(mode), ttl=ttl,
                           pid=self.p.pid)
        self._cp("ledger.post_intent")
        lease = self.table.try_acquire(self.p, key, ttl, mode=mode)
        if lease is None:
            self.ledger.append("resolve", key=key)
            return None
        self._cp("grant.pre_ledger")
        self.ledger.append_lease("grant", lease)
        return lease

    def acquire_batch(self, keys: Sequence[str], ttl: float,
                      timeout: Optional[float] = None,
                      mode: LeaseMode = LeaseMode.EXCLUSIVE) -> List[Lease]:
        ordered = self.table.batch_order(keys)
        for key in ordered:
            self.ledger.append("intent", key=key, mode=int(mode), ttl=ttl,
                               pid=self.p.pid)
        self._cp("ledger.post_intent")
        try:
            leases = self.table.acquire_batch(self.p, ordered, ttl,
                                              timeout=timeout, mode=mode)
        except TimeoutError:
            for key in ordered:  # the table released everything it held
                self.ledger.append("resolve", key=key)
            raise
        self._cp("grant.pre_ledger")
        for lease in leases:
            self.ledger.append_lease("grant", lease)
        return leases

    def renew(self, lease: Lease,
              ttl: Optional[float] = None) -> Optional[Lease]:
        self._cp("renew.pre_cas")
        renewed = self.table.renew(self.p, lease, ttl)
        if renewed is None:
            self.ledger.append_lease("lost", lease)
            return None
        self._cp("renew.pre_ledger")
        self.ledger.append_lease("renew", renewed)
        return renewed

    def release(self, lease: Lease) -> bool:
        self._cp("release.pre_cas")
        ok = self.table.release(self.p, lease)
        self._cp("release.pre_ledger")
        # Tombstone either way: a failed release means the lease is already
        # dead (expired/fenced), and the view should stop claiming it.
        self.ledger.append_lease("release", lease)
        return ok

    def upgrade(self, lease: Lease,
                ttl: Optional[float] = None) -> Optional[Lease]:
        up = self.table.upgrade(self.p, lease, ttl)
        if up is not None:
            self.ledger.append_lease("release", lease)  # slot consumed
            self.ledger.append_lease("grant", up)
        return up

    # ------------------------------------------------------------- restart
    def adopt_process(self, p: Process) -> None:
        """Rebind to a new incarnation WITHOUT recovery (the amnesiac
        baseline the benchmarks compare against)."""
        self.p = p
        self.ledger.append("session", pid=p.pid)

    def restart(self, p: Process) -> List[Lease]:
        """Crash-restart re-entry: replay the ledger, reclaim what lives.

        Three passes, each bounded by what was *in flight* at the crash:

        1. every ``live`` record → :meth:`ShardedLockTable.reclaim` (fast
           fencing-checked CAS; still-valid leases come back, expired or
           fenced-out ones are tombstoned);
        2. every dangling ``intent`` → the orphan probe, which adopts
           grants that committed but were never recorded (the word's
           holder is one of our dead pids);
        3. a fresh ``session`` record so the next incarnation knows this
           pid too is fair game for its own orphan probe.

        Returns the reclaimed leases, ledgered as ``reclaim`` records.
        """
        view = self.ledger.replay()
        dead = [pid for pid in view.pids if pid != p.pid]
        self.p = p
        self.ledger.append("session", pid=p.pid)
        out: List[Lease] = []
        for key in sorted(view.live):
            lease = view.live[key].as_lease()
            got = self.table.reclaim(p, lease)
            if got is not None:
                self.ledger.append_lease("reclaim", got)
                out.append(got)
            else:
                self.ledger.append_lease("lost", lease)
        for key in sorted(view.intents):
            rec = view.intents[key]
            got = None
            if rec.mode == int(LeaseMode.EXCLUSIVE):
                got = self.table.reclaim_orphan(p, key, dead,
                                                rec.ttl or lease_ttl(rec))
            # SHARED intents are not probed: the packed word's reader count
            # is anonymous, so a dead reader's maybe-join cannot be told
            # apart from a stranger's — the slot (if any) expires with its
            # horizon and harms no one (readers fence nothing downstream).
            if got is not None:
                self.ledger.append_lease("reclaim", got)
                out.append(got)
            self.ledger.append("resolve", key=key)
        return out


def lease_ttl(rec: LedgerRecord) -> float:
    """A defensive fallback TTL for records written before ttl was known."""
    return rec.ttl if rec.ttl > 0 else 1.0
