"""Contention-adaptive lock inflation policy: when a key is hot enough to
escalate from the packed CAS word to a per-key MCS queue, and when to come
back down.

The packed expiry word is cost-optimal when uncontended — a grant is one
CAS, a renewal is one CAS, an idle key costs nothing.  Under a zipfian hot
key it degenerates: every waiter re-runs the shard critical section per
poll, rCAS per acquire grows with the number of contenders, and grant order
is a lottery (the p99 acquire latency is the geometric tail of losing it).
The queue-based machinery the paper already builds (budgeted MCS cohorts,
``repro.core.mcs``) fixes exactly that regime — FIFO handoff, local
spinning, bounded remote ops — but costs registers and an enqueue per
acquire, which is the wrong trade for the uncontended 99% of the keyspace.

So the mode is *adaptive*, per key (lock inflation, in the HotSpot sense):

* **inflate** when the per-key contention rate over a sliding window
  crosses :attr:`InflationPolicy.inflate_retries` — the home shard flips
  the word's mode bit (a CAS: the readers field goes two's-complement
  negative, see ``coord/table.py``) and hangs a two-cohort split-phase MCS
  queue off the key;
* **deflate** when the rate falls below :attr:`InflationPolicy.deflate_retries`
  *and* the queue has drained *and* the key has been inflated for at least
  :attr:`InflationPolicy.min_inflated` — the hysteresis floor.  A freshly
  deflated key cannot re-inflate for :attr:`InflationPolicy.min_deflated`
  (the refractory gap).  Together the two floors bound the transition
  frequency under any oscillating load to at most one inflate+deflate pair
  per ``min_inflated + min_deflated`` of virtual time (the flapping test
  pins this).

The estimator is **host-side metadata**, like shard placement and the
client slot ledger: it observes protocol events (blocked exclusive
verdicts) and influences *decisions*, but all protocol state lives in the
simulated registers and every word mutation stays a CAS.  Zero cost when
idle is literal: a table built without a policy (``inflation=None``) takes
one attribute check per exclusive acquire and touches nothing else.

Determinism: decisions are pure functions of (event sequence, virtual
clock), both of which the sim engine derives from the seed — two same-seed
runs produce byte-identical inflate/deflate event logs, which the CI
bench-smoke gate diffs.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Optional

__all__ = ["InflationPolicy", "ContentionEstimator"]


@dataclass(frozen=True)
class InflationPolicy:
    """Thresholds + hysteresis for per-key lock inflation.

    The defaults are sized for the sim workloads' virtual-time scales
    (HOLD = 10us, backoff 20us..2ms): a zipfian hot key at 64x16 clients
    crosses ``inflate_retries`` within its first few milliseconds, a
    uniform workload never gets close, and the two hysteresis floors keep
    a key from flapping faster than once per ~``min_inflated +
    min_deflated`` even under adversarial on/off load.
    """

    # Inflate when this many blocked exclusive attempts land within one
    # sliding ``window`` on a single key.
    inflate_retries: int = 32
    window: float = 1e-3
    # Deflate (at release, queue drained) once the windowed rate is below
    # this — strictly colder than the inflate threshold, the classic
    # two-threshold hysteresis band.
    deflate_retries: int = 4
    # Hysteresis floors: minimum inflated residency, and the refractory
    # gap before a deflated key may inflate again.
    min_inflated: float = 5e-3
    min_deflated: float = 1e-3
    # A parked queue waiter distrusts the queue after this many TTLs
    # without a handoff (dead predecessor / discarded epoch) and falls
    # back to probing the word directly.
    stale_after_ttls: float = 4.0

    def __post_init__(self):
        if self.inflate_retries <= 0 or self.window <= 0:
            raise ValueError("inflate_retries and window must be > 0")
        if self.deflate_retries >= self.inflate_retries:
            raise ValueError(
                "deflate_retries must sit below inflate_retries "
                "(the hysteresis band would be empty or inverted)")
        if self.min_inflated < 0 or self.min_deflated < 0:
            raise ValueError("hysteresis floors must be >= 0")
        if self.stale_after_ttls <= 0:
            raise ValueError("stale_after_ttls must be > 0")


class _KeyHeat:
    """Two-bucket sliding window + per-key transition timestamps."""

    __slots__ = ("bucket", "count", "prev", "inflated_at", "deflated_at")

    def __init__(self, bucket: int):
        self.bucket = bucket    # current window-bucket index
        self.count = 0          # events in the current bucket
        self.prev = 0           # events in the immediately preceding bucket
        self.inflated_at = -1.0
        self.deflated_at = -1.0


class ContentionEstimator:
    """Windowed per-key contention rates + hysteresis clocks.

    One instance per table.  ``note`` is O(1); the rate is the standard
    two-bucket approximation of a sliding window (current bucket plus the
    previous one weighted by its remaining overlap) — monotone in the true
    rate and exact for steady loads, which is all a threshold needs.

    Thread-safe under its own lock for the threaded tables; under the sim
    engine every call sits inside one atomic step, so the lock is
    uncontended and the event order (hence every decision) is seeded.
    """

    _SWEEP = 4096

    def __init__(self, policy: InflationPolicy):
        self.policy = policy
        self._heat: Dict[str, _KeyHeat] = {}
        self._guard = threading.Lock()

    # ------------------------------------------------------------ internals
    def _shift(self, h: _KeyHeat, b: int) -> None:
        if b != h.bucket:
            h.prev = h.count if b == h.bucket + 1 else 0
            h.count = 0
            h.bucket = b

    def _rate(self, h: _KeyHeat, now: float) -> float:
        """Events in the sliding window ending at ``now``."""
        w = self.policy.window
        b = int(now / w)
        self._shift(h, b)
        frac = now / w - b  # how far into the current bucket we are
        return h.count + h.prev * (1.0 - frac)

    def _entry(self, key: str, bucket: int) -> _KeyHeat:
        h = self._heat.get(key)
        if h is None:
            if len(self._heat) >= self._SWEEP:
                cold = [k for k, v in self._heat.items()
                        if v.bucket < bucket - 1 and v.inflated_at < 0]
                for k in cold:
                    del self._heat[k]
            h = self._heat[key] = _KeyHeat(bucket)
        return h

    # ------------------------------------------------------------------ API
    def note(self, key: str, now: float) -> None:
        """Record one contention event (a blocked exclusive attempt)."""
        b = int(now / self.policy.window)
        with self._guard:
            h = self._entry(key, b)
            self._shift(h, b)
            h.count += 1

    def rate(self, key: str, now: float) -> float:
        with self._guard:
            h = self._heat.get(key)
            return self._rate(h, now) if h is not None else 0.0

    def should_inflate(self, key: str, now: float) -> bool:
        """Hot enough, and past the refractory gap since the last deflate."""
        pol = self.policy
        with self._guard:
            h = self._heat.get(key)
            if h is None:
                return False
            if 0.0 <= h.deflated_at and now < h.deflated_at + pol.min_deflated:
                return False
            return self._rate(h, now) >= pol.inflate_retries

    def should_deflate(self, key: str, now: float) -> bool:
        """Cold enough, and past the minimum inflated residency."""
        pol = self.policy
        with self._guard:
            h = self._heat.get(key)
            if h is None:
                return True
            if 0.0 <= h.inflated_at and now < h.inflated_at + pol.min_inflated:
                return False
            return self._rate(h, now) < pol.deflate_retries

    def mark_inflated(self, key: str, now: float) -> None:
        with self._guard:
            h = self._entry(key, int(now / self.policy.window))
            h.inflated_at = now

    def mark_deflated(self, key: str, now: float) -> None:
        with self._guard:
            h = self._heat.get(key)
            if h is not None:
                h.inflated_at = -1.0
                h.deflated_at = now
