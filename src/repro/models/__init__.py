"""Model zoo: one Model class, ten architectures via block patterns."""

from .transformer import Model, layer_plan  # noqa: F401
from .io import input_specs  # noqa: F401
from .specs import (  # noqa: F401
    ParamSpec,
    init_params,
    param_bytes,
    param_count,
    pspec_tree,
    shape_dtype_tree,
    sharding_tree,
)
