"""Mixture-of-Experts FFN (DeepSeek-style: shared + routed, fine-grained).

Dispatch is capacity-based, *group-local* and sort-free: tokens are split
into G groups (one per data shard at scale — matching expert-parallel system
semantics where capacity and drops are per-shard), each group ranks its
(token, choice) pairs per expert via a stable argsort, scatters into a
``[G, E, C_g, D]`` capacity buffer (G on the ``data`` axis, E on the
``model`` axis), runs the expert GEMMs as one batched einsum, and gathers the
outputs back weighted by router gates.

This avoids the O(S·E·C) one-hot dispatch tensor of Switch/GShard — which is
intractable for 256-expert fine-grained MoE — while staying pure
einsum/scatter (TPU-friendly, differentiable, GSPMD-shardable).  Per-chip
capacity memory is ``E·C_g·D / |model|`` — bounded regardless of global
batch.  A dense one-hot path (`dispatch="onehot"`) is kept as the numerical
oracle in tests (groups=1).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..compat import get_abstract_mesh, shard_map
from ..configs.base import ModelConfig, MoEConfig
from .layers import ashard, mlp, mlp_spec
from .specs import ParamSpec


def moe_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    m: MoEConfig = cfg.moe
    D, E, F = cfg.d_model, m.num_experts, m.d_expert
    layouts = {
        # (wi logical, wo logical) — see MoEConfig.expert_sharding.
        "fsdp_d": ((("expert", "embed", None)), ("expert", None, "embed")),
        "fsdp_f": ((("expert", None, "mlp_fsdp")), ("expert", "mlp_fsdp", None)),
        "ep2d": ((("expert2d", None, None)), ("expert2d", None, None)),
        # manual a2a EP: one expert per chip when E divides the chip count,
        # else E over `model` with d_model FSDP on `data` (gathered inside).
        "ep_a2a": (
            (("expert2d", None, None), ("expert2d", None, None))
            if E % 256 == 0
            else ((("expert", "embed", None)), ("expert", "mlp_fsdp", None))
        ),
    }
    wi_l, wo_l = (
        layouts[m.expert_sharding][0], layouts[m.expert_sharding][1]
    )
    spec: Dict = {
        "router": ParamSpec(
            (D, E), ("embed", None), init="normal", scale=0.006, dtype=jnp.float32
        ),
        # Fused gate+up per expert.
        "wi": ParamSpec((E, D, 2 * F), wi_l, dtype=dtype),
        "wo": ParamSpec((E, F, D), wo_l, dtype=dtype),
    }
    if m.num_shared:
        spec["shared"] = mlp_spec(D, m.num_shared * F, "swiglu", dtype)
    return spec


def _router_probs(logits: jnp.ndarray, m: MoEConfig) -> jnp.ndarray:
    if m.router == "softmax":      # DeepSeek-V2
        return jax.nn.softmax(logits, axis=-1)
    if m.router == "sigmoid":      # DeepSeek-V3
        return jax.nn.sigmoid(logits)
    raise ValueError(m.router)


def _topk_gates(probs: jnp.ndarray, m: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gates, idx = jax.lax.top_k(probs, m.top_k)
    if m.router == "sigmoid":      # V3 renormalises among the selected
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx


def aux_load_balance_loss(probs: jnp.ndarray, counts: jnp.ndarray, m: MoEConfig):
    """Switch-style load-balance auxiliary: E · <f_e> · <p_e> (per group)."""
    G, S, E = probs.shape
    f = counts.astype(jnp.float32) / (S * m.top_k)         # [G, E]
    p = jnp.mean(probs.astype(jnp.float32), axis=1)        # [G, E]
    return jnp.mean(m.num_experts * jnp.sum(f * p, axis=-1))


def _capacity(tokens_per_group: int, m: MoEConfig) -> int:
    c = int(tokens_per_group * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


# ---------------------------------------------------------------------------
# Grouped scatter dispatch (production path)
# ---------------------------------------------------------------------------
def _scatter_moe(p, xg: jnp.ndarray, m: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """xg: [G, S, D] → (y [G, S, D], aux). Capacity overflow tokens drop."""
    G, S, D = xg.shape
    E, k = m.num_experts, m.top_k
    C = _capacity(S, m)
    garange = jnp.arange(G, dtype=jnp.int32)[:, None]

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = _router_probs(logits, m)
    gates, idx = _topk_gates(probs, m)                      # [G, S, k]

    flat_e = idx.reshape(G, S * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1).astype(jnp.int32)   # rank within group
    counts = (
        jnp.zeros((G, E), jnp.int32).at[garange, flat_e].add(1)
    )
    aux = aux_load_balance_loss(probs, counts, m)
    starts = jnp.cumsum(counts, axis=-1) - counts           # exclusive prefix
    pos = ranks - jnp.take_along_axis(starts, flat_e, axis=-1).astype(jnp.int32)
    slot = jnp.where(pos < C, flat_e * C + pos, E * C)      # overflow → dropped

    token_of = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)  # [S*k]
    gathered = xg[:, token_of]                              # [G, S*k, D]
    xe = (
        jnp.zeros((G, E * C + 1, D), xg.dtype).at[garange, slot].add(gathered)
    )
    exp_axes = (
        (None, "expert2d", None, None)
        if m.expert_sharding == "ep2d"
        else ("batch", "expert", None, None)
    )
    xe = ashard(xe[:, : E * C].reshape(G, E, C, D), exp_axes)

    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"])
    h = ashard(h, exp_axes)
    gate_h, up_h = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate_h) * up_h
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    ye = ashard(ye, exp_axes).reshape(G, E * C, D)
    ye = jnp.concatenate([ye, jnp.zeros((G, 1, D), ye.dtype)], axis=1)

    picked = jnp.take_along_axis(ye, slot[..., None], axis=1)  # [G, S*k, D]
    picked = picked * gates.reshape(G, S * k, 1).astype(ye.dtype)
    y = jnp.zeros((G, S, D), ye.dtype).at[garange, token_of[None, :]].add(picked)
    return y, aux


# ---------------------------------------------------------------------------
# One-hot dispatch (oracle / tiny configs; groups=1 semantics)
# ---------------------------------------------------------------------------
def _onehot_moe(p, xg: jnp.ndarray, m: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    G, S, D = xg.shape
    assert G == 1, "onehot oracle is ungrouped"
    x2d = xg[0]
    E, k = m.num_experts, m.top_k
    C = _capacity(S, m)

    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = _router_probs(logits, m)
    gates, idx = _topk_gates(probs, m)
    counts = jnp.zeros((E,), jnp.int32).at[idx.reshape(-1)].add(1)
    aux = aux_load_balance_loss(probs[None], counts[None], m)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)        # [S, k, E]
    flat = onehot.reshape(S * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat                   # exclusive prefix
    pos = jnp.sum(pos * flat, axis=-1).reshape(S, k)
    keep = pos < C
    disp = (
        jax.nn.one_hot(idx, E, dtype=x2d.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=x2d.dtype)[..., None, :]
    )[..., :C]                                              # [S, k, E, C]
    dispatch = jnp.sum(disp, axis=1)                        # [S, E, C]
    combine = jnp.sum(disp * gates[..., None, None].astype(x2d.dtype), axis=1)

    xe = jnp.einsum("sec,sd->ecd", dispatch, x2d)
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    gate_h, up_h = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate_h) * up_h
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    y = jnp.einsum("sec,ecd->sd", combine, ye)
    return y[None], aux


# ---------------------------------------------------------------------------
# Manual expert parallelism (shard_map island): explicit all-to-all dispatch
# ---------------------------------------------------------------------------
def _manual_ep_body(cfg: ModelConfig, ep_axes, fsdp_gather: bool,
                    batch_axes=("data",)):
    """Fully-manual EP body. Per chip: route my token slice to expert owners
    over ``ep_axes`` with one all-to-all, run my experts locally, a2a back,
    combine, then psum the token slices over `model`.

    GSPMD resolves the capacity-buffer einsums by replicating expert weights
    (measured: 26-56 TB/chip/step on deepseek-v3 — §Perf); inside a manual
    region the only fabric traffic is the token a2a (~0.6 GB/chip/layer) and
    the output psum.
    """
    m = cfg.moe
    E, k = m.num_experts, m.top_k
    D = cfg.d_model

    def body(x_loc, router, wi_loc, wo_loc):
        # x_loc: [B_loc, T, D] (replicated over `model`); weights local.
        ep = 1
        for ax in ep_axes:
            ep *= jax.lax.axis_size(ax)
        e_loc = E // ep
        midx = jax.lax.axis_index("model")
        msize = jax.lax.axis_size("model")
        B_loc, T, _ = x_loc.shape
        T_loc = T // msize
        # my token slice (dedup across the replicated model axis)
        x_my = jax.lax.dynamic_slice_in_dim(x_loc, midx * T_loc, T_loc, 1)
        S_loc = B_loc * T_loc
        xs = x_my.reshape(S_loc, D)

        logits = xs.astype(jnp.float32) @ router
        probs = _router_probs(logits, m)
        gates, idx = _topk_gates(probs, m)                 # [S_loc, k]
        counts = jnp.zeros((E,), jnp.int32).at[idx.reshape(-1)].add(1)
        aux = aux_load_balance_loss(probs[None], counts[None], m)

        # slot within (dst chip, local expert): capacity per (src, expert)
        C = max(8, -(-int(S_loc * k * m.capacity_factor / E) // 8) * 8)
        flat_e = idx.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        ranks = jnp.argsort(order).astype(jnp.int32)
        starts = jnp.cumsum(counts) - counts
        pos = ranks - starts[flat_e].astype(jnp.int32)
        slot = jnp.where(pos < C, flat_e * C + pos, E * C)  # [S_loc*k]
        token_of = jnp.repeat(jnp.arange(S_loc, dtype=jnp.int32), k)

        send = jnp.zeros((E * C + 1, D), xs.dtype).at[slot].add(xs[token_of])
        send = send[: E * C].reshape(ep, e_loc * C, D)
        if len(ep_axes) == 1:
            recv = jax.lax.all_to_all(send, ep_axes[0], 0, 0, tiled=False)
        else:
            recv = jax.lax.all_to_all(send, ep_axes, 0, 0, tiled=False)
        # recv: [ep(src), e_loc*C, D] → my experts' tokens from every source
        xe = recv.reshape(ep, e_loc, C, D).transpose(1, 0, 2, 3).reshape(
            e_loc, ep * C, D
        )
        if fsdp_gather:
            wi = jax.lax.all_gather(wi_loc, "data", axis=1, tiled=True)
            wo = jax.lax.all_gather(wo_loc, "data", axis=1, tiled=True)
        else:
            wi, wo = wi_loc, wo_loc
        h = jnp.einsum("ecd,edf->ecf", xe, wi)
        g_h, u_h = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(g_h) * u_h
        ye = jnp.einsum("ecf,efd->ecd", h, wo)             # [e_loc, ep*C, D]
        ye = ye.reshape(e_loc, ep, C, D).transpose(1, 0, 2, 3).reshape(
            ep, e_loc * C, D
        )
        if len(ep_axes) == 1:
            back = jax.lax.all_to_all(ye, ep_axes[0], 0, 0, tiled=False)
        else:
            back = jax.lax.all_to_all(ye, ep_axes, 0, 0, tiled=False)
        back = back.reshape(E * C, D)
        back = jnp.concatenate([back, jnp.zeros((1, D), back.dtype)], 0)
        picked = back[slot] * gates.reshape(-1)[:, None].astype(back.dtype)
        y_my = jnp.zeros((S_loc, D), back.dtype).at[token_of].add(picked)
        # Reassemble the sequence: all-gather the T/|model| slices — half the
        # wire of the zero-fill + psum formulation (§Perf iteration).
        y_full = jax.lax.all_gather(
            y_my.reshape(B_loc, T_loc, D), "model", axis=1, tiled=True
        )
        aux = jax.lax.pmean(aux, batch_axes + ("model",))
        return y_full, aux

    return body


def _manual_ep_moe(p, x: jnp.ndarray, cfg: ModelConfig):
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    E = m.num_experts
    # Fully-manual island over ALL mesh axes (partial-manual shard_map trips
    # XLA partitioner bugs).  A `pod` axis, if present, carries extra batch
    # rows (flat multi-pod mode); the EP group stays within a pod and expert
    # grads psum over `pod` at the island boundary (weights are replicated
    # over `pod` in their specs).
    mesh_axes = tuple(get_abstract_mesh().axis_names)
    batch_axes = ("pod", "data") if "pod" in mesh_axes else ("data",)
    # EP group: all chips of a pod when E divides data*model (deepseek-v3:
    # one expert per chip, weights never move); else the model axis with
    # weight FSDP on data gathered inside (deepseek-v2: E=160).
    two_d = E % 256 == 0
    ep_axes = ("data", "model") if two_d else ("model",)
    fsdp_gather = not two_d
    wspec = P(("data", "model")) if two_d else P("model", "data")
    body = _manual_ep_body(cfg, ep_axes, fsdp_gather, batch_axes)
    fn = shard_map(
        body,
        in_specs=(P(batch_axes, None, None), P(), wspec, wspec),
        out_specs=(P(batch_axes, None, None), P()),
        axis_names=frozenset(mesh_axes),
        check_vma=False,
    )
    return fn(x, p["router"], p["wi"], p["wo"])


def moe_ffn(
    p, x: jnp.ndarray, cfg: ModelConfig, dispatch: str = "scatter"
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN. x: [B, T, D] → (y [B, T, D], aux scalar)."""
    m = cfg.moe
    B, T, D = x.shape
    S = B * T
    use_island = m.expert_sharding == "ep_a2a" and dispatch == "scatter"
    if use_island:
        # The island slices T over `model` to dedup the replicated batch;
        # decode (T=1) and ragged T fall back to the GSPMD scatter path
        # (small tensors — the expensive case the island exists for is the
        # capacity-buffer einsum at training/prefill scale).
        mesh = get_abstract_mesh()
        msize = dict(mesh.shape).get("model", 1) if mesh is not None else 1
        if T % max(msize, 1) != 0 or msize <= 1:
            use_island = False
    if use_island:
        y, aux = _manual_ep_moe(p, x, cfg)
    else:
        G = m.groups if (m.groups >= 1 and S % m.groups == 0) else 1
        fn = _scatter_moe if dispatch == "scatter" else _onehot_moe
        xg = x.reshape(G, S // G, D)
        if G > 1:
            xg = ashard(xg, ("batch", None, None))
        yg, aux = fn(p, xg, m)
        y = yg.reshape(B, T, D)
    if m.num_shared:
        y = y + mlp(p["shared"], x, "swiglu")
    return ashard(y, ("batch", None, "embed")), aux
