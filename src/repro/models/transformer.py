"""Model assembly: block patterns → scanned layer stack → LM / encoder heads.

One `Model` class covers all ten assigned architectures.  The per-layer block
kind comes from ``cfg.block_pattern`` (cycled), giving:

* dense / moe transformers      — ("attn",)
* RecurrentGemma hybrid         — ("rec", "rec", "attn")
* xLSTM                         — ("mlstm",)*7 + ("slstm",)
* HuBERT encoder                — ("attn",), causal=False

Layers are grouped into [lead (unrolled) | scanned super-blocks | tail
(unrolled)] so heterogeneous patterns still compile as a single
``lax.scan`` over stacked parameters (small HLO even for 80-layer models),
with per-super-block remat.  MoE models put their leading dense-FFN layers in
``lead``.

Three entry points per model, matching the dry-run cells:
    loss(params, batch)                      — train_*
    prefill(params, batch, max_len)          — prefill_*
    decode_step(params, cache, tokens)       — decode_* / long_*
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from . import recurrent as rec
from . import xlstm as xl
from .layers import (
    ashard,
    chunked_xent,
    embed,
    embed_spec,
    mlp,
    mlp_spec,
    rmsnorm,
    rmsnorm_spec,
    softmax_xent,
    unembed,
    unembed_spec,
)
from .specs import ParamSpec, init_params, shape_dtype_tree, stack_layer_specs


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Per-kind block specs / apply / cache
# ---------------------------------------------------------------------------
def _block_spec(cfg: ModelConfig, kind: str, dtype) -> Dict:
    if kind in ("attn", "attn_dense"):
        a = attn.mla_spec(cfg, dtype) if cfg.attention == "mla" else attn.gqa_spec(cfg, dtype)
        if cfg.moe is not None and kind == "attn":
            f = moe_mod.moe_spec(cfg, dtype)
        elif cfg.moe is not None:
            f = mlp_spec(cfg.d_model, cfg.moe.dense_d_ff or cfg.d_ff, cfg.act, dtype)
        else:
            f = mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, dtype)
        return {
            "ln1": rmsnorm_spec(cfg.d_model, dtype),
            "attn": a,
            "ln2": rmsnorm_spec(cfg.d_model, dtype),
            "ffn": f,
        }
    if kind == "rec":
        return {
            "ln1": rmsnorm_spec(cfg.d_model, dtype),
            "rec": rec.rglru_block_spec(cfg, dtype),
            "ln2": rmsnorm_spec(cfg.d_model, dtype),
            "ffn": mlp_spec(cfg.d_model, cfg.d_ff, cfg.act, dtype),
        }
    if kind == "mlstm":
        return {"ln": rmsnorm_spec(cfg.d_model, dtype),
                "cell": xl.mlstm_block_spec(cfg, dtype)}
    if kind == "slstm":
        return {"ln": rmsnorm_spec(cfg.d_model, dtype),
                "cell": xl.slstm_block_spec(cfg, dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


def _block_apply(cfg: ModelConfig, kind: str, p, x, mode: str,
                 cache=None, max_len: int = 0):
    """Returns (x, new_cache, aux). mode: train | prefill | decode."""
    aux = jnp.float32(0)
    if kind in ("attn", "attn_dense"):
        h = rmsnorm(p["ln1"], x)
        if cfg.attention == "mla":
            if mode == "train":
                y, new_cache = attn.mla_attention(p["attn"], h, cfg,
                                                  use_pallas=cfg.use_pallas), cache
            elif mode == "prefill":
                y, new_cache = attn.mla_prefill(p["attn"], h, cfg, max_len)
            else:
                y, new_cache = attn.mla_decode(p["attn"], h, cfg, cache)
        else:
            if mode == "train":
                y, new_cache = attn.gqa_attention(p["attn"], h, cfg,
                                                  use_pallas=cfg.use_pallas), cache
            elif mode == "prefill":
                y, new_cache = attn.gqa_prefill(p["attn"], h, cfg, max_len)
            else:
                y, new_cache = attn.gqa_decode(p["attn"], h, cfg, cache)
        x = x + y
        h = rmsnorm(p["ln2"], x)
        if cfg.moe is not None and kind == "attn":
            y, aux = moe_mod.moe_ffn(p["ffn"], h, cfg)
        else:
            y = mlp(p["ffn"], h, cfg.act)
        return x + y, new_cache, aux
    if kind == "rec":
        h = rmsnorm(p["ln1"], x)
        if mode == "train":
            y = rec.rglru_block(p["rec"], h, cfg)
            new_cache = cache
        elif mode == "prefill":
            y, new_cache = rec.rglru_block_with_state(p["rec"], h, cfg, None)
        else:
            y, new_cache = rec.rglru_decode(p["rec"], h, cfg, cache)
        x = x + y
        h = rmsnorm(p["ln2"], x)
        return x + mlp(p["ffn"], h, cfg.act), new_cache, aux
    if kind in ("mlstm", "slstm"):
        mod = xl if True else None
        h = rmsnorm(p["ln"], x)
        if kind == "mlstm":
            if mode == "decode":
                y, new_cache = xl.mlstm_decode(p["cell"], h, cfg, cache)
            else:
                y, new_cache = xl.mlstm_block(p["cell"], h, cfg,
                                              None if mode == "train" else None)
                if mode == "train":
                    new_cache = cache
        else:
            if mode == "decode":
                y, new_cache = xl.slstm_decode(p["cell"], h, cfg, cache)
            else:
                y, new_cache = xl.slstm_block(p["cell"], h, cfg, None)
                if mode == "train":
                    new_cache = cache
        return x + y, new_cache, aux
    raise ValueError(kind)


def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 dtype, as_spec: bool):
    """Cache spec (ShapeDtypeStruct) or concrete initial cache per kind."""
    def conc(tree):
        if as_spec:
            return tree
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), tree,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

    if kind in ("attn", "attn_dense"):
        if cfg.attention == "mla":
            spec = attn.mla_cache_spec(cfg, batch, max_len, dtype)
            return conc(spec)
        spec = attn.gqa_cache_spec(cfg, batch, max_len, dtype)
        return conc(spec)
    if kind == "rec":
        spec = rec.rglru_state_spec(cfg, batch)
        return conc(spec)
    if kind == "mlstm":
        spec = xl.mlstm_state_spec(cfg, batch)
        if as_spec:
            return spec
        c = conc(spec)
        return c._replace(m=jnp.full(c.m.shape, -1e30, jnp.float32))
    if kind == "slstm":
        spec = xl.slstm_state_spec(cfg, batch)
        if as_spec:
            return spec
        c = conc(spec)
        return c._replace(m=jnp.full(c.m.shape, -1e30, jnp.float32))
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------
class LayerPlan(NamedTuple):
    lead: Tuple[str, ...]       # unrolled leading layers (kinds)
    pattern: Tuple[str, ...]    # scanned super-block pattern
    n_scan: int                 # number of scanned super-blocks
    tail: Tuple[str, ...]       # unrolled trailing layers


def layer_plan(cfg: ModelConfig) -> LayerPlan:
    kinds: List[str] = [
        cfg.block_pattern[i % len(cfg.block_pattern)] for i in range(cfg.num_layers)
    ]
    n_lead = cfg.moe.num_dense_layers if cfg.moe is not None else 0
    lead = tuple("attn_dense" for _ in range(n_lead))
    rest = kinds[n_lead:]
    p = len(cfg.block_pattern)
    n_scan = len(rest) // p
    tail = tuple(rest[n_scan * p :])
    return LayerPlan(lead=lead, pattern=tuple(cfg.block_pattern), n_scan=n_scan,
                     tail=tail)


# ---------------------------------------------------------------------------
# The Model
# ---------------------------------------------------------------------------
class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = layer_plan(cfg)
        self.dtype = _dtype(cfg)

    # ------------------------------------------------------------- specs ---
    def specs(self) -> Dict:
        cfg, plan, dt = self.cfg, self.plan, self.dtype
        sb_spec = {f"b{i}": _block_spec(cfg, k, dt) for i, k in enumerate(plan.pattern)}
        out: Dict[str, Any] = {
            "embed": embed_spec(cfg.vocab_size, cfg.d_model, dt),
            "lead": [_block_spec(cfg, k, dt) for k in plan.lead],
            "blocks": stack_layer_specs(sb_spec, plan.n_scan) if plan.n_scan else {},
            "tail": [_block_spec(cfg, k, dt) for k in plan.tail],
            "final_norm": rmsnorm_spec(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            out["unembed"] = unembed_spec(cfg.vocab_size, cfg.d_model, dt)
        if cfg.mtp_depth:
            out["mtp"] = {
                "proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                  ("embed", None), dtype=dt),
                "block": _block_spec(cfg, "attn_dense" if cfg.moe else "attn", dt),
                "norm": rmsnorm_spec(cfg.d_model, dt),
            }
        return out

    def init(self, rng) -> Dict:
        return init_params(self.specs(), rng)

    def param_shapes(self) -> Dict:
        return shape_dtype_tree(self.specs())

    # ----------------------------------------------------------- forward ---
    def _logits(self, params, h):
        if self.cfg.tie_embeddings:
            return h @ params["embed"]["table"].T
        return unembed(params["unembed"], h)

    def _embed_inputs(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.frontend == "audio":
            return batch["embeds"].astype(self.dtype)  # stub frontend output
        x = embed(params["embed"], batch["tokens"])
        if cfg.frontend == "vision":
            x = jnp.concatenate([batch["embeds"].astype(x.dtype), x], axis=1)
        return x

    def _stack(self, params, x, mode, caches=None, max_len: int = 0):
        """Run lead → scanned super-blocks → tail. Returns (x, caches, aux)."""
        cfg, plan = self.cfg, self.plan
        aux_total = jnp.float32(0)
        new_lead = []
        for p_l, kind, c_l in zip(
            params["lead"], plan.lead,
            caches["lead"] if caches else [None] * len(plan.lead),
        ):
            x, nc, aux = _block_apply(cfg, kind, p_l, x, mode, c_l, max_len)
            new_lead.append(nc)
            aux_total = aux_total + aux

        new_scan = caches["blocks"] if caches else None
        if plan.n_scan:
            def superblock(x_and_aux, xs):
                x_, aux_ = x_and_aux
                p_sb, c_sb = xs
                ncs = {}
                for i, kind in enumerate(plan.pattern):
                    c_i = c_sb[f"b{i}"] if c_sb is not None else None
                    x_, nc, a = _block_apply(cfg, kind, p_sb[f"b{i}"], x_, mode,
                                             c_i, max_len)
                    ncs[f"b{i}"] = nc
                    aux_ = aux_ + a
                return (x_, aux_), ncs

            body = superblock
            if cfg.remat != "none" and mode == "train":
                body = jax.checkpoint(superblock, prevent_cse=False)

            c_scan = caches["blocks"] if caches is not None else None
            if c_scan is None:
                # dummy per-layer None caches for scan structure
                (x, aux_total), _ = jax.lax.scan(
                    lambda ca, p_sb: body(ca, (p_sb, None)),
                    (x, aux_total), params["blocks"],
                )
            else:
                (x, aux_total), new_scan = jax.lax.scan(
                    body, (x, aux_total), (params["blocks"], c_scan)
                )

        new_tail = []
        for p_l, kind, c_l in zip(
            params["tail"], plan.tail,
            caches["tail"] if caches else [None] * len(plan.tail),
        ):
            x, nc, aux = _block_apply(cfg, kind, p_l, x, mode, c_l, max_len)
            new_tail.append(nc)
            aux_total = aux_total + aux

        new_caches = (
            {"lead": new_lead, "blocks": new_scan, "tail": new_tail}
            if caches is not None
            else None
        )
        return x, new_caches, aux_total

    def forward(self, params, batch) -> jnp.ndarray:
        """Training-mode forward to final hidden states [B, T, D]."""
        x = self._embed_inputs(params, batch)
        x, _, aux = self._stack(params, x, "train")
        return rmsnorm(params["final_norm"], x), aux

    # -------------------------------------------------------------- loss ---
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        h, aux = self.forward(params, batch)
        labels = batch["labels"]
        mask = batch.get("mask")
        if cfg.frontend == "vision":
            h = h[:, cfg.frontend_tokens :]  # loss over text positions only
        T = labels.shape[1]
        if T >= 2048:
            ce = chunked_xent(h, lambda hc: self._logits(params, hc), labels, mask)
        else:
            ce = softmax_xent(self._logits(params, h), labels, mask)
        total = ce
        metrics = {"ce": ce}
        if cfg.moe is not None:
            total = total + cfg.moe.aux_loss_weight * aux
            metrics["aux"] = aux
        if cfg.mtp_depth:
            mtp_ce = self._mtp_loss(params, h, batch)
            total = total + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params, h, batch) -> jnp.ndarray:
        """DeepSeek-V3 multi-token prediction: one extra block predicts t+2."""
        cfg = self.cfg
        labels = batch["labels"]
        emb_next = embed(params["embed"], labels)      # embedding of token t+1
        z = jnp.concatenate([h.astype(emb_next.dtype), emb_next], axis=-1)
        z = z @ params["mtp"]["proj"]
        z, _, _ = _block_apply(cfg, "attn_dense" if cfg.moe else "attn",
                               params["mtp"]["block"], z, "train")
        z = rmsnorm(params["mtp"]["norm"], z)
        labels2 = jnp.roll(labels, -1, axis=1)
        mask = jnp.ones_like(labels2, jnp.float32).at[:, -1].set(0.0)
        if labels2.shape[1] >= 2048:
            return chunked_xent(z, lambda hc: self._logits(params, hc), labels2, mask)
        return softmax_xent(self._logits(params, z), labels2, mask)

    # ------------------------------------------------------------- serve ---
    def cache(self, batch: int, max_len: int, as_spec: bool = False) -> Dict:
        cfg, plan = self.cfg, self.plan
        mk = lambda kind: _block_cache(cfg, kind, batch, max_len, self.dtype, as_spec)
        lead = [mk(k) for k in plan.lead]
        tail = [mk(k) for k in plan.tail]
        blocks = None
        if plan.n_scan:
            sb = {f"b{i}": mk(k) for i, k in enumerate(plan.pattern)}
            if as_spec:
                blocks = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((plan.n_scan, *s.shape), s.dtype),
                    sb,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
                )
            else:
                blocks = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (plan.n_scan, *a.shape)).copy(), sb
                )
        return {"lead": lead, "blocks": blocks, "tail": tail}

    def prefill(self, params, batch, max_len: int):
        """Process the prompt; returns (last-token logits, caches)."""
        x = self._embed_inputs(params, batch)
        caches = self.cache(x.shape[0], max_len)
        x, new_caches, _ = self._stack(params, x, "prefill", caches, max_len)
        h = rmsnorm(params["final_norm"], x[:, -1:])
        return self._logits(params, h), new_caches

    def decode_step(self, params, caches, tokens):
        """One token for every sequence. tokens: [B, 1] → logits [B, 1, V]."""
        x = embed(params["embed"], tokens)
        x, new_caches, _ = self._stack(params, x, "decode", caches)
        h = rmsnorm(params["final_norm"], x)
        return self._logits(params, h), new_caches
