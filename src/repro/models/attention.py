"""Attention: GQA + MLA, train/prefill (online-softmax, chunked) and decode.

The chunked online-softmax implementation (`online_attention`) is the XLA
path used everywhere on CPU and in the dry-run; on TPU the Pallas flash
kernel (`repro.kernels`) implements the same contract and is swapped in via
``ModelConfig.use_pallas``.  Both are validated against each other and against
the quadratic reference in tests.

Sharding note: GQA KV heads are *expanded to the full head count before the
attention einsums* (`_expand_kv`).  With K < |model| the [K, G] factorisation
of H cannot be expressed as a sharding of either dim, and XLA falls back to
"involuntary full rematerialization" (replicate + reslice) on every reshape —
measured at ~100× the expected ICI traffic on the 16×16 mesh (see
EXPERIMENTS.md §Perf iteration 1).  Expanding keeps every tensor sharded on
the same ``heads`` axis end-to-end; the repeat is chip-local.

MLA (DeepSeek multi-head latent attention) keeps the compressed KV cache
``(c_kv, k_rope)`` — 576 floats/token instead of 2·H·d — and uses the
*absorbed-weight* decode path (scores and values computed in the latent
space), which is the memory-roofline win that makes 128-head decode feasible.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig, ModelConfig
from .layers import ashard, rmsnorm, rmsnorm_spec, rope
from .specs import ParamSpec

_NEG_INF = -1e30


def _expand_kv(k: jnp.ndarray, H: int) -> jnp.ndarray:
    """[B, T, K, d] → [B, T, H, d] by repeating each KV head H//K times."""
    K = k.shape[2]
    if K == H:
        return k
    reps = H // K
    k = jnp.repeat(k, reps, axis=2)
    return ashard(k, ("batch", None, "heads", None))


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (XLA path; flash-kernel contract)
# ---------------------------------------------------------------------------
def online_attention(
    q: jnp.ndarray,  # [B, Tq, H, dk]
    k: jnp.ndarray,  # [B, Tk, K, dk]
    v: jnp.ndarray,  # [B, Tk, K, dv]
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 512,
    k_block: int = 1024,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Blocked attention with running (max, sum) — O(Tq·blk) live memory.

    GQA KV heads are expanded to H.  ``window > 0`` restricts keys to
    ``q_pos - window < k_pos <= q_pos``.  The KV-block scan body is rematted
    (flash-style): backward recomputes the [qb, kb] probability block instead
    of saving nk of them.
    """
    B, Tq, H, dk = q.shape
    _, Tk, K, dv = v.shape
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)

    qb = min(q_block, Tq)
    kb = min(k_block, Tk)
    pq = (-Tq) % qb
    pk = (-Tk) % kb
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // qb, kp.shape[1] // kb

    # [B, nq, H, qb, dk] / [B, nk, H, kb, d*]
    qs = qp.reshape(B, nq, qb, H, dk).transpose(0, 1, 3, 2, 4) * scale
    ks = kp.reshape(B, nk, kb, H, dk).transpose(0, 1, 3, 2, 4)
    vs = vp.reshape(B, nk, kb, H, dv).transpose(0, 1, 3, 2, 4)

    q_pos = q_offset + jnp.arange(nq * qb).reshape(nq, qb)
    k_pos = jnp.arange(nk * kb).reshape(nk, kb)
    k_valid = k_pos < Tk

    def per_batch(qs_b, ks_b, vs_b):
        # qs_b: [nq, H, qb, dk]; ks_b: [nk, H, kb, dk]; vs_b: [nk, H, kb, dv]
        def one_q_block(qi, qpos):
            @jax.checkpoint
            def kv_step(carry, xs):
                m, l, acc = carry
                kb_, vb_, kpos, kval = xs
                s = jnp.einsum(
                    "hqd,hld->hql", qi, kb_, preferred_element_type=jnp.float32
                )
                mask = kval[None, :]
                if causal:
                    mask = mask & (kpos[None, :] <= qpos[:, None])
                if window > 0:
                    mask = mask & (kpos[None, :] > qpos[:, None] - window)
                s = jnp.where(mask[None, :, :], s, _NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "hql,hld->hqd", p.astype(vb_.dtype), vb_,
                    preferred_element_type=jnp.float32,
                )
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((H, qb), _NEG_INF, jnp.float32)
            l0 = jnp.zeros((H, qb), jnp.float32)
            a0 = jnp.zeros((H, qb, dv), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), (ks_b, vs_b, k_pos, k_valid)
            )
            return acc / jnp.maximum(l, 1e-30)[..., None]

        return jax.vmap(one_q_block)(qs_b, q_pos)

    out = jax.vmap(per_batch)(qs, ks, vs)        # [B, nq, H, qb, dv]
    out = out.transpose(0, 1, 3, 2, 4).reshape(B, nq * qb, H, dv)
    return out[:, :Tq].astype(v.dtype)


def full_attention_reference(
    q, k, v, *, causal=True, window=0, scale=None, q_offset=0
) -> jnp.ndarray:
    """Quadratic reference (tests + tiny shapes). Same contract as above."""
    B, Tq, H, dk = q.shape
    _, Tk, K, dv = v.shape
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    s = jnp.einsum("bqhd,blhd->bhql", q, k, preferred_element_type=jnp.float32) * scale
    q_pos = q_offset + jnp.arange(Tq)
    k_pos = jnp.arange(Tk)
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhql,blhd->bqhd", p.astype(v.dtype), v)
    return out


def decode_attention(
    q: jnp.ndarray,          # [B, 1, H, dk]
    k_cache: jnp.ndarray,    # [B, S, K, dk]
    v_cache: jnp.ndarray,    # [B, S, K, dv]
    length: jnp.ndarray,     # [B] or scalar — #valid cache entries
    *,
    window: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    B, S, K, dk = k_cache.shape
    H = q.shape[2]
    dv = v_cache.shape[-1]
    kc = _expand_kv(k_cache, H)
    vc = _expand_kv(v_cache, H)
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    s = jnp.einsum(
        "bhd,bshd->bhs", q[:, 0], kc, preferred_element_type=jnp.float32
    ) * scale
    pos = jnp.arange(S)[None, :]
    lb = jnp.broadcast_to(jnp.asarray(length).reshape(-1, 1), (B, S))
    valid = pos < lb
    if window > 0:
        valid &= pos >= lb - window
    s = jnp.where(valid[:, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p.astype(vc.dtype), vc)
    return out[:, None].astype(vc.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------
def gqa_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamSpec((D, H * hd), ("embed", "heads"), dtype=dtype),
        "wk": ParamSpec((D, K * hd), ("embed", "heads"), dtype=dtype),
        "wv": ParamSpec((D, K * hd), ("embed", "heads"), dtype=dtype),
        "wo": ParamSpec((H * hd, D), ("heads", "embed"), dtype=dtype),
    }


class KVCache(NamedTuple):
    k: jnp.ndarray       # [B, S, K, hd]
    v: jnp.ndarray
    length: jnp.ndarray  # [] int32 — tokens currently cached


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    S = min(max_len, cfg.window) if cfg.window else max_len
    return KVCache(
        k=jax.ShapeDtypeStruct((batch, S, K, hd), dtype),
        v=jax.ShapeDtypeStruct((batch, S, K, hd), dtype),
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, T, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, T, H, hd)
    k = (x @ p["wk"]).reshape(B, T, K, hd)
    v = (x @ p["wv"]).reshape(B, T, K, hd)
    q = ashard(rope(q, positions, cfg.rope_theta), ("batch", None, "heads", None))
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(p, x, cfg: ModelConfig, *, use_pallas: bool = False):
    """Training/prefill self-attention. x: [B, T, D] → [B, T, D]."""
    B, T, _ = x.shape
    positions = jnp.arange(T)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    if use_pallas:
        from ..kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.window,
            q_block=cfg.q_block, k_block=cfg.k_block,
        )
    else:
        out = online_attention(
            q, k, v, causal=cfg.causal, window=cfg.window,
            q_block=cfg.q_block, k_block=cfg.k_block,
        )
    out = out.reshape(B, T, -1) @ p["wo"]
    return ashard(out, ("batch", None, "embed"))


def gqa_prefill(p, x, cfg: ModelConfig, max_len: int):
    """Prefill: run attention AND build the cache (ring-buffered if windowed)."""
    B, T, _ = x.shape
    positions = jnp.arange(T)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = online_attention(
        q, k, v, causal=cfg.causal, window=cfg.window,
        q_block=cfg.q_block, k_block=cfg.k_block,
    )
    S = min(max_len, cfg.window) if cfg.window else max_len
    if T >= S:
        ck, cv = k[:, T - S :], v[:, T - S :]
        if cfg.window > 0:
            # Ring-buffer layout: token t lives at slot t % S so decode's
            # ``pos % S`` overwrite hits the oldest entry.
            ck = jnp.roll(ck, shift=T % S, axis=1)
            cv = jnp.roll(cv, shift=T % S, axis=1)
    else:
        pad = ((0, 0), (0, S - T), (0, 0), (0, 0))
        ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
    cache = KVCache(k=ck, v=cv, length=jnp.int32(T))
    y = out.reshape(B, T, -1) @ p["wo"]
    return ashard(y, ("batch", None, "embed")), cache


def gqa_decode(p, x, cfg: ModelConfig, cache: KVCache):
    """One decode step. x: [B, 1, D]; returns ([B, 1, D], new cache)."""
    B, _, D = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    pos = cache.length  # absolute position of the new token
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, K, hd)
    v = (x @ p["wv"]).reshape(B, 1, K, hd)
    ppos = jnp.full((B, 1), pos, jnp.int32)
    q = rope(q, ppos, cfg.rope_theta)
    k = rope(k, ppos, cfg.rope_theta)
    S = cache.k.shape[1]
    slot = jnp.where(cfg.window > 0, pos % S, jnp.minimum(pos, S - 1))
    ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    if cfg.window > 0:
        n_valid = jnp.minimum(pos + 1, S)
        out = decode_attention(q, ck, cv, jnp.broadcast_to(n_valid, (B,)))
    else:
        out = decode_attention(q, ck, cv, jnp.broadcast_to(pos + 1, (B,)))
    y = out.reshape(B, 1, -1) @ p["wo"]
    new_cache = KVCache(k=ck, v=cv, length=cache.length + 1)
    return ashard(y, ("batch", None, "embed")), new_cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek V2/V3)
# ---------------------------------------------------------------------------
def mla_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    m: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    dn, dr, dv = m.nope_head_dim, m.rope_head_dim, m.v_head_dim
    spec: Dict = {
        "w_dkv": ParamSpec((D, m.kv_lora_rank), ("embed", None), dtype=dtype),
        "kv_norm": rmsnorm_spec(m.kv_lora_rank, dtype),
        "w_uk": ParamSpec((m.kv_lora_rank, H, dn), (None, "heads", None), dtype=dtype),
        "w_uv": ParamSpec((m.kv_lora_rank, H, dv), (None, "heads", None), dtype=dtype),
        "w_kr": ParamSpec((D, dr), ("embed", None), dtype=dtype),
        "wo": ParamSpec((H * dv, D), ("heads", "embed"), dtype=dtype),
    }
    if m.q_lora_rank:
        spec.update(
            w_dq=ParamSpec((D, m.q_lora_rank), ("embed", None), dtype=dtype),
            q_norm=rmsnorm_spec(m.q_lora_rank, dtype),
            w_uq=ParamSpec(
                (m.q_lora_rank, H, dn + dr), (None, "heads", None), dtype=dtype
            ),
        )
    else:
        spec["wq"] = ParamSpec((D, H, dn + dr), ("embed", "heads", None), dtype=dtype)
    return spec


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # [B, S, kv_lora]
    k_rope: jnp.ndarray  # [B, S, dr]
    length: jnp.ndarray


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jax.ShapeDtypeStruct((batch, max_len, m.rope_head_dim), dtype),
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )


def _mla_q(p, x, cfg: ModelConfig, positions):
    m = cfg.mla
    if m.q_lora_rank:
        cq = rmsnorm(p["q_norm"], x @ p["w_dq"])
        q = jnp.einsum("btr,rhd->bthd", cq, p["w_uq"])
    else:
        q = jnp.einsum("btd,dhe->bthe", x, p["wq"])
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return ashard(q_nope, ("batch", None, "heads", None)), ashard(
        q_rope, ("batch", None, "heads", None)
    )


def _mla_latents(p, x, cfg: ModelConfig, positions):
    c_kv = rmsnorm(p["kv_norm"], x @ p["w_dkv"])            # [B, T, r]
    k_rope = rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(p, x, cfg: ModelConfig, *, use_pallas: bool = False):
    """Training/prefill MLA: expand latents to per-head K/V, flash-attend."""
    m = cfg.mla
    B, T, _ = x.shape
    H = cfg.num_heads
    positions = jnp.arange(T)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latents(p, x, cfg, positions)
    k_nope = ashard(jnp.einsum("btr,rhd->bthd", c_kv, p["w_uk"]),
                    ("batch", None, "heads", None))
    v = ashard(jnp.einsum("btr,rhd->bthd", c_kv, p["w_uv"]),
               ("batch", None, "heads", None))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, m.rope_head_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    attend = online_attention
    if use_pallas:
        from ..kernels import ops as kops

        attend = kops.flash_attention
    out = attend(
        q, k, v, causal=cfg.causal, window=cfg.window,
        q_block=cfg.q_block, k_block=cfg.k_block, scale=scale,
    )
    y = out.reshape(B, T, -1) @ p["wo"]
    return ashard(y, ("batch", None, "embed"))


def mla_prefill(p, x, cfg: ModelConfig, max_len: int):
    m = cfg.mla
    B, T, _ = x.shape
    positions = jnp.arange(T)[None, :]
    y = mla_attention(p, x, cfg)
    c_kv, k_rope = _mla_latents(p, x, cfg, positions)
    pad = max_len - T
    cache = MLACache(
        c_kv=jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        k_rope=jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
        length=jnp.int32(T),
    )
    return y, cache


def mla_decode(p, x, cfg: ModelConfig, cache: MLACache):
    """Absorbed-weight decode: score and reduce in the 512-d latent space.

    q_lat = q_nope · W_uk  →  scores = q_lat · c_kv + q_rope · k_rope
    out   = (attn · c_kv) · W_uv — the cache stays compressed end-to-end.
    """
    m = cfg.mla
    B = x.shape[0]
    pos = cache.length
    ppos = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, ppos)
    c_new, kr_new = _mla_latents(p, x, cfg, ppos)
    c_kv = jax.lax.dynamic_update_slice(cache.c_kv, c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache.k_rope, kr_new, (0, pos, 0))

    q_lat = jnp.einsum("bthd,rhd->bthr", q_nope, p["w_uk"])  # absorb W_uk
    s_lat = jnp.einsum("bthr,bsr->bths", q_lat, c_kv)
    s_rope = jnp.einsum("bthd,bsd->bths", q_rope, k_rope)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    s = (s_lat + s_rope).astype(jnp.float32) * scale
    S = c_kv.shape[1]
    valid = jnp.arange(S)[None, :] < (pos + 1)
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bths,bsr->bthr", a, c_kv)            # reduce in latent
    out = jnp.einsum("bthr,rhd->bthd", o_lat, p["w_uv"])     # absorb W_uv
    y = out.reshape(B, 1, -1) @ p["wo"]
    new_cache = MLACache(c_kv=c_kv, k_rope=k_rope, length=cache.length + 1)
    return ashard(y, ("batch", None, "embed")), new_cache
