"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, sequential) — Beck et al., arXiv:2405.04517.

mLSTM recurrence per head (d = head dim, stabiliser m):

    log i_t, log f_t = gate projections (log f via logsigmoid)
    m_t  = max(log f_t + m_{t-1}, log i_t)
    C_t  = e^{log f_t + m_{t-1} - m_t} C_{t-1} + e^{log i_t - m_t} v_t k_t^T
    n_t  = ...same decay... + e^{log i_t - m_t} k_t
    h_t  = (C_t q_t) / max(|n_t . q_t|, e^{-m_t})

Training/prefill uses the *chunkwise* form: intra-chunk quadratic attention
with gate-decay masks + inter-chunk recurrent state carried by a scan over
chunks — O(T·K) memory instead of O(T^2), the same trade the flash kernel
makes for softmax attention.  Decode is the plain one-step recurrence.
sLSTM has a true (non-associative) recurrent dependency through h_{t-1}, so
it is a lax.scan over time in all modes, faithful to the paper.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..compat import get_abstract_mesh, shard_map
from ..configs.base import ModelConfig, XLSTMConfig
from .layers import ashard, rmsnorm, rmsnorm_spec
from .specs import ParamSpec


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_block_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    x: XLSTMConfig = cfg.xlstm
    D, H = cfg.d_model, cfg.num_heads
    inner = int(x.proj_factor_m * D)
    dh = inner // H
    dqk = dh // 2  # qk at half width (official qk_dim_factor=0.5)
    return {
        "w_up": ParamSpec((D, inner), ("embed", "mlp"), dtype=dtype),
        "w_og": ParamSpec((D, inner), ("embed", "mlp"), dtype=dtype),
        "wq": ParamSpec((H, dh, dqk), ("heads", None, None), dtype=dtype),
        "wk": ParamSpec((H, dh, dqk), ("heads", None, None), dtype=dtype),
        "wv": ParamSpec((H, dh, dh), ("heads", None, None), dtype=dtype),
        "w_if": ParamSpec((inner, 2 * H), ("mlp", None), init="normal",
                          scale=0.02, dtype=jnp.float32),
        "b_if": ParamSpec((2 * H,), (None,), init="zeros", dtype=jnp.float32),
        "gnorm": rmsnorm_spec(inner, dtype),
        "w_down": ParamSpec((inner, D), ("mlp", "embed"), dtype=dtype),
    }


class MLSTMState(NamedTuple):
    c: jnp.ndarray   # [B, H, dqk, dh]
    n: jnp.ndarray   # [B, H, dqk]
    m: jnp.ndarray   # [B, H]


def mlstm_state_spec(cfg: ModelConfig, batch: int) -> MLSTMState:
    x = cfg.xlstm
    H = cfg.num_heads
    inner = int(x.proj_factor_m * cfg.d_model)
    dh = inner // H
    dqk = dh // 2
    return MLSTMState(
        c=jax.ShapeDtypeStruct((batch, H, dqk, dh), jnp.float32),
        n=jax.ShapeDtypeStruct((batch, H, dqk), jnp.float32),
        m=jax.ShapeDtypeStruct((batch, H), jnp.float32),
    )


def _mlstm_qkv_gates(p, x2: jnp.ndarray, cfg: ModelConfig):
    """x2: [B, T, inner] → q,k,v [B,T,H,*], log_i/log_f [B,T,H] (fp32)."""
    H = cfg.num_heads
    B, T, inner = x2.shape
    dh = inner // H
    z = x2.reshape(B, T, H, dh)
    q = jnp.einsum("bthd,hde->bthe", z, p["wq"])
    k = jnp.einsum("bthd,hde->bthe", z, p["wk"]) / math.sqrt(p["wq"].shape[-1])
    v = jnp.einsum("bthd,hde->bthe", z, p["wv"])
    gif = x2.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    log_i, raw_f = jnp.split(gif, 2, axis=-1)             # [B, T, H]
    log_f = jax.nn.log_sigmoid(raw_f)
    return q, k, v, log_i, log_f


def mlstm_chunkwise(
    q, k, v, log_i, log_f, state: MLSTMState, chunk: int
) -> Tuple[jnp.ndarray, MLSTMState]:
    """Chunkwise-parallel mLSTM. Shapes: q,k [B,T,H,dqk], v [B,T,H,dh]."""
    B, T, H, dqk = q.shape
    dh = v.shape[-1]
    K = min(chunk, T)
    pad = (-T) % K
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zp(q), zp(k), zp(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nC = q.shape[1] // K

    # [nC, B, H, K, *]
    rs = lambda a, d: a.reshape(B, nC, K, H, d).transpose(1, 0, 3, 2, 4)
    qcb = rs(q, dqk)
    kc = rs(k, dqk)
    vc = rs(v, dh)
    li = log_i.reshape(B, nC, K, H).transpose(1, 0, 3, 2).astype(jnp.float32)
    lf = log_f.reshape(B, nC, K, H).transpose(1, 0, 3, 2).astype(jnp.float32)

    def chunk_step(carry, xs):
        C, n, m = carry                      # [B,H,dqk,dh], [B,H,dqk], [B,H]
        qb, kb, vb, lib, lfb = xs            # [B,H,K,*]
        G = jnp.cumsum(lfb, axis=-1)         # within-chunk cumulative log f
        # A[t,s] = G_t - G_s + log i_s  for s <= t
        A = G[..., :, None] - G[..., None, :] + lib[..., None, :]
        tri = jnp.tril(jnp.ones((K, K), bool))
        A = jnp.where(tri, A, -jnp.inf)
        m_intra = jnp.max(A, axis=-1)                          # [B,H,K]
        m_t = jnp.maximum(G + m[..., None], m_intra)           # [B,H,K]
        # intra: stabilised decay-weighted attention
        S = jnp.exp(A - m_t[..., None])                        # [B,H,K,K]
        qk = jnp.einsum("bhte,bhse->bhts", qb, kb,
                        preferred_element_type=jnp.float32)
        W = S * qk
        num_intra = jnp.einsum("bhts,bhsd->bhtd", W.astype(vb.dtype), vb)
        den_intra = jnp.sum(W, axis=-1)                        # [B,H,K]
        # inter: contribution of the carried state
        scale = jnp.exp(G + m[..., None] - m_t)                # [B,H,K]
        num_inter = jnp.einsum("bhte,bhed->bhtd", qb, C.astype(qb.dtype))
        num_inter = num_inter.astype(jnp.float32) * scale[..., None]
        den_inter = jnp.einsum("bhte,bhe->bht", qb, n.astype(qb.dtype)) * scale
        num = num_intra.astype(jnp.float32) + num_inter
        den = den_intra + den_inter.astype(jnp.float32)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to the chunk boundary
        g_last = G[..., -1]                                    # [B,H]
        w_end = G[..., -1:] - G + lib                          # [B,H,K]
        m_new = jnp.maximum(g_last + m, jnp.max(w_end, axis=-1))
        decay = jnp.exp(g_last + m - m_new)
        wi = jnp.exp(w_end - m_new[..., None])                 # [B,H,K]
        C_new = decay[..., None, None] * C + jnp.einsum(
            "bhse,bhsd,bhs->bhed", kb.astype(jnp.float32),
            vb.astype(jnp.float32), wi)
        n_new = decay[..., None] * n + jnp.einsum(
            "bhse,bhs->bhe", kb.astype(jnp.float32), wi)
        return (C_new, n_new, m_new), h

    init = (state.c, state.n, state.m)
    (C, n, m), hs = jax.lax.scan(chunk_step, init, (qcb, kc, vc, li, lf))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, nC * K, H, dh)[:, :T]
    return h, MLSTMState(c=C, n=n, m=m)


def mlstm_step(q1, k1, v1, li1, lf1, state: MLSTMState):
    """One-token recurrence. q1,k1 [B,H,dqk], v1 [B,H,dh], li/lf [B,H]."""
    m_new = jnp.maximum(lf1 + state.m, li1)
    fd = jnp.exp(lf1 + state.m - m_new)
    iw = jnp.exp(li1 - m_new)
    C = fd[..., None, None] * state.c + iw[..., None, None] * (
        k1.astype(jnp.float32)[..., :, None] * v1.astype(jnp.float32)[..., None, :]
    )
    n = fd[..., None] * state.n + iw[..., None] * k1.astype(jnp.float32)
    num = jnp.einsum("bhe,bhed->bhd", q1.astype(jnp.float32), C)
    den = jnp.einsum("bhe,bhe->bh", q1.astype(jnp.float32), n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, MLSTMState(c=C, n=n, m=m_new)


def mlstm_block(p, x: jnp.ndarray, cfg: ModelConfig,
                state: MLSTMState | None = None):
    """Full mLSTM block. x: [B,T,D] → ([B,T,D], state)."""
    B, T, D = x.shape
    x2 = ashard(x @ p["w_up"], ("batch", None, "mlp"))
    og = jax.nn.sigmoid(x @ p["w_og"])
    q, k, v, li, lf = _mlstm_qkv_gates(p, x2, cfg)
    if state is None:
        H = cfg.num_heads
        dqk = q.shape[-1]
        dh = v.shape[-1]
        state = MLSTMState(
            c=jnp.zeros((B, H, dqk, dh), jnp.float32),
            n=jnp.zeros((B, H, dqk), jnp.float32),
            m=jnp.full((B, H), -1e30, jnp.float32),
        )
    h, new_state = mlstm_chunkwise(q, k, v, li, lf, state, cfg.xlstm.chunk)
    h = h.reshape(B, T, -1).astype(x.dtype)
    h = rmsnorm(p["gnorm"], h) * og
    out = h @ p["w_down"]
    return ashard(out, ("batch", None, "embed")), new_state


def mlstm_decode(p, x: jnp.ndarray, cfg: ModelConfig, state: MLSTMState):
    B = x.shape[0]
    x2 = x @ p["w_up"]
    og = jax.nn.sigmoid(x @ p["w_og"])
    q, k, v, li, lf = _mlstm_qkv_gates(p, x2, cfg)
    h, new_state = mlstm_step(q[:, 0], k[:, 0], v[:, 0], li[:, 0], lf[:, 0], state)
    h = h.reshape(B, 1, -1).astype(x.dtype)
    h = rmsnorm(p["gnorm"], h) * og
    return ashard(h @ p["w_down"], ("batch", None, "embed")), new_state


def mlstm_reference(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Sequential oracle: scan mlstm_step over time."""
    B, T, D = x.shape
    H = cfg.num_heads
    x2 = x @ p["w_up"]
    og = jax.nn.sigmoid(x @ p["w_og"])
    q, k, v, li, lf = _mlstm_qkv_gates(p, x2, cfg)
    dqk, dh = q.shape[-1], v.shape[-1]
    s0 = MLSTMState(
        c=jnp.zeros((B, H, dqk, dh), jnp.float32),
        n=jnp.zeros((B, H, dqk), jnp.float32),
        m=jnp.full((B, H), -1e30, jnp.float32),
    )

    def step(s, xs):
        qt, kt, vt, lit, lft = xs
        h, s = mlstm_step(qt, kt, vt, lit, lft, s)
        return s, h

    _, hs = jax.lax.scan(
        step, s0,
        (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         li.swapaxes(0, 1), lf.swapaxes(0, 1)),
    )
    h = hs.swapaxes(0, 1).reshape(B, T, -1).astype(x.dtype)
    h = rmsnorm(p["gnorm"], h) * og
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_block_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    x: XLSTMConfig = cfg.xlstm
    D, H = cfg.d_model, cfg.num_heads
    dh = D // H
    dff = int(x.proj_factor_s * D)
    return {
        # The sLSTM cell is a true sequential recurrence (h_{t-1} feeds the
        # gates), so per-step tensors are tiny ([B, D]); sharding them over
        # `model` costs a collective per TIME STEP (measured: 24k tiny
        # all-reduces per layer at T=4096).  Cell weights/activations are
        # replicated over `model` instead — the model axis idles through the
        # sequential section and the FFN stays tensor-parallel.
        "w_in": ParamSpec((D, 4 * D), ("embed", None), dtype=dtype),
        "r": ParamSpec((4, H, dh, dh), (None, None, None, None),
                       init="normal", scale=0.02, dtype=dtype),
        "gnorm": rmsnorm_spec(D, dtype),
        "ffn_wi": ParamSpec((D, 2 * dff), ("embed", "mlp"), dtype=dtype),
        "ffn_wo": ParamSpec((dff, D), ("mlp", "embed"), dtype=dtype),
    }


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # [B, D]
    n: jnp.ndarray
    m: jnp.ndarray
    h: jnp.ndarray


def slstm_state_spec(cfg: ModelConfig, batch: int) -> SLSTMState:
    D = cfg.d_model
    sd = jax.ShapeDtypeStruct((batch, D), jnp.float32)
    return SLSTMState(c=sd, n=sd, m=sd, h=sd)


def _slstm_cell(p, wx_t, state: SLSTMState, cfg: ModelConfig):
    """wx_t: [B, 4D] precomputed input projections for one step."""
    B = wx_t.shape[0]
    D = cfg.d_model
    H = cfg.num_heads
    dh = D // H
    hr = state.h.reshape(B, H, dh).astype(p["r"].dtype)
    rec = jnp.einsum("bhd,ghde->gbhe", hr, p["r"]).reshape(4, B, D)
    z_in, i_in, f_in, o_in = jnp.split(wx_t, 4, axis=-1)
    z = jnp.tanh(z_in.astype(jnp.float32) + rec[0].astype(jnp.float32))
    log_i = i_in.astype(jnp.float32) + rec[1].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_in.astype(jnp.float32) + rec[2].astype(jnp.float32))
    o = jax.nn.sigmoid(o_in.astype(jnp.float32) + rec[3].astype(jnp.float32))
    m_new = jnp.maximum(log_f + state.m, log_i)
    fd = jnp.exp(log_f + state.m - m_new)
    iw = jnp.exp(log_i - m_new)
    c = fd * state.c + iw * z
    n = fd * state.n + iw
    h = o * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, m=m_new, h=h)


def _slstm_scan_local(p_r, wx, state, cfg: ModelConfig):
    """The sequential cell scan, pure-local math (runs inside a fully-manual
    shard_map when a mesh is active: per-TIME-STEP tensors are tiny and any
    GSPMD sharding of them costs one collective per step per layer — measured
    3 TB/chip/step of 1 MB all-reduces on the 16×16 mesh)."""
    def step(s, wx_t):
        s = _slstm_cell({"r": p_r}, wx_t, s, cfg)
        return s, s.h

    new_state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    return hs.swapaxes(0, 1), new_state


def slstm_block(p, x: jnp.ndarray, cfg: ModelConfig,
                state: SLSTMState | None = None):
    """x: [B, T, D] → ([B, T, D], state). Sequential over T (faithful)."""
    from ..models.layers import _ACT_RULES

    B, T, D = x.shape
    if state is None:
        z = jnp.zeros((B, D), jnp.float32)
        state = SLSTMState(c=z, n=z, m=jnp.full((B, D), -1e30, jnp.float32), h=z)
    wx = ashard(x @ p["w_in"], ("batch", None, None))  # [B, T, 4D] repl/model

    if _ACT_RULES:  # distributed: fully-manual island, batch over data(+pod)
        from jax.sharding import PartitionSpec as P

        mesh_axes = tuple(get_abstract_mesh().axis_names)
        b_axes = ("pod", "data") if "pod" in mesh_axes else ("data",)
        bspec = P(b_axes)
        fn = shard_map(
            lambda r, w, s: _slstm_scan_local(r, w, s, cfg),
            in_specs=(P(), bspec, jax.tree.map(lambda _: bspec, state)),
            out_specs=(bspec, jax.tree.map(lambda _: bspec, state)),
            axis_names=frozenset(mesh_axes),
            check_vma=False,
        )
        hs, new_state = fn(p["r"], wx, state)
    else:
        hs, new_state = _slstm_scan_local(p["r"], wx, state, cfg)
    h = hs.astype(x.dtype)
    h = rmsnorm(p["gnorm"], h)
    # position-wise gated FFN
    f = h @ p["ffn_wi"]
    g, u = jnp.split(f, 2, axis=-1)
    out = (jax.nn.silu(g) * u) @ p["ffn_wo"]
    return ashard(out, ("batch", None, "embed")), new_state


def slstm_decode(p, x: jnp.ndarray, cfg: ModelConfig, state: SLSTMState):
    wx = (x @ p["w_in"])[:, 0]
    new_state = _slstm_cell(p, wx, state, cfg)
    h = rmsnorm(p["gnorm"], new_state.h[:, None].astype(x.dtype))
    f = h @ p["ffn_wi"]
    g, u = jnp.split(f, 2, axis=-1)
    out = (jax.nn.silu(g) * u) @ p["ffn_wo"]
    return ashard(out, ("batch", None, "embed")), new_state
