"""Model input construction: ShapeDtypeStruct stand-ins (dry-run) and
concrete synthetic batches (tests/examples) from the same declaration.

``[audio]``/``[vlm]`` modality frontends are STUBS per the assignment:
``input_specs`` supplies precomputed frame/patch embeddings at d_model.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig


def _mk(concrete: bool, rng, shape, dtype, kind: str, vocab: int = 0):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    if kind == "tokens":
        return jax.random.randint(rng, shape, 0, vocab, dtype=dtype)
    if kind == "embeds":
        return (0.02 * jax.random.normal(rng, shape)).astype(dtype)
    raise ValueError(kind)


def input_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    concrete: bool = False,
    rng: Optional[jax.Array] = None,
    dtype=jnp.bfloat16,
) -> Dict[str, Any]:
    """Inputs for the given cell.

    train:   {tokens/embeds, labels[, embeds for vlm]}
    prefill: {tokens/embeds[, embeds]}
    decode:  {tokens} — the cache is built separately from Model.cache().
    """
    B, T = shape.global_batch, shape.seq_len
    if rng is None and concrete:
        rng = jax.random.PRNGKey(0)
    rngs = jax.random.split(rng, 4) if concrete else [None] * 4

    if shape.kind == "decode":
        return {"tokens": _mk(concrete, rngs[0], (B, 1), jnp.int32, "tokens",
                              cfg.vocab_size)}

    batch: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        batch["embeds"] = _mk(concrete, rngs[0], (B, T, cfg.d_model), dtype, "embeds")
    elif cfg.frontend == "vision":
        n_txt = T - cfg.frontend_tokens
        batch["embeds"] = _mk(
            concrete, rngs[0], (B, cfg.frontend_tokens, cfg.d_model), dtype, "embeds"
        )
        batch["tokens"] = _mk(concrete, rngs[1], (B, n_txt), jnp.int32, "tokens",
                              cfg.vocab_size)
    else:
        batch["tokens"] = _mk(concrete, rngs[1], (B, T), jnp.int32, "tokens",
                              cfg.vocab_size)

    if shape.kind == "train":
        if cfg.frontend == "vision":
            n_lbl = T - cfg.frontend_tokens
        else:
            n_lbl = T
        batch["labels"] = _mk(concrete, rngs[2], (B, n_lbl), jnp.int32, "tokens",
                              cfg.vocab_size)
    return batch
