"""Declarative parameter specs with logical sharding axes.

Every model in the zoo declares its parameters as a pytree of
:class:`ParamSpec` — shape, logical axis names, and an initializer.  From the
same declaration we derive:

* materialized parameters (``init_params``),
* ``jax.ShapeDtypeStruct`` stand-ins for the dry-run (no allocation),
* ``NamedSharding`` trees via logical→mesh rules (``repro/sharding``).

This is what lets ``launch/dryrun.py`` lower a 671B-parameter model on a CPU
host: shapes and shardings come from the declaration, not from tracing a real
init.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ParamSpec:
    """One parameter: shape + logical axes + init recipe."""

    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]  # one logical name (or None) per dim
    init: str = "fan_in"                # fan_in | normal | zeros | ones | embed
    scale: float = 1.0
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], specs):
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def init_params(specs, rng: jax.Array):
    """Materialize parameters from a spec tree (CPU-scale configs only)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    rngs = jax.random.split(rng, len(leaves))

    def one(spec: ParamSpec, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        if spec.init == "normal":
            return (spec.scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
        if spec.init == "embed":
            return (spec.scale * jax.random.normal(key, spec.shape)).astype(spec.dtype)
        if spec.init == "fan_in":
            # Contraction dim is the second-to-last for >=2D (d_in, d_out)
            # weights and stacked (layers/experts, d_in, d_out) weights.
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = spec.scale / math.sqrt(max(fan_in, 1))
            return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)
        raise ValueError(f"unknown init {spec.init}")

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, rngs)])


def shape_dtype_tree(specs):
    """ShapeDtypeStruct stand-ins — the dry-run's parameter 'allocation'."""
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def logical_to_pspec(logical: Sequence[Optional[str]], rules: Dict[str, Optional[str]]) -> P:
    """Map logical axis names to mesh axes via rules; unknown names error."""
    out = []
    for name in logical:
        if name is None:
            out.append(None)
        else:
            if name not in rules:
                raise KeyError(f"no sharding rule for logical axis {name!r}")
            out.append(rules[name])
    # Trailing Nones are dropped by PartitionSpec semantics anyway.
    return P(*out)


def sharding_tree(specs, mesh: Mesh, rules: Dict[str, Optional[str]]):
    return tree_map_specs(
        lambda s: NamedSharding(mesh, logical_to_pspec(s.logical, rules)), specs
    )


def pspec_tree(specs, rules: Dict[str, Optional[str]]):
    return tree_map_specs(lambda s: logical_to_pspec(s.logical, rules), specs)


def param_count(specs) -> int:
    leaves, _ = jax.tree.flatten(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def param_bytes(specs) -> int:
    leaves, _ = jax.tree.flatten(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype).itemsize for s in leaves))


def stack_layer_specs(spec_tree, num_layers: int, axis_name: Optional[str] = "layers"):
    """Add a leading stacked-layers dim to every spec (for scan-over-layers)."""
    return tree_map_specs(
        lambda s: ParamSpec(
            shape=(num_layers, *s.shape),
            logical=(axis_name, *s.logical),
            init=s.init,
            scale=s.scale,
            dtype=s.dtype,
        ),
        spec_tree,
    )
