"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block: x → {linear branch → causal depthwise conv(4) → RG-LRU}, gated by a
parallel GeLU branch, then an output projection.  The RG-LRU is a gated
*linear* recurrence

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

which is associative, so training/prefill uses ``jax.lax.associative_scan``
(log-depth — the TPU-native answer to the paper's "per-class optimal
mechanism"), and decode is a one-step state update.  This is what makes the
``long_500k`` cell tractable: state is O(1) in sequence length.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RGLRUConfig
from .layers import ashard
from .specs import ParamSpec


def rglru_block_spec(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict:
    g: RGLRUConfig = cfg.rglru
    D = cfg.d_model
    W = g.width or D
    return {
        "w_x": ParamSpec((D, W), ("embed", "mlp"), dtype=dtype),
        "w_gate": ParamSpec((D, W), ("embed", "mlp"), dtype=dtype),
        "conv_w": ParamSpec((g.conv_width, W), (None, "mlp"), init="normal",
                            scale=0.1, dtype=dtype),
        "conv_b": ParamSpec((W,), ("mlp",), init="zeros", dtype=dtype),
        "w_a": ParamSpec((W, W), ("mlp", None), dtype=dtype),
        "b_a": ParamSpec((W,), (None,), init="zeros", dtype=dtype),
        "w_i": ParamSpec((W, W), ("mlp", None), dtype=dtype),
        "b_i": ParamSpec((W,), (None,), init="zeros", dtype=dtype),
        "lam": ParamSpec((W,), (None,), init="ones", dtype=jnp.float32),
        "w_out": ParamSpec((W, D), ("mlp", "embed"), dtype=dtype),
    }


class RGLRUState(NamedTuple):
    h: jnp.ndarray        # [B, W] recurrent state (fp32)
    conv: jnp.ndarray     # [B, conv_width-1, W] trailing inputs


def rglru_state_spec(cfg: ModelConfig, batch: int) -> RGLRUState:
    g = cfg.rglru
    W = g.width or cfg.d_model
    return RGLRUState(
        h=jax.ShapeDtypeStruct((batch, W), jnp.float32),
        conv=jax.ShapeDtypeStruct((batch, g.conv_width - 1, W), jnp.float32),
    )


def _causal_conv(p, x: jnp.ndarray, conv_width: int) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds. x: [B, T, W]."""
    out = x * p["conv_w"][conv_width - 1]
    for i in range(1, conv_width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * p["conv_w"][conv_width - 1 - i]
    return out + p["conv_b"]


def _gates(p, x: jnp.ndarray, c: float):
    r = jax.nn.sigmoid((x @ p["w_a"]).astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_a = -c * jax.nn.softplus(p["lam"]) * r      # [B, T, W] fp32
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * x.astype(jnp.float32)


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t via associative scan. a, b: [B, T, W] fp32."""
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Training forward (zero initial state). x: [B, T, D] → [B, T, D]."""
    y, _ = rglru_block_with_state(p, x, cfg, None)
    return y


def rglru_block_with_state(
    p, x: jnp.ndarray, cfg: ModelConfig, state: RGLRUState | None
) -> Tuple[jnp.ndarray, RGLRUState]:
    g = cfg.rglru
    B, T, D = x.shape
    W = g.width or D
    z = ashard(x @ p["w_x"], ("batch", None, "mlp"))
    gate = jax.nn.gelu(ashard(x @ p["w_gate"], ("batch", None, "mlp")))
    if state is not None:
        hist = jnp.concatenate([state.conv.astype(z.dtype), z], axis=1)
        zc = _causal_conv(p, hist, g.conv_width)[:, g.conv_width - 1 :]
        h0 = state.h
    else:
        zc = _causal_conv(p, z, g.conv_width)
        h0 = jnp.zeros((B, W), jnp.float32)
    a, b = _gates(p, zc, g.c)
    h = rglru_scan(a, b, h0)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    tail = jnp.concatenate([state.conv.astype(z.dtype), z], axis=1)[:, -(g.conv_width - 1):] \
        if state is not None else _tail_pad(z, g.conv_width - 1)
    new_state = RGLRUState(h=h[:, -1], conv=tail.astype(jnp.float32))
    return ashard(out, ("batch", None, "embed")), new_state


def _tail_pad(z: jnp.ndarray, n: int) -> jnp.ndarray:
    T = z.shape[1]
    if T >= n:
        return z[:, T - n :]
    return jnp.pad(z, ((0, 0), (n - T, 0), (0, 0)))


def rglru_decode(p, x: jnp.ndarray, cfg: ModelConfig, state: RGLRUState):
    """One-token step. x: [B, 1, D] → ([B, 1, D], new state)."""
    g = cfg.rglru
    z = x @ p["w_x"]                                    # [B, 1, W]
    gate = jax.nn.gelu(x @ p["w_gate"])
    hist = jnp.concatenate([state.conv.astype(z.dtype), z], axis=1)  # [B, cw, W]
    zc = jnp.einsum("btw,tw->bw", hist, p["conv_w"]) + p["conv_b"]
    zc = zc[:, None, :]
    a, b = _gates(p, zc, g.c)
    h = a[:, 0] * state.h + b[:, 0]
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    new_state = RGLRUState(h=h, conv=hist[:, 1:].astype(jnp.float32))
    return ashard(out, ("batch", None, "embed")), new_state


def rglru_reference(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Sequential-scan oracle for tests (identical math, lax.scan over T)."""
    g = cfg.rglru
    B, T, D = x.shape
    z = x @ p["w_x"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    zc = _causal_conv(p, z, g.conv_width)
    a, b = _gates(p, zc, g.c)
    W = g.width or D

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(
        step, jnp.zeros((B, W), jnp.float32),
        (a.swapaxes(0, 1), b.swapaxes(0, 1)),
    )
    h = hs.swapaxes(0, 1)
    return (h.astype(x.dtype) * gate) @ p["w_out"]
