"""Shared layers: norms, MLPs, embeddings, RoPE, losses, activation sharding.

All layers are (spec builder, pure function) pairs over explicit param pytrees
— no module framework, so the same code paths serve init, training, the
dry-run's ShapeDtypeStruct lowering, and the Pallas-kernel swap.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .specs import ParamSpec

# ---------------------------------------------------------------------------
# Activation sharding: logical rules installed by the launcher/trainer.
# Empty rules (unit tests, CPU examples) make `ashard` a no-op.
# ---------------------------------------------------------------------------
_ACT_RULES: Dict[str, Optional[object]] = {}


@contextlib.contextmanager
def activation_rules(rules: Dict[str, Optional[object]]):
    global _ACT_RULES
    prev = _ACT_RULES
    _ACT_RULES = dict(rules)
    try:
        yield
    finally:
        _ACT_RULES = prev


def ashard(x: jnp.ndarray, logical: Sequence[Optional[str]]) -> jnp.ndarray:
    """Constrain activation sharding by logical axis names (no-op w/o rules)."""
    if not _ACT_RULES:
        return x
    spec = P(*[(_ACT_RULES.get(n) if n else None) for n in logical])
    return jax.lax.with_sharding_constraint(x, spec)


# ------------------------------------------------------------------- norms --
def rmsnorm_spec(d: int, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("embed",), init="ones", dtype=dtype)}


def rmsnorm(p, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d: int, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones", dtype=dtype),
        "bias": ParamSpec((d,), ("embed",), init="zeros", dtype=dtype),
    }


def layernorm(p, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


# -------------------------------------------------------------------- MLPs --
def mlp_spec(d_model: int, d_ff: int, act: str, dtype=jnp.bfloat16) -> Dict:
    if act == "swiglu":
        return {
            # Fused gate+up projection: one matmul, better MXU utilisation.
            "wi": ParamSpec((d_model, 2 * d_ff), ("embed", "mlp"), dtype=dtype),
            "wo": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
        }
    return {
        "wi": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }


def mlp(p, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = x @ p["wi"]
    h = ashard(h, ("batch", None, "mlp"))
    if act == "swiglu":
        gate, up = jnp.split(h, 2, axis=-1)
        h = jax.nn.silu(gate) * up
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown activation {act}")
    out = h @ p["wo"]
    return ashard(out, ("batch", None, "embed"))


# -------------------------------------------------------------- embeddings --
def embed_spec(vocab: int, d_model: int, dtype=jnp.bfloat16) -> Dict:
    return {
        "table": ParamSpec(
            (vocab, d_model), ("vocab", "embed"), init="embed", scale=0.02, dtype=dtype
        )
    }


def embed(p, tokens: jnp.ndarray) -> jnp.ndarray:
    # Gather the vocab shards (model axis) before the lookup: token gathers on
    # a vocab-sharded operand force XLA down a masked-allreduce path that is
    # broken inside manual subgroups, and the gathered table slice is small
    # (V × D/|data| — e.g. 65 MB/chip for llama3).  The d_model dim stays
    # FSDP-sharded over `data`.
    table = ashard(p["table"], (None, "embed_fsdp"))
    out = jnp.take(table, tokens, axis=0)
    return ashard(out, ("batch", None, "embed"))


def unembed_spec(vocab: int, d_model: int, dtype=jnp.bfloat16) -> Dict:
    return {
        "w": ParamSpec(
            (d_model, vocab), ("embed", "vocab"), init="fan_in", dtype=dtype
        )
    }


def unembed(p, x: jnp.ndarray) -> jnp.ndarray:
    logits = x @ p["w"]
    return ashard(logits, ("batch", None, "vocab"))


# -------------------------------------------------------------------- RoPE --
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding, half-split convention.

    x: [..., T, H, d] (d even); positions: broadcastable to [..., T].
    """
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq  # [..., T, half]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ------------------------------------------------------------------- losses --
def softmax_xent(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Mean cross-entropy in fp32. logits [..., V], labels int [...].

    The gold logit is extracted with a one-hot contraction rather than a
    gather: gathers on vocab-sharded operands force an all-gather (and crash
    XLA's partitioner inside manual subgroups); the iota-compare contraction
    partitions cleanly over the ``model`` axis.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = (labels[..., None] == jnp.arange(V, dtype=labels.dtype)).astype(
        jnp.float32
    )
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_xent(
    hidden: jnp.ndarray,
    logits_fn,
    labels: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
    chunk: int = 1024,
) -> jnp.ndarray:
    """Cross-entropy without materializing [B, T, V] logits.

    Scans over sequence chunks, computing logits per chunk under remat — the
    memory-roofline lever for large-vocab models (recurrentgemma: V=256k).
    ``logits_fn(h_chunk) -> [B, c, V]`` (works for tied or untied heads).
    """
    B, T, D = hidden.shape
    if T % chunk != 0:
        return softmax_xent(logits_fn(hidden), labels, mask)
    n = T // chunk
    h = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)  # [n, B, c, D]
    y = labels.reshape(B, n, chunk).swapaxes(0, 1)
    m = (
        mask.reshape(B, n, chunk).swapaxes(0, 1).astype(jnp.float32)
        if mask is not None
        else jnp.ones((n, B, chunk), jnp.float32)
    )

    @jax.checkpoint
    def body(carry, xs):
        hc, yc, mc = xs
        logits = logits_fn(hc).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        V = logits.shape[-1]
        onehot = (yc[..., None] == jnp.arange(V, dtype=yc.dtype)).astype(jnp.float32)
        gold = jnp.sum(logits * onehot, axis=-1)
        nll = (logz - gold) * mc
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (h, y, m))
    return tot / jnp.maximum(cnt, 1.0)
