"""Optimizer substrate: AdamW + schedules + clipping (pure JAX, no optax)."""

from .adamw import AdamWState, adamw_init, adamw_update, global_norm  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
