"""AdamW with decoupled weight decay, global-norm clipping, ZeRO-friendly.

Optimizer state mirrors the parameter pytree, so the same logical sharding
rules apply: with parameters 2-D sharded (FSDP over ``data`` × TP over
``model``) the moments inherit the sharding and the update is fully local —
the ZeRO-1/3 schedule emerges from GSPMD without a separate partitioner.
``state_dtype`` lets the huge-MoE configs trade moment precision for HBM
(recorded per-config in EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(
    params,
    grads,
    state: AdamWState,
    lr: jnp.ndarray,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12)) if grad_clip else 1.0
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1 - b1) * g
        v_new = b2 * v32 + (1 - b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # Decoupled weight decay on matrices only (ndim >= 2), like the
        # standard LLM recipe (no decay on norms/biases/scalars).
        wd = weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        AdamWState(step=step, mu=new_mu, nu=new_nu),
        {"grad_norm": gnorm},
    )
