"""deepseek-v2-236b [moe] — arXiv:2405.04434 (hf: deepseek-ai/DeepSeek-V2).

60L, d_model 5120, 128 heads, MLA (kv_lora 512, q_lora 1536, nope 128, rope 64,
v 128), MoE: 160 routed experts top-6 + 2 shared, expert d_ff 1536, softmax
router; 1 leading dense layer with d_ff 12288; vocab 102400.
"""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=12288,
    vocab_size=102400,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, d_expert=1536, num_shared=2,
                  router="softmax", num_dense_layers=1, dense_d_ff=12288),
    rope_theta=10000.0,
)

SMOKE = CONFIG.with_overrides(
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, num_shared=2,
                  router="softmax", num_dense_layers=1, dense_d_ff=128,
                  capacity_factor=2.0),
    q_block=16,
    k_block=16,
)
