"""glm4-9b [dense] — hf: THUDM/glm-4-9b.

40L, d_model 4096, 32 heads GQA kv=2, d_ff 13696, vocab 151552, RoPE.
(Partial-rotary from the HF config is simplified to full rotary; noted in
DESIGN.md.)
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=10000.0,
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, q_block=16, k_block=16,
)
