"""Architecture registry: ``--arch <id>`` → exact published config.

Every assigned architecture has a full CONFIG (the published figures) and a
SMOKE config (same family, reduced width/depth) used by CPU tests.  The full
configs are only ever lowered via ShapeDtypeStructs in the dry-run.
"""

from importlib import import_module
from typing import Dict

from .base import (  # noqa: F401
    MeshConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    RunConfig,
    SHAPES,
    ShapeConfig,
    XLSTMConfig,
)

_MODULES: Dict[str, str] = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "glm4-9b": "glm4_9b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "llama3-8b": "llama3_8b",
    "llama3.2-1b": "llama32_1b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-76b": "internvl2_76b",
    "hubert-xlarge": "hubert_xlarge",
    "xlstm-1.3b": "xlstm_13b",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG


def shape_cells(arch: str):
    """The (shape, reason-if-skipped) cells assigned to this arch."""
    cfg = get_config(arch)
    cells = []
    for name, shp in SHAPES.items():
        skip = None
        if shp.kind == "decode" and not cfg.causal:
            skip = "encoder-only architecture has no autoregressive decode"
        elif name == "long_500k" and cfg.family not in ("hybrid", "ssm"):
            skip = "full quadratic attention; 512k dense attention infeasible"
        cells.append((shp, skip))
    return cells
