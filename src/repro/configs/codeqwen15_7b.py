"""codeqwen1.5-7b [dense] — hf: Qwen/CodeQwen1.5-7B.

32L, d_model 4096, 32 heads MHA (kv=32), d_ff 13440, vocab 92416,
rope_theta 1e6 (64k context).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=256, q_block=16, k_block=16,
)
