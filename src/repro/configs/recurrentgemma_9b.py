"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin).

38L in a 1:2 attention:recurrence pattern ("rec","rec","attn"); d_model 4096,
16 heads MQA (kv=1) with sliding window 2048 on attention layers; d_ff 12288;
RG-LRU recurrence; vocab 256000; tied embeddings.  (lru width = d_model here;
official uses a narrower LRU — noted in DESIGN.md.)
"""

from .base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    rglru=RGLRUConfig(width=0, conv_width=4, c=8.0),
    window=2048,
    tie_embeddings=True,
    rope_theta=10000.0,
)

SMOKE = CONFIG.with_overrides(
    num_layers=5,  # one scanned super-block + 2 tail layers
    d_model=64, num_heads=4, num_kv_heads=1, d_ff=128, vocab_size=256,
    window=16, q_block=16, k_block=16,
)
