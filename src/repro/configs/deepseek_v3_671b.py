"""deepseek-v3-671b [moe] — arXiv:2412.19437 (hf: deepseek-ai/DeepSeek-V3).

61L, d_model 7168, 128 heads, MLA (kv_lora 512, q_lora 1536), MoE: 256 routed
top-8 + 1 shared, expert d_ff 2048, sigmoid router with renorm; 3 leading
dense layers d_ff 18432; vocab 129280; multi-token prediction (1 depth).
"""

from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                  router="sigmoid", num_dense_layers=3, dense_d_ff=18432),
    mtp_depth=1,
    rope_theta=10000.0,
)

SMOKE = CONFIG.with_overrides(
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                  nope_head_dim=16, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, num_shared=1,
                  router="sigmoid", num_dense_layers=1, dense_d_ff=128,
                  capacity_factor=2.0),
    q_block=16,
    k_block=16,
)
