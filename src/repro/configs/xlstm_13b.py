"""xlstm-1.3b [ssm] — arXiv:2405.04517.

48 blocks, d_model 2048, 4 heads, mLSTM:sLSTM 7:1 pattern, no separate FFN in
mLSTM blocks (proj_factor 2 up-projection built in; sLSTM blocks carry a 4/3
gated FFN), vocab 50304.
"""

from .base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attention="none",
    block_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm=XLSTMConfig(proj_factor_m=2.0, proj_factor_s=4 / 3, chunk=64),
)

SMOKE = CONFIG.with_overrides(
    num_layers=4,
    d_model=64, num_heads=4, num_kv_heads=4, vocab_size=256,
    block_pattern=("mlstm", "slstm"),
    xlstm=XLSTMConfig(chunk=8),
)
