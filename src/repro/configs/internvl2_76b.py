"""internvl2-76b [vlm] — arXiv:2404.16821 (InternViT-6B + Llama-3-70B backbone).

LM backbone only (per assignment): 80L, d_model 8192, 64 heads GQA kv=8,
d_ff 28672, vocab 128256.  The vision frontend is a STUB: ``input_specs()``
provides 256 precomputed patch embeddings per image at d_model, prepended to
the text sequence.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision",
    frontend_tokens=256,
    rope_theta=500000.0,
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
    vocab_size=256, frontend_tokens=4, q_block=16, k_block=16,
)
