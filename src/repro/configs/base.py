"""Config dataclasses: model architecture, shapes, mesh, run options."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    num_shared: int = 0           # shared ("always-on") experts
    capacity_factor: float = 1.25
    aux_loss_weight: float = 1e-3
    router: str = "softmax"       # softmax (v2) | sigmoid (v3)
    num_dense_layers: int = 1     # leading dense-FFN layers before MoE starts
    dense_d_ff: int = 0           # FFN dim of the leading dense layers
    # Dispatch groups: capacity and sorting are per-group (per data-shard at
    # scale), matching EP-system semantics and bounding the capacity buffer.
    # The launcher overrides this to the mesh's data-axis size.
    groups: int = 1
    # Expert weight sharding (§Perf iteration target):
    #   fsdp_d — experts on `model`, d_model dim FSDP on `data` (baseline:
    #            contraction dim sharded ⇒ weights all-gather every layer)
    #   fsdp_f — experts on `model`, FFN dim FSDP on `data` (contraction dim
    #            whole ⇒ no weight movement; grads reduce-scatter naturally)
    #   ep2d   — experts on `data`×`model` jointly (pure EP at E ≥ chips:
    #            weights never move; tokens all-to-all to expert owners)
    expert_sharding: str = "fsdp_d"


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536      # 0 → no query compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class RGLRUConfig:
    width: int = 0               # 0 → d_model
    conv_width: int = 4
    c: float = 8.0               # a_t = a^(c·r_t)


@dataclass(frozen=True)
class XLSTMConfig:
    proj_factor_m: float = 2.0   # mLSTM up-projection
    proj_factor_s: float = 4 / 3  # sLSTM FFN
    chunk: int = 64              # chunk size for the parallel mLSTM form


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | vlm | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 → d_model // num_heads
    attention: str = "gqa"       # gqa | mla | none
    # Per-layer block pattern, cycled: e.g. ("rec","rec","attn") for 1:2
    # hybrids, ("mlstm",)*7 + ("slstm",) for xLSTM, ("attn",) for transformers.
    block_pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    rope_theta: float = 500000.0
    act: str = "swiglu"          # swiglu | gelu
    causal: bool = True          # False → encoder-only (no decode path)
    tie_embeddings: bool = False
    window: int = 0              # sliding-window size for "attn" when >0...
    mtp_depth: int = 0           # DeepSeek-V3 multi-token prediction heads
    frontend: str = "none"       # none | audio | vision (STUB embeddings)
    frontend_tokens: int = 256   # prepended embedding tokens for vlm
    dtype: str = "bfloat16"
    remat: str = "block"         # none | block | full
    # attention chunking (XLA online-softmax path; Pallas kernel on TPU)
    q_block: int = 512
    k_block: int = 1024
    use_pallas: bool = False     # TPU deployment flag (CPU container: False)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def scan_unit(self) -> Tuple[int, int]:
        """(#scanned super-blocks, #unrolled leftover layers)."""
        p = len(self.block_pattern)
        return self.num_layers // p, self.num_layers % p

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class RunConfig:
    """Trainer/server options."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer_state_dtype: str = "float32"
    sync_mode: str = "sync"      # none | sync | local (pod-axis schedule)
    sync_budget: int = 1
    compress_int8: bool = False
    microbatches: int = 1        # gradient accumulation
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
