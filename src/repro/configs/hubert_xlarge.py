"""hubert-xlarge [audio] — arXiv:2106.07447.

Encoder-only (no decode path): 48L, d_model 1280, 16 heads (kv=16), d_ff 5120
GELU, vocab 504 (masked-prediction codebook).  The audio frontend (conv
feature extractor) is a STUB: ``input_specs()`` provides precomputed frame
embeddings at d_model.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    act="gelu",
    causal=False,
    frontend="audio",
    rope_theta=10000.0,
)

SMOKE = CONFIG.with_overrides(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
    vocab_size=64, q_block=16, k_block=16,
)
