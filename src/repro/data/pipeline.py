"""Deterministic synthetic LM data pipeline.

Design goals (the ones that matter at 1000-node scale):

* **Stateless addressing** — batch ``i`` is a pure function of ``(seed, i)``,
  so restart-from-checkpoint resumes the stream exactly (no iterator state to
  persist) and elastic re-sharding is trivial: a host owns rows
  ``[host * rows_per_host, ...)`` of the global batch regardless of history.
* **Per-host sharding** — each host materialises only its slice.
* **Learnable signal** — tokens follow a seeded first-order Markov chain, so
  the e2e example's loss decreases measurably within a few hundred steps
  (pure-uniform tokens would hide optimizer bugs).
* **Double-buffered prefetch** — a background thread keeps ``prefetch``
  batches ready (overlapping host data work with device compute).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


class SyntheticLMDataset:
    """Markov-chain token stream with stateless batch addressing."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 branching: int = 4):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        rng = np.random.default_rng(seed)
        V = cfg.vocab_size
        # Sparse deterministic transition table: each token can be followed by
        # `branching` successors → H(next|cur) = log2(branching) bits.
        self.successors = rng.integers(0, V, size=(V, branching), dtype=np.int32)

    def batch(self, index: int, host: int = 0, num_hosts: int = 1) -> Dict[str, np.ndarray]:
        """Global batch ``index``, restricted to this host's row slice."""
        cfg, shp = self.cfg, self.shape
        B, T = shp.global_batch, shp.seq_len
        assert B % num_hosts == 0, (B, num_hosts)
        rows = B // num_hosts
        rng = np.random.default_rng((self.seed, index, host))
        V = cfg.vocab_size
        stream = np.empty((rows, T + 1), np.int32)
        stream[:, 0] = rng.integers(0, V, size=rows)
        choices = rng.integers(0, self.successors.shape[1], size=(rows, T))
        for t in range(T):
            stream[:, t + 1] = self.successors[stream[:, t], choices[:, t]]
        batch: Dict[str, np.ndarray] = {}
        if cfg.frontend == "audio":
            batch["embeds"] = rng.standard_normal(
                (rows, T, cfg.d_model), dtype=np.float32
            ) * 0.02
            batch["labels"] = stream[:, :T]
        elif cfg.frontend == "vision":
            n_txt = T - cfg.frontend_tokens
            batch["embeds"] = rng.standard_normal(
                (rows, cfg.frontend_tokens, cfg.d_model), dtype=np.float32
            ) * 0.02
            batch["tokens"] = stream[:, :n_txt]
            batch["labels"] = stream[:, 1 : n_txt + 1]
        else:
            batch["tokens"] = stream[:, :T]
            batch["labels"] = stream[:, 1 : T + 1]
        return batch


def make_batch_iterator(
    dataset: SyntheticLMDataset,
    start_step: int = 0,
    host: int = 0,
    num_hosts: int = 1,
    prefetch: int = 2,
) -> Iterator[Dict[str, np.ndarray]]:
    """Background-thread prefetching iterator starting at ``start_step``."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        i = start_step
        while not stop.is_set():
            b = dataset.batch(i, host, num_hosts)
            while not stop.is_set():
                try:
                    q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue
            i += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
