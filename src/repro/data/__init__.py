"""Data pipeline: deterministic synthetic token streams, sharded per host."""

from .pipeline import SyntheticLMDataset, make_batch_iterator  # noqa: F401
