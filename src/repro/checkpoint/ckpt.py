"""Checkpointing: async, atomic, integrity-checked, mesh-agnostic.

Fault-tolerance properties (the large-scale requirements):

* **Atomicity** — writes go to ``step_<n>.tmp`` then ``os.replace`` to the
  final name; a crash mid-write never corrupts the latest checkpoint.
* **Integrity** — a manifest records per-array checksums (crc via zlib) and
  shapes; ``load_checkpoint`` verifies before restoring and falls back to the
  previous step on mismatch (torn-write recovery).
* **Mesh-agnostic restore** — arrays are saved unsharded (gathered) with
  their pytree paths; restore re-shards onto whatever mesh/sharding the new
  job uses (elastic scaling: a 512-chip checkpoint restores onto 256 chips).
* **Writer election** — in multi-host jobs exactly one host writes; election
  runs on the paper's ALock via :class:`repro.coord.CoordinationService`
  (the owning host pays zero fabric ops — the asymmetric design's point).
* **Async** — the device→host gather happens on the caller thread
  (cheap), serialization+fsync on a background thread, so the train loop
  stalls only for the gather.
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


_ML_DTYPES = ("bfloat16", "float8_e4m3fn", "float8_e5m2")


def _encode(arr: np.ndarray):
    """npz cannot store ml_dtypes (bf16 → void); view as uint bits + tag."""
    if arr.dtype.name in _ML_DTYPES:
        bits = np.uint8 if arr.dtype.itemsize == 1 else np.uint16
        return arr.view(bits), arr.dtype.name
    return arr, str(arr.dtype)


def _decode(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _ML_DTYPES:
        import ml_dtypes

        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _keystr_simple(k) -> str:
    """``jax.tree_util.keystr(..., simple=True)`` with a jax-0.4.x fallback
    (the ``simple`` kwarg is newer than the pinned CI jax)."""
    try:
        return jax.tree_util.keystr((k,), simple=True)
    except TypeError:
        for attr in ("key", "idx", "name"):
            if hasattr(k, attr):
                return str(getattr(k, attr))
        return str(k)


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_keystr_simple(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(
    directory: str,
    step: int,
    state: Any,
    *,
    extra: Optional[Dict] = None,
    _async: bool = False,
) -> threading.Thread | None:
    """Write ``state`` (pytree of arrays) for ``step``. Returns the writer
    thread when ``_async`` (join it before exiting the process)."""
    os.makedirs(directory, exist_ok=True)
    raw = _flatten_with_paths(state)
    flat, dtypes = {}, {}
    for k, v in raw.items():
        enc, name = _encode(v)
        flat[k] = enc
        dtypes[k] = name
    manifest = {
        "step": int(step),
        "extra": extra or {},
        "arrays": {
            k: {
                "shape": list(v.shape),
                "dtype": dtypes[k],
                "crc": zlib.crc32(np.ascontiguousarray(v).tobytes()),
            }
            for k, v in flat.items()
        },
    }

    def write():
        tmp = os.path.join(directory, f"step_{step:08d}.tmp.npz")
        final = os.path.join(directory, f"step_{step:08d}.npz")
        mtmp = os.path.join(directory, f"step_{step:08d}.tmp.json")
        mfinal = os.path.join(directory, f"step_{step:08d}.json")
        np.savez(tmp, **flat)
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        os.replace(mtmp, mfinal)

    if _async:
        t = threading.Thread(target=write, daemon=False)
        t.start()
        return t
    write()
    return None


def _available_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.endswith(".json") and name.startswith("step_") and ".tmp" not in name:
            steps.append(int(name[len("step_"):-len(".json")]))
    return sorted(steps)


def load_checkpoint(
    directory: str,
    like: Any,
    *,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, int, Dict]:
    """Restore the newest (or given) verified checkpoint.

    ``like`` provides the target pytree structure; ``shardings`` (optional
    matching pytree of NamedSharding) re-shards on load — the elastic path.
    Falls back to older steps if integrity verification fails.
    """
    steps = _available_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    for s in reversed(steps):
        try:
            with open(os.path.join(directory, f"step_{s:08d}.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(directory, f"step_{s:08d}.npz"))
            flat = {}
            for k, meta in manifest["arrays"].items():
                arr = data[k]
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc"]:
                    raise IOError(f"checksum mismatch for {k} at step {s}")
                flat[k] = _decode(arr, meta["dtype"])
        except Exception:
            if s == steps[0]:
                raise
            continue  # torn/corrupt: fall back to the previous step
        # Rebuild the pytree in `like`'s structure.
        paths = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in paths[0]:
            key = "/".join(_keystr_simple(k) for k in path)
            if key not in flat:
                raise KeyError(f"checkpoint missing array {key}")
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}"
                )
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(paths[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, sh: jax.device_put(a, sh), tree, shardings
            )
        return tree, s, manifest.get("extra", {})
    raise IOError("no verifiable checkpoint found")


class CheckpointManager:
    """Periodic async checkpoints with writer election + retention."""

    def __init__(
        self,
        directory: str,
        every: int = 200,
        keep: int = 3,
        svc=None,            # repro.coord.CoordinationService
        host: int = 0,
        writer_home: int = 0,
    ):
        self.directory = directory
        self.every = max(1, every)
        self.keep = keep
        self.svc = svc
        self.host = host
        self.writer_home = writer_home
        self._proc = svc.host_process(host) if svc is not None else None
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, state, extra: Optional[Dict] = None) -> bool:
        if step % self.every != 0:
            return False
        if self.svc is not None:
            # Exactly one host wins the epoch election (paper's ALock inside).
            if not self.svc.elect("ckpt-writer", self._proc, epoch=step,
                                  home_host=self.writer_home):
                return False
        self.wait()  # never two in-flight writes
        host_state = jax.tree.map(np.asarray, state)  # device→host gather
        self._pending = save_checkpoint(
            self.directory, step, host_state, extra=extra, _async=True
        )
        self._gc()
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        # One write is in flight (not yet on disk): keep `keep - 1` of the
        # existing checkpoints so `keep` remain once it lands.
        if not self.keep:
            return
        steps = _available_steps(self.directory)
        keep_existing = max(self.keep - 1, 0)
        doomed = steps[:-keep_existing] if keep_existing else steps
        for s in doomed:
            for suffix in (".npz", ".json"):
                try:
                    os.remove(os.path.join(self.directory, f"step_{s:08d}{suffix}"))
                except OSError:
                    pass
