"""Fault-tolerant checkpointing with ALock-elected writers."""

from .ckpt import CheckpointManager, load_checkpoint, save_checkpoint  # noqa: F401
