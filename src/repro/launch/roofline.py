"""Roofline report: three terms per (arch × shape × mesh) from dry-run JSONs.

    compute    = flops_per_device / peak_flops          (197 TFLOP/s bf16)
    memory     = hbm_bytes_per_device / hbm_bw          (819 GB/s)
    collective = ici_wire/ici_bw + dcn_wire/dcn_bw      (50 GB/s ICI,
                                                         ~6.25 GB/s DCN/chip)

All inputs are trip-count-corrected per-device numbers from
``launch/hloparse.py`` over the compiled dry-run artifact.  The report adds:

* the dominant term (the bottleneck the §Perf loop iterates on),
* MODEL_FLOPS / HLO_FLOPS — the useful-compute ratio (catches remat and
  masked-block waste),
* roofline fraction = compute_term / max(all terms) — how close the cell
  would run to the compute roofline if perfectly overlapped,
* a one-line "what would move the dominant term" hint.

Usage: PYTHONPATH=src python -m repro.launch.roofline --results results/
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from ..core.asymmetry import TPUv5e

HW = TPUv5e()


def roofline_terms(rec: Dict) -> Dict:
    p = rec["parsed"]
    chips = rec["num_devices"]
    compute_s = p["flops_per_device"] / HW.peak_flops_bf16
    memory_s = p["hbm_bytes_per_device"] / HW.hbm_bw
    coll_s = (
        p["ici_wire_bytes_per_chip"] / HW.ici_bw_per_link
        + p["dcn_wire_bytes_per_chip"] / HW.dcn_bw_per_chip
    )
    model_per_dev = rec["model_flops"] / chips
    useful = model_per_dev / max(p["flops_per_device"], 1.0)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = compute_s / bound if bound > 0 else 0.0
    hints = {
        "compute": "reduce recompute/masked-block waste (remat policy, "
                   "two-phase causal blocking); raise arithmetic intensity",
        "memory": "cut activation traffic: larger fusion, microbatching, "
                  "chunked loss, flash tiles sized to VMEM",
        "collective": "reshard to shrink wire bytes: sequence-parallel "
                      "norms, cohort (hierarchical) exchange, int8 DCN hop, "
                      "overlap via async collectives",
    }
    return {
        "cell": rec["cell"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "roofline_fraction": frac,
        "useful_flops_ratio": useful,
        "model_flops_per_dev": model_per_dev,
        "hlo_flops_per_dev": p["flops_per_device"],
        "peak_bytes_per_dev": rec["memory_analysis"]["peak_estimate_bytes_per_device"],
        "fits_hbm": rec["memory_analysis"]["peak_estimate_bytes_per_device"]
        <= HW.hbm_bytes,
        "hint": hints[dominant],
    }


def load_all(results_dir: str) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        rec = json.load(open(path))
        if "skipped" in rec:
            out.append({"cell": rec["cell"], "skipped": rec["skipped"]})
            continue
        out.append(roofline_terms(rec))
    return out


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'cell':58s} {'compute':>9s} {'memory':>9s} {'coll':>9s} "
           f"{'dom':>10s} {'roofl%':>7s} {'useful%':>8s} {'HBM GB':>7s} fits")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skipped" in r:
            lines.append(f"{r['cell']:58s} SKIP: {r['skipped']}")
            continue
        lines.append(
            f"{r['cell']:58s} {r['compute_s']:9.3f} {r['memory_s']:9.3f} "
            f"{r['collective_s']:9.3f} {r['dominant']:>10s} "
            f"{100 * r['roofline_fraction']:6.1f}% "
            f"{100 * r['useful_flops_ratio']:7.1f}% "
            f"{r['peak_bytes_per_dev'] / 1e9:7.1f} "
            f"{'y' if r['fits_hbm'] else 'N'}"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load_all(args.results)
    print(format_table(rows))
    if args.json_out:
        json.dump(rows, open(args.json_out, "w"), indent=1)


if __name__ == "__main__":
    main()
