"""Launchers: production meshes, the multi-pod dry-run, train and serve."""
