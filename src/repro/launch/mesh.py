"""Production mesh construction.

A *function*, not a module-level constant — importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any jax
initialisation; tests must see one device).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

from ..compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None) -> Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    if devices is None:
        devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, found {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before importing jax"
        )
    return _compat_make_mesh(shape, axes, devices=devices)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> Mesh:
    """Arbitrary mesh for tests/examples (CPU-scale)."""
    return _compat_make_mesh(shape, axes, devices=devices)
