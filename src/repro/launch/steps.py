"""Step builders shared by the trainer, the server and the dry-run.

The pod axis — the slow fabric, the paper's "remote class" — is expressed
with a *leading pod dimension* (vmap-over-pod) rather than a manual shard_map
around the whole model: XLA's SPMD partitioner mis-handles gathers inside
manual subgroups, and the vmap formulation lowers to exactly the cohort
schedule anyway:

* per-pod gradients come out of ``vmap`` with a leading ``[P, ...]`` dim
  sharded over ``pod``;
* within each pod, GSPMD reduce-scatters gradients across ``data`` (FSDP) —
  the *cohort election*: each chip ends up leader of a 1/|data| fragment;
* the cross-pod exchange is the dim-0 mean — one collective over ``pod``
  carrying only fragments (the elected leaders' 2-party protocol), optionally
  int8+error-feedback via a collectives-only shard_map;
* the FSDP all-gather redistributes — the cohort hand-off.

Modes (``RunConfig.sync_mode``):
  flat  — paper-baseline: batch sharded over (pod×data) jointly; XLA emits one
          logical all-reduce spanning the DCN.
  sync  — cohort schedule above; numerically identical to flat.
  local — budgeted: per-pod parameters + optimizer (leading pod dim in the
          train state); pods reconcile by parameter averaging every
          ``sync_budget`` steps (bounded staleness, straggler mitigation —
          the paper's fairness budget).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ModelConfig, RunConfig, ShapeConfig
from ..models import Model
from ..models.layers import activation_rules
from ..optim import adamw_update, adamw_init, cosine_schedule
from ..optim.adamw import AdamWState
from ..models import input_specs
from ..sharding import ACT_RULES, batch_pspec, cache_pspecs, param_pspecs
from ..sharding.rules import fitted_shardings


# ---------------------------------------------------------------------------
# int8 + error-feedback cross-pod exchange (collectives-only shard_map: safe)
# ---------------------------------------------------------------------------
def _int8_pod_mean(grads_p, ef_p, mesh: Mesh):
    """Mean over the leading pod dim with int8 wire format + error feedback.

    grads_p/ef_p leaves: [P, ...] sharded P('pod', ...). Returns
    (mean [...] replicated over pod, new_ef [P, ...]).
    """
    from ..core.cohort import _ef_quantize

    def body(gp, ep):
        # local block: leading dim 1 (this pod's slice)
        g, e = gp[0], ep[0]
        q, scale, new_e = _ef_quantize(g, e)
        qs = jax.lax.all_gather(q, "pod", axis=0)          # int8 on the wire
        ss = jax.lax.all_gather(scale, "pod", axis=0)
        npods = qs.shape[0]
        deq = qs.astype(g.dtype) * ss.reshape((npods,) + (1,) * g.ndim).astype(g.dtype)
        return jnp.sum(deq, axis=0) / npods, new_e[None]

    def exchange(gs, es):
        flat_g, tdef = jax.tree.flatten(gs)
        flat_e, _ = jax.tree.flatten(es)
        outs = [body(g, e) for g, e in zip(flat_g, flat_e)]
        return (
            jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]),
        )

    # Fully manual: the body is collectives-only (pod all-gather + elementwise
    # quantize), and inputs are replicated over data/model, so claiming every
    # axis is equivalent — and partial-manual islands trip XLA partitioner
    # bugs on older jax (same reason as the MoE island, see models/moe.py).
    fn = shard_map(
        exchange,
        mesh=mesh,
        in_specs=(P("pod"), P("pod")),
        out_specs=(P(), P("pod")),
        axis_names=frozenset(mesh.axis_names),
        check_vma=False,
    )
    return fn(grads_p, ef_p)


def _pod_split(batch, npods: int):
    """[B, ...] → [P, B/P, ...] with dim0 on ``pod`` and dim1 on ``data``."""
    def one(a):
        a = a.reshape(npods, a.shape[0] // npods, *a.shape[1:])
        return jax.lax.with_sharding_constraint(
            a, P("pod", "data", *([None] * (a.ndim - 2)))
        )
    return jax.tree.map(one, batch)


# ---------------------------------------------------------------------------
# Train state
# ---------------------------------------------------------------------------
def train_state_specs(model: Model, run: RunConfig, npods: int = 1):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the full train state.

    ``local`` mode keeps per-pod parameters/optimizer: every leaf gets a
    leading pod dim sharded over ``pod``.
    """
    pspecs = param_pspecs(model.specs())
    pshapes = model.param_shapes()
    sdtype = jnp.float32 if run.optimizer_state_dtype == "float32" else jnp.bfloat16
    opt_shapes = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "mu": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, sdtype), pshapes),
        "nu": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, sdtype), pshapes),
    }
    opt_specs = {"step": P(), "mu": pspecs, "nu": pspecs}
    shapes = {"params": pshapes, "opt": opt_shapes}
    specs = {"params": pspecs, "opt": opt_specs}
    if run.sync_mode == "local" and npods > 1:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((npods, *s.shape), s.dtype),
            shapes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        specs = jax.tree.map(
            lambda ps: P("pod", *ps), specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        shapes["opt"]["step"] = jax.ShapeDtypeStruct((npods,), jnp.int32)
    if run.compress_int8 and npods > 1 and run.sync_mode == "sync":
        shapes["ef"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((npods, *s.shape), jnp.float32),
            pshapes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        specs["ef"] = jax.tree.map(
            lambda ps: P("pod", *ps), pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
    return shapes, specs


def init_train_state(model: Model, run: RunConfig, rng, npods: int = 1) -> Dict:
    params = model.init(rng)
    sdtype = jnp.float32 if run.optimizer_state_dtype == "float32" else jnp.bfloat16
    opt = adamw_init(params, sdtype)
    state = {"params": params, "opt": {"step": opt.step, "mu": opt.mu, "nu": opt.nu}}
    if run.sync_mode == "local" and npods > 1:
        state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (npods, *a.shape)).copy(), state
        )
    if run.compress_int8 and npods > 1 and run.sync_mode == "sync":
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros((npods, *p.shape), jnp.float32), params
        )
    return state


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------
def _grad_fn(loss_fn, microbatches: int, batch_axes=("data",)):
    """value_and_grad with optional gradient accumulation over microbatches.

    Accumulation bounds live activation memory to one microbatch's worth —
    the memory-roofline knob (grads accumulate in fp32, sharded like params).
    ``batch_axes`` keeps the row sharding (incl. ``pod`` in flat multi-pod
    mode) across the microbatch reshape.
    """
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    if microbatches <= 1:
        return vg

    def accumulated(params, batch):
        def split(a):
            a = a.reshape(microbatches, a.shape[0] // microbatches, *a.shape[1:])
            return jax.lax.with_sharding_constraint(
                a, P(None, batch_axes, *([None] * (a.ndim - 2)))
            )

        bm = jax.tree.map(split, batch)

        def mb(carry, mbatch):
            gacc, lacc, macc = carry
            (l, m), g = vg(params, mbatch)
            gacc = jax.tree.map(
                lambda ga, gi: ga + gi.astype(jnp.float32), gacc, g
            )
            macc = jax.tree.map(lambda a, b: a + b, macc, m)
            return (gacc, lacc + l, macc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        # First microbatch outside the scan initialises the accumulators.
        (l_first, m_first), g_first = vg(
            params, jax.tree.map(lambda a: a[0], bm)
        )
        gacc = jax.tree.map(lambda ga, gi: ga + gi.astype(jnp.float32), g0, g_first)
        rest = jax.tree.map(lambda a: a[1:], bm)
        (gacc, lsum, msum), _ = jax.lax.scan(
            mb, (gacc, l_first, m_first), rest
        )
        n = float(microbatches)
        grads = jax.tree.map(lambda g: (g / n), gacc)
        return (lsum / n, jax.tree.map(lambda m: m / n, msum)), grads

    return accumulated


def _adamw_piece(run: RunConfig, params, grads, opt_dict):
    opt = AdamWState(opt_dict["step"], opt_dict["mu"], opt_dict["nu"])
    lr = cosine_schedule(
        opt.step, peak_lr=run.learning_rate, warmup=run.warmup_steps,
        total=run.total_steps,
    )
    params, opt, om = adamw_update(
        params, grads, opt, lr,
        weight_decay=run.weight_decay, grad_clip=run.grad_clip,
    )
    return params, {"step": opt.step, "mu": opt.mu, "nu": opt.nu}, om


def _act_rules(multi_pod: bool, pod_in_batch: bool):
    """Activation rules; the batch dim carries (pod, data) whenever the pod
    axis is NOT peeled off by vmap (flat-mode train, all serving) — else the
    first with_sharding_constraint silently replicates work across pods."""
    rules = dict(ACT_RULES)
    if multi_pod and pod_in_batch:
        rules["batch"] = ("pod", "data")
    return rules


def build_train_step(
    model: Model,
    run: RunConfig,
    mesh: Mesh,
    shape: ShapeConfig,
) -> Tuple[Callable, Any, Any, Any]:
    """Returns (jitted step, state shapes, state shardings, batch shardings)."""
    cfg = model.cfg
    multi_pod = "pod" in mesh.shape
    npods = mesh.shape.get("pod", 1) if hasattr(mesh.shape, "get") else (
        dict(mesh.shape).get("pod", 1)
    )
    state_shapes, state_pspecs = train_state_specs(model, run, npods)
    mode = run.sync_mode if multi_pod else "flat"

    def loss_fn(p, b):
        return model.loss(p, b)

    # In sync/local modes the pod dim is peeled off by vmap before grad_fn
    # sees the batch; in flat multi-pod mode rows stay (pod×data)-sharded.
    _gf_axes = (
        ("pod", "data")
        if (multi_pod and run.sync_mode in ("flat", "none"))
        else ("data",)
    )
    grad_fn = _grad_fn(loss_fn, run.microbatches, _gf_axes)
    rules = _act_rules(multi_pod, run.sync_mode in ("flat", "none"))

    def step(state, batch):
        with activation_rules(rules):
            if mode in ("flat", "none") or not multi_pod:
                (loss, metrics), grads = grad_fn(state["params"], batch)
                params, opt, om = _adamw_piece(run, state["params"], grads,
                                               state["opt"])
                new_state = {"params": params, "opt": opt}
                if "ef" in state:
                    new_state["ef"] = state["ef"]
            elif mode == "sync":
                bp = _pod_split(batch, npods)
                (loss_p, metrics_p), grads_p = jax.vmap(
                    grad_fn, in_axes=(None, 0),
                )(state["params"], bp)
                loss = jnp.mean(loss_p)
                metrics = jax.tree.map(jnp.mean, metrics_p)
                new_state = {}
                if run.compress_int8:
                    grads, new_ef = _int8_pod_mean(grads_p, state["ef"], mesh)
                    new_state["ef"] = new_ef
                else:
                    # The cohort exchange: fragment mean over the pod dim.
                    grads = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads_p)
                params, opt, om = _adamw_piece(run, state["params"], grads,
                                               state["opt"])
                new_state.update({"params": params, "opt": opt})
            elif mode == "local":
                bp = _pod_split(batch, npods)
                (loss_p, metrics_p), grads_p = jax.vmap(
                    grad_fn, in_axes=(0, 0),
                )(state["params"], bp)
                loss = jnp.mean(loss_p)
                metrics = jax.tree.map(jnp.mean, metrics_p)
                params_p, opt_p, om = jax.vmap(
                    functools.partial(_adamw_piece, run)
                )(state["params"], grads_p, state["opt"])
                om = jax.tree.map(jnp.mean, om)
                # Budgeted reconcile: pods average every `sync_budget` steps.
                do_sync = (opt_p["step"][0] % run.sync_budget) == 0
                params_p = jax.lax.cond(
                    do_sync,
                    lambda ps: jax.tree.map(
                        lambda a: jnp.broadcast_to(
                            jnp.mean(a, axis=0, keepdims=True), a.shape
                        ),
                        ps,
                    ),
                    lambda ps: ps,
                    params_p,
                )
                new_state = {"params": params_p, "opt": opt_p}
            else:
                raise ValueError(mode)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
            return new_state, metrics

    batch_axes = ("pod", "data") if multi_pod else ("data",)
    bspecs = batch_pspec(cfg, shape, batch_axes=batch_axes)
    bshapes = input_specs(cfg, shape)
    state_sh = fitted_shardings(state_shapes, state_pspecs, mesh)
    batch_sh = fitted_shardings(bshapes, bspecs, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    return jitted, state_shapes, state_sh, batch_sh


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------
def build_encode_step(model: Model, mesh: Mesh, shape: ShapeConfig):
    """Encoder-only forward → logits (hubert 'prefill')."""
    cfg = model.cfg
    multi_pod = "pod" in mesh.shape
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    pspecs = param_pspecs(model.specs())
    bspecs = batch_pspec(cfg, shape, batch_axes=batch_axes)

    rules = _act_rules(multi_pod, True)

    def encode(params, batch):
        with activation_rules(rules):
            h, _ = model.forward(params, batch)
            return model._logits(params, h)

    param_sh = fitted_shardings(model.param_shapes(), pspecs, mesh)
    batch_sh = fitted_shardings(input_specs(cfg, shape), bspecs, mesh)
    return jax.jit(encode, in_shardings=(param_sh, batch_sh))


def build_prefill_step(model: Model, mesh: Mesh, shape: ShapeConfig, max_len: int):
    cfg = model.cfg
    multi_pod = "pod" in mesh.shape
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    pspecs = param_pspecs(model.specs())
    bspecs = batch_pspec(cfg, shape, batch_axes=batch_axes)
    cache_spec = model.cache(shape.global_batch, max_len, as_spec=True)
    cspecs = cache_pspecs(cache_spec, batch_axes=batch_axes, mesh=mesh)

    rules = _act_rules(multi_pod, True)

    def prefill(params, batch):
        with activation_rules(rules):
            return model.prefill(params, batch, max_len)

    param_sh = fitted_shardings(model.param_shapes(), pspecs, mesh)
    batch_sh = fitted_shardings(input_specs(cfg, shape), bspecs, mesh)
    cache_sh = fitted_shardings(cache_spec, cspecs, mesh)
    jitted = jax.jit(
        prefill,
        in_shardings=(param_sh, batch_sh),
        out_shardings=(None, cache_sh),
    )
    return jitted, cache_spec, (param_sh, batch_sh, cache_sh)


def build_decode_step(model: Model, mesh: Mesh, shape: ShapeConfig, max_len: int):
    """serve_step: one new token for every sequence against a seq_len cache."""
    cfg = model.cfg
    multi_pod = "pod" in mesh.shape
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    pspecs = param_pspecs(model.specs())
    cache_spec = model.cache(shape.global_batch, max_len, as_spec=True)
    cspecs = cache_pspecs(cache_spec, batch_axes=batch_axes, mesh=mesh)

    rules = _act_rules(multi_pod, True)

    def decode(params, caches, tokens):
        with activation_rules(rules):
            return model.decode_step(params, caches, tokens)

    param_sh = fitted_shardings(model.param_shapes(), pspecs, mesh)
    cache_sh = fitted_shardings(cache_spec, cspecs, mesh)
    tok_shape = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    tok_sh = fitted_shardings(tok_shape, P(batch_axes), mesh)
    jitted = jax.jit(
        decode,
        in_shardings=(param_sh, cache_sh, tok_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    return jitted, cache_spec, (param_sh, cache_sh, tok_sh)
