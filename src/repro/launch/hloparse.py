"""Trip-count-aware analysis of optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, but our models
scan over layers (and the online-softmax attention scans over KV blocks), so
FLOPs/bytes/collective counts must be multiplied by loop trip counts.  This
module parses ``compiled.as_text()`` into a computation graph and walks it
with multipliers:

* **flops** — ``dot`` ops: ``2 × |result| × contraction`` (operand shapes
  resolved through a per-computation symbol table); recursed into fusions,
  calls, conditionals (×1) and whiles (×trip count, parsed from the loop
  condition's comparison constant).
* **hbm bytes** — fusion-boundary traffic: for every non-control instruction
  at computation scope, output bytes + operand bytes (fusions count their
  boundary only — the "perfectly fused kernels" model of HBM traffic).
* **collectives** — kind, wire bytes/chip (bandwidth-optimal algorithm
  factors), group size, and whether any group crosses the pod boundary
  (device id // pod_size differs) — the ICI vs DCN split for the roofline.

Validated against ``cost_analysis`` on loop-free graphs and against hand
counts on scanned graphs (tests/test_hloparse.py).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\]{},.: ])*?)\s*([\w\-]+)\(")

_CONTROL_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy", "after-all", "partition-id", "replica-id",
    "iota", "get-dimension-size",
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shapes_of(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(type_str: str) -> int:
    return sum(
        _DTYPE_BYTES[dt] * int(np.prod(shape)) if shape else _DTYPE_BYTES[dt]
        for dt, shape in _shapes_of(type_str)
    )


@dataclass
class Collective:
    kind: str
    result_bytes: int
    group_size: int
    crosses_pod: bool
    count: float = 1.0  # multiplied by loop trip counts
    # CPU XLA rewrites bf16 dots to f32, so matmul partial-sums get reduced
    # pre-cast; TPU reduces them in bf16. f32 collectives tagged dot_general
    # count at half width in the tpu-normalized wire bytes.
    f32_dot_artifact: bool = False

    def wire_bytes_per_chip(self) -> float:
        n, b = self.group_size, self.result_bytes
        if n <= 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * (n - 1) / n * b
        if self.kind == "all-gather":
            return (n - 1) / n * b            # result is the gathered buffer
        if self.kind == "reduce-scatter":
            return (n - 1) * b                # result is the shard
        if self.kind == "all-to-all":
            return (n - 1) / n * b
        if self.kind == "collective-permute":
            return float(b)
        return 0.0


@dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> type str


def _parse_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = ""
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and (line.startswith("%") or line.startswith("ENTRY")):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rest = mi.group(1), mi.group(2)
        mo = _OPCODE_RE.match(rest)
        if not mo:
            continue
        type_str, opcode = mo.group(1).strip(), mo.group(2)
        # operands: %names inside the first (...) after the opcode
        paren = rest[mo.end() - 1 :]
        depth, end = 0, 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = re.findall(r"%([\w.\-]+)", paren[: end + 1])
        inst = Instr(name, opcode, type_str, operands, rest)
        cur.instrs.append(inst)
        cur.symbols[name] = type_str
    return comps, entry


def _attr(raw: str, key: str) -> Optional[str]:
    m = re.search(key + r"=\{([^}]*)\}", raw)
    return m.group(1) if m else None


def _called(raw: str) -> List[str]:
    out = []
    for key in ("calls", "body", "to_apply"):
        m = re.search(key + r"=%([\w.\-]+)", raw)
        if m:
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", raw)
    if m:
        out.extend(re.findall(r"%([\w.\-]+)", m.group(1)))
    return out


def _trip_count(cond: Computation) -> int:
    consts = [int(c) for c in re.findall(r"constant\((\d+)\)", "\n".join(
        i.raw for i in cond.instrs))]
    return max(consts) if consts else 1


def _parse_groups(raw: str, num_devices: int) -> List[List[int]]:
    m = re.search(r"replica_groups=\{\{(.*?)\}\}", raw)
    if m:
        return [
            [int(x) for x in g.split(",") if x.strip()]
            for g in m.group(1).split("},{")
        ]
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", raw
    )
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        base = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            base = base.transpose(perm)
        return base.reshape(g, s).tolist()
    # collective-permute: source_target_pairs
    if "source_target_pairs" in raw:
        seg = raw.split("source_target_pairs=", 1)[1]
        pairs = re.findall(r"\{(\d+),(\d+)\}", seg)
        return [[int(a), int(b)] for a, b in pairs]
    return []


@dataclass
class HLOStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0       # per-chip wire bytes (TPU-normalized)
    dcn_bytes: float = 0.0
    ici_bytes_raw: float = 0.0   # as measured on the CPU-backend HLO
    dcn_bytes_raw: float = 0.0
    collectives: List[Collective] = field(default_factory=list)

    def add_collective(self, c: Collective):
        self.collectives.append(c)
        wire = c.wire_bytes_per_chip() * c.count
        norm = wire * (0.5 if c.f32_dot_artifact else 1.0)
        if c.crosses_pod:
            self.dcn_bytes += norm
            self.dcn_bytes_raw += wire
        else:
            self.ici_bytes += norm
            self.ici_bytes_raw += wire

    def top_collectives(self, k: int = 8) -> List[dict]:
        """Largest collectives by total wire bytes (hillclimb targets)."""
        agg: Dict[tuple, dict] = {}
        for c in self.collectives:
            key = (c.kind, c.result_bytes, c.group_size, c.crosses_pod)
            a = agg.setdefault(
                key,
                {"kind": c.kind, "result_bytes": c.result_bytes,
                 "group_size": c.group_size, "crosses_pod": c.crosses_pod,
                 "count": 0.0, "wire_bytes": 0.0},
            )
            a["count"] += c.count
            a["wire_bytes"] += c.wire_bytes_per_chip() * c.count
        return sorted(agg.values(), key=lambda a: -a["wire_bytes"])[:k]


def _dot_flops(inst: Instr, comp: Computation) -> float:
    shapes = _shapes_of(inst.type_str)
    if not shapes:
        return 0.0
    result_elems = int(np.prod(shapes[0][1])) if shapes[0][1] else 1
    lhs_type = comp.symbols.get(inst.operands[0]) if inst.operands else None
    contract = 1
    cdims = _attr(inst.raw, "lhs_contracting_dims")
    if lhs_type and cdims is not None:
        lhs_shapes = _shapes_of(lhs_type)
        if lhs_shapes:
            lhs_shape = lhs_shapes[0][1]
            for d in cdims.split(","):
                d = d.strip()
                if d:
                    contract *= lhs_shape[int(d)]
    return 2.0 * result_elems * contract


def analyze(text: str, *, num_devices: int, pod_size: int) -> HLOStats:
    comps, entry = _parse_computations(text)
    stats = HLOStats()
    fusion_comps = set()
    for c in comps.values():
        for i in c.instrs:
            if i.opcode == "fusion":
                fusion_comps.update(_called(i.raw))

    def crosses(groups: List[List[int]]) -> bool:
        for g in groups:
            pods = {d // pod_size for d in g}
            if len(pods) > 1:
                return True
        return False

    visited_flops: Dict[str, float] = {}

    def comp_flops(name: str) -> float:
        """FLOPs of one execution of computation `name` (incl. nested)."""
        if name in visited_flops:
            return visited_flops[name]
        comp = comps.get(name)
        if comp is None:
            return 0.0
        total = 0.0
        for inst in comp.instrs:
            if inst.opcode in ("dot", "convolution"):
                total += _dot_flops(inst, comp)
            elif inst.opcode == "while":
                body = re.search(r"body=%([\w.\-]+)", inst.raw)
                cond = re.search(r"condition=%([\w.\-]+)", inst.raw)
                trips = _trip_count(comps[cond.group(1)]) if cond else 1
                if body:
                    total += trips * comp_flops(body.group(1))
            elif inst.opcode in ("fusion", "call", "conditional", "custom-call"):
                for sub in _called(inst.raw):
                    total += comp_flops(sub)
        visited_flops[name] = total
        return total

    def walk_bytes_colls(name: str, mult: float):
        comp = comps.get(name)
        if comp is None:
            return
        for inst in comp.instrs:
            if inst.opcode == "while":
                body = re.search(r"body=%([\w.\-]+)", inst.raw)
                cond = re.search(r"condition=%([\w.\-]+)", inst.raw)
                trips = _trip_count(comps[cond.group(1)]) if cond else 1
                if body:
                    walk_bytes_colls(body.group(1), mult * trips)
                continue
            if inst.opcode in ("call", "conditional"):
                for sub in _called(inst.raw):
                    walk_bytes_colls(sub, mult)
                continue
            base = inst.opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and "-done" not in inst.opcode:
                groups = _parse_groups(inst.raw, num_devices)
                gsize = len(groups[0]) if groups else num_devices
                is_f32_dot = (
                    "f32[" in inst.type_str
                    and "dot_general" in inst.raw
                    and base in ("all-reduce", "reduce-scatter")
                )
                stats.add_collective(
                    Collective(
                        kind=base,
                        result_bytes=_bytes_of(inst.type_str),
                        group_size=gsize if base != "collective-permute" else 2,
                        crosses_pod=crosses(groups),
                        count=mult,
                        f32_dot_artifact=is_f32_dot,
                    )
                )
                continue
            if inst.opcode in _CONTROL_OPS:
                continue
            # fusion-boundary HBM traffic, with in-place/slice corrections:
            # XLA aliases dynamic-update-slice (scan stacking) in place, and
            # slices/gathers only touch the moved bytes — counting their full
            # operands would overcount by the stacked-buffer size × trips.
            out_b = _bytes_of(inst.type_str)
            if inst.opcode in ("dynamic-slice", "slice", "gather"):
                stats.hbm_bytes += mult * 2 * out_b
                continue
            if inst.opcode == "dynamic-update-slice":
                upd = (
                    _bytes_of(comp.symbols[inst.operands[1]])
                    if len(inst.operands) > 1 and inst.operands[1] in comp.symbols
                    else 0
                )
                stats.hbm_bytes += mult * 2 * upd
                continue
            if inst.opcode == "scatter":
                upd = (
                    _bytes_of(comp.symbols[inst.operands[2]])
                    if len(inst.operands) > 2 and inst.operands[2] in comp.symbols
                    else out_b
                )
                stats.hbm_bytes += mult * 3 * upd
                continue
            if inst.opcode == "fusion":
                called = _called(inst.raw)
                sub = comps.get(called[0]) if called else None
                root_dus = bool(
                    sub and sub.instrs
                    and sub.instrs[-1].opcode == "dynamic-update-slice"
                )
                if root_dus:
                    # in-place stacking fusion: write the update only
                    small = sum(
                        _bytes_of(comp.symbols[o]) for o in inst.operands[1:]
                        if o in comp.symbols
                    )
                    stats.hbm_bytes += mult * 2 * small
                    continue
                # Operands consumed only through dynamic-slice inside the
                # fusion (scan xs slicing) touch slice bytes, not the full
                # stacked buffer — without this, a T-step scan over stacked
                # inputs overcounts by T×.
                sliced_params = {}
                if sub is not None:
                    param_of = {}
                    for si in sub.instrs:
                        if si.opcode == "parameter":
                            m = re.search(r"parameter\((\d+)\)", si.raw)
                            if m:
                                param_of[si.name] = int(m.group(1))
                    used_other = set()
                    for si in sub.instrs:
                        for o in si.operands:
                            if o in param_of:
                                if si.opcode == "dynamic-slice" and si.operands[0] == o:
                                    sliced_params.setdefault(
                                        param_of[o], 0
                                    )
                                    sliced_params[param_of[o]] += _bytes_of(
                                        si.type_str
                                    )
                                else:
                                    used_other.add(param_of[o])
                    for idx in used_other:
                        sliced_params.pop(idx, None)
                out_b_f = _bytes_of(inst.type_str)
                in_b_f = 0
                for i_op, o in enumerate(inst.operands):
                    if o not in comp.symbols:
                        continue
                    if i_op in sliced_params:
                        in_b_f += sliced_params[i_op]
                    else:
                        in_b_f += _bytes_of(comp.symbols[o])
                stats.hbm_bytes += mult * (out_b_f + in_b_f)
                continue
            in_b = sum(
                _bytes_of(comp.symbols[o]) for o in inst.operands
                if o in comp.symbols
            )
            stats.hbm_bytes += mult * (out_b + in_b)

    stats.flops = comp_flops(entry)
    walk_bytes_colls(entry, 1.0)
    return stats
