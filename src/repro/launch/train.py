"""Training driver: data pipeline → jitted step → checkpoints → metrics.

Runs real steps on whatever mesh fits the local devices (CPU tests/examples
use reduced configs; the production meshes are exercised by the dry-run).
Fault-tolerance wiring:

* checkpoint every ``checkpoint_every`` steps — async, atomic, integrity-
  checked, writer elected through the paper's ALock (``repro.coord``);
* restart: ``--resume`` restores the newest verified checkpoint and the data
  pipeline continues at the restored step (stateless batch addressing);
* straggler/elastic behaviour is exercised in tests/test_elastic.py via
  re-meshing a saved checkpoint onto a different device count.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager, load_checkpoint
from ..compat import set_mesh
from ..configs import RunConfig, SHAPES, ShapeConfig, get_config
from ..coord import CoordinationService
from ..data import SyntheticLMDataset, make_batch_iterator
from ..models import Model
from .mesh import make_mesh
from .steps import build_train_step, init_train_state


def train(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 50,
    shape: Optional[ShapeConfig] = None,
    mesh_shape=(1, 1),
    mesh_axes=("data", "model"),
    run: Optional[RunConfig] = None,
    resume: bool = False,
    log_every: int = 10,
    num_hosts: int = 1,
) -> Dict:
    cfg = get_config(arch, smoke=smoke)
    run = run or RunConfig(total_steps=steps, checkpoint_every=max(1, steps // 2))
    shape = shape or ShapeConfig("e2e", seq_len=128, global_batch=8, kind="train")
    mesh = make_mesh(mesh_shape, mesh_axes)
    model = Model(cfg)
    npods = dict(zip(mesh_axes, mesh_shape)).get("pod", 1)

    svc = CoordinationService(num_hosts=max(num_hosts, 1))
    ckpt = CheckpointManager(
        run.checkpoint_dir, every=run.checkpoint_every, svc=svc, host=0
    )

    with set_mesh(mesh):
        step_fn, state_shapes, state_sh, batch_sh = build_train_step(
            model, run, mesh, shape
        )
        start_step = 0
        if resume:
            try:
                host_state, start_step, extra = load_checkpoint(
                    run.checkpoint_dir, state_shapes, shardings=state_sh
                )
                state = host_state
                print(f"[train] resumed from step {start_step}")
            except FileNotFoundError:
                state = jax.device_put(
                    init_train_state(model, run, jax.random.PRNGKey(run.seed),
                                     npods),
                    state_sh,
                )
        else:
            state = jax.device_put(
                init_train_state(model, run, jax.random.PRNGKey(run.seed), npods),
                state_sh,
            )

        data = SyntheticLMDataset(cfg, shape, seed=run.seed)
        it = make_batch_iterator(data, start_step=start_step)
        history = []
        t0 = time.time()
        for i in range(start_step, steps):
            batch = jax.device_put(next(it), batch_sh)
            state, metrics = step_fn(state, batch)
            if (i + 1) % log_every == 0 or i == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = i + 1
                history.append(m)
                print(
                    f"[train] step {i + 1}/{steps} loss={m['loss']:.4f} "
                    f"grad_norm={m.get('grad_norm', float('nan')):.3f} "
                    f"({(time.time() - t0) / (i - start_step + 1):.2f}s/step)"
                )
            ckpt.maybe_save(i + 1, state, extra={"arch": arch})
        ckpt.wait()
        it.close()
    return {"history": history, "final_state": state, "config": cfg}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--sync-mode", default="flat")
    args = ap.parse_args()
    run = RunConfig(
        total_steps=args.steps,
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=max(1, args.steps // 2),
        sync_mode=args.sync_mode,
    )
    shape = ShapeConfig("cli", seq_len=args.seq_len, global_batch=args.batch,
                        kind="train")
    out = train(args.arch, smoke=args.smoke, steps=args.steps, shape=shape,
                run=run, resume=args.resume)
    losses = [h["loss"] for h in out["history"]]
    print(f"[train] done; first logged loss {losses[0]:.4f} → last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
