import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
# on the production meshes and extract memory/cost/collective statistics.
#
# The two lines above MUST run before any other import (jax locks the device
# count at first initialisation), which is why this module has no docstring.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
#       --shape train_4k [--multi-pod] [--sync-mode sync] [--out results/]
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
#
# Each cell writes results/<arch>__<shape>__<mesh>__<mode>.json with:
#   memory_analysis (per-device bytes), cost_analysis (XLA's once-per-while),
#   trip-count-corrected flops / hbm bytes / ICI+DCN collective bytes
#   (launch/hloparse.py), and the collective inventory.

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.configs import ARCHS, SHAPES, get_config, shape_cells, RunConfig
from repro.launch import hloparse
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    build_decode_step,
    build_encode_step,
    build_prefill_step,
    build_train_step,
    init_train_state,
    train_state_specs,
)
from repro.models import Model, input_specs, param_count
from repro.models.specs import is_spec
from repro.sharding import batch_pspec

POD_SIZE = 256


# Per-arch production knobs (from the §Perf napkin math: activation bytes
# per chip ≈ L·(B_loc/mb)·T·D·2; target ≤ ~8 GB with params+optimizer).
PRODUCTION_RUN = {
    "llama3.2-1b": dict(microbatches=2),
    "llama3-8b": dict(microbatches=8),
    "glm4-9b": dict(microbatches=8),
    "codeqwen1.5-7b": dict(microbatches=8),
    "hubert-xlarge": dict(microbatches=4),
    "internvl2-76b": dict(microbatches=16, optimizer_state_dtype="bfloat16"),
    "recurrentgemma-9b": dict(microbatches=8),
    # xlstm + MoE archs use fully-manual shard_map islands (sLSTM cell, EP
    # a2a), which do not compose with the vmap-over-pod "sync" lowering —
    # their multi-pod cells run the flat GSPMD schedule instead (see §Perf).
    "xlstm-1.3b": dict(microbatches=8, _flat_multipod=True),
    "deepseek-v2-236b": dict(microbatches=4, optimizer_state_dtype="bfloat16",
                             _flat_multipod=True),
    "deepseek-v3-671b": dict(microbatches=4, optimizer_state_dtype="bfloat16",
                             _flat_multipod=True),
}

# Expert-weight layout per MoE arch (§Perf iteration: the baseline fsdp_d
# moves expert weights over the fabric every layer).
EXPERT_SHARDING = {
    "deepseek-v2-236b": "ep_a2a",   # EP over model axis + weight FSDP gather
    "deepseek-v3-671b": "ep_a2a",   # E=256 → one expert per chip, manual a2a
}


def production_config(arch: str, expert_sharding: str = None,
                      microbatches: int = None):
    """Full config with launcher overrides for the production mesh."""
    cfg = get_config(arch)
    if cfg.moe is not None:
        # group-local dispatch: one group per data shard
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(
                cfg.moe,
                groups=16,
                expert_sharding=expert_sharding
                or EXPERT_SHARDING.get(arch, cfg.moe.expert_sharding),
            )
        )
    return cfg


def production_run(arch: str, sync_mode: str, microbatches: int = None,
                   multi_pod: bool = False) -> RunConfig:
    kw = dict(PRODUCTION_RUN.get(arch, {}))
    if kw.pop("_flat_multipod", False) and multi_pod and sync_mode == "sync":
        sync_mode = "flat"
    if microbatches is not None:
        kw["microbatches"] = microbatches
    return RunConfig(sync_mode=sync_mode, **kw)


def _routed_expert_fraction(cfg) -> float:
    """Fraction of params that are routed experts (for active-param count)."""
    if cfg.moe is None:
        return 0.0
    from repro.models.moe import moe_spec
    from repro.models.specs import param_count as pc
    spec = moe_spec(cfg)
    routed = pc({"wi": spec["wi"], "wo": spec["wo"]})
    return routed


def model_flops_estimate(cfg, shape, n_params: int) -> float:
    """MODEL_FLOPS: 6·N·D (train, dense) / 6·N_active·D (MoE) / 2·N·D (fwd)."""
    model = Model(cfg)
    n_total = n_params
    if cfg.moe is not None:
        plan = model.plan
        n_moe_layers = plan.n_scan * len(plan.pattern) + len(plan.tail)
        routed_per_layer = _routed_expert_fraction(cfg)
        routed_total = routed_per_layer * n_moe_layers
        active = n_total - routed_total * (1 - cfg.moe.top_k / cfg.moe.num_experts)
    else:
        active = n_total
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * active * tokens


def lower_cell(arch: str, shape_name: str, multi_pod: bool, sync_mode: str,
               expert_sharding: str = None, microbatches: int = None):
    cfg = production_config(arch, expert_sharding=expert_sharding)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    run = production_run(arch, sync_mode, microbatches, multi_pod=multi_pod)
    # Cap microbatches so each microbatch still fills every data shard --
    # otherwise XLA pads rows and every chip burns flops on padding
    # (measured: internvl2 2-pod at mb=16 ran the FULL batch per pod).
    npods = 2 if multi_pod else 1
    mb_cap = max(1, shape.global_batch // (npods * 16))
    if run.microbatches > mb_cap:
        run = dataclasses.replace(run, microbatches=mb_cap)

    with set_mesh(mesh):
        if shape.kind == "train":
            step, state_shapes, state_sh, batch_sh = build_train_step(
                model, run, mesh, shape
            )
            batch = input_specs(cfg, shape)
            npods = 2 if multi_pod else 1
            state = jax.tree.map(
                lambda s: s, state_shapes,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
            lowered = step.lower(state, batch)
        elif shape.kind == "prefill":
            if not cfg.causal:
                # Encoder-only: "prefill" is a plain forward (no cache).
                step = build_encode_step(model, mesh, shape)
            else:
                step, cache_spec, _ = build_prefill_step(
                    model, mesh, shape, max_len=shape.seq_len
                )
            batch = input_specs(cfg, shape)
            lowered = step.lower(model.param_shapes(), batch)
        else:  # decode
            step, cache_spec, _ = build_decode_step(
                model, mesh, shape, max_len=shape.seq_len
            )
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            lowered = step.lower(model.param_shapes(), cache_spec, tokens)
        compiled = lowered.compile()
    return cfg, shape, mesh, lowered, compiled


def run_cell(arch: str, shape_name: str, multi_pod: bool, sync_mode: str,
             out_dir: str, skip_existing: bool = True, tag: str = "",
             expert_sharding: str = None, microbatches: int = None):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}__{sync_mode}" + (
        f"__{tag}" if tag else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    if skip_existing and os.path.exists(out_path):
        print(f"[dryrun] {cell_id}: cached")
        return json.load(open(out_path))

    for shp, skip in shape_cells(arch):
        if shp.name == shape_name and skip:
            rec = {"cell": cell_id, "skipped": skip}
            os.makedirs(out_dir, exist_ok=True)
            json.dump(rec, open(out_path, "w"), indent=1)
            print(f"[dryrun] {cell_id}: SKIP ({skip})")
            return rec

    t0 = time.time()
    print(f"[dryrun] {cell_id}: lowering...", flush=True)
    cfg, shape, mesh, lowered, compiled = lower_cell(
        arch, shape_name, multi_pod, sync_mode,
        expert_sharding=expert_sharding, microbatches=microbatches,
    )
    t1 = time.time()
    print(f"[dryrun] {cell_id}: compiled in {t1 - t0:.1f}s; analyzing...",
          flush=True)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    ndev = 512 if multi_pod else 256
    stats = hloparse.analyze(text, num_devices=ndev, pod_size=POD_SIZE)

    n_params = param_count(Model(cfg).specs())
    mf = model_flops_estimate(cfg, shape, n_params)

    coll_summary = {}
    for c in stats.collectives:
        key = f"{c.kind}{'@dcn' if c.crosses_pod else '@ici'}"
        agg = coll_summary.setdefault(
            key, {"instances": 0.0, "wire_bytes_per_chip": 0.0}
        )
        agg["instances"] += c.count
        agg["wire_bytes_per_chip"] += c.wire_bytes_per_chip() * c.count

    rec = {
        "cell": cell_id,
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "sync_mode": sync_mode,
        "num_devices": ndev,
        "compile_seconds": round(t1 - t0, 1),
        "params": n_params,
        "model_flops": mf,
        "memory_analysis": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "peak_estimate_bytes_per_device": (
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
            ),
        },
        "cost_analysis_once": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "parsed": {
            "flops_per_device": stats.flops,
            "hbm_bytes_per_device": stats.hbm_bytes,
            "ici_wire_bytes_per_chip": stats.ici_bytes,
            "dcn_wire_bytes_per_chip": stats.dcn_bytes,
            "ici_wire_bytes_per_chip_raw": stats.ici_bytes_raw,
            "dcn_wire_bytes_per_chip_raw": stats.dcn_bytes_raw,
        },
        "collectives": coll_summary,
        "top_collectives": stats.top_collectives(8),
    }
    os.makedirs(out_dir, exist_ok=True)
    json.dump(rec, open(out_path, "w"), indent=1)
    print(f"[dryrun] {cell_id}: done ({time.time() - t0:.1f}s total)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--sync-mode", default="sync",
                    choices=("flat", "sync", "local"))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell")
    ap.add_argument("--out", default="results")
    ap.add_argument("--no-skip", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--expert-sharding", default=None,
                    choices=(None, "fsdp_d", "fsdp_f", "ep2d"))
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    failures = []
    if args.all:
        for arch in ARCHS:
            for shape_name in SHAPES:
                for multi_pod in (False, True):
                    try:
                        run_cell(arch, shape_name, multi_pod, args.sync_mode,
                                 args.out, not args.no_skip)
                    except Exception as e:
                        traceback.print_exc()
                        failures.append((arch, shape_name, multi_pod, str(e)))
    else:
        meshes = []
        if args.multi_pod or not args.single_pod:
            meshes.append(True)
        if args.single_pod or not args.multi_pod:
            meshes.append(False)
        for mp in sorted(set(meshes)):
            run_cell(args.arch, args.shape, mp, args.sync_mode, args.out,
                     not args.no_skip, tag=args.tag,
                     expert_sharding=args.expert_sharding,
                     microbatches=args.microbatches)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
