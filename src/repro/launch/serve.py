"""Batched serving driver: prefill a prompt batch, then greedy decode.

``serve`` is the library entry (used by examples/serve_batch.py and the e2e
tests); ``main`` is the CLI.  Batching model: requests accumulate into fixed
batches (continuous batching is approximated by slot reuse at the example
level; the step functions themselves are batch-static, which is what the
decode dry-run cells lower).

Request-batch **admission** is a lock-table client
(:class:`BatchAdmission`): each concurrent batch slot is a lease in the
sharded asymmetric lock table, so admission control inherits the table's
guarantees — a crashed batch worker's slot expires after its TTL instead of
throttling the server forever, the fencing token identifies the admission for
downstream accounting, and the serving host (the table's local class) pays
zero simulated RDMA operations on its own admission path.  Off by default
(``admission_slots=0``) so library users and tests keep the bare fast path.
"""

from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import set_mesh
from ..configs import ShapeConfig, get_config
from ..coord import CoordinationService, LeaseMode, RecoverableClient
from ..core import Overloaded
from ..data import SyntheticLMDataset
from ..models import Model, input_specs
from .mesh import make_mesh
from .steps import build_decode_step, build_prefill_step


class BatchAdmission:
    """Admission control for request batches, as a lock-table client.

    Each of ``num_slots`` concurrent batch slots is a key in the sharded lock
    table; admitting a batch means taking a lease on a free slot.  The TTL is
    the worst-case batch walltime: a worker that dies mid-batch stops renewing
    and its slot re-opens at expiry, so capacity can never leak away.  The
    lease's fencing token travels with the batch for downstream accounting
    (e.g. a KV-cache pool can reject a zombie batch's writes).

    **Read slots vs write slots** (the mode-aware split): mutating batches
    (decode/prefill that write KV state) take EXCLUSIVE leases on the write
    slots as before, while read-only work — health probes, stats scrapes,
    cache-warm scans — shares ``read_slots`` *read lanes* through SHARED
    leases (:meth:`admit_read`): any number of readers join a lane with a
    single CAS and zero simulated RDMA ops on the serving host, so read
    traffic never queues behind (or consumes) batch capacity.  A maintenance
    operation that must quiesce a lane's readers takes an EXCLUSIVE lease on
    it (:meth:`quiesce`): the table's writer-intent barrier stops new joins,
    the cohort drains within one TTL, and readers resume the moment the
    maintenance lease is released.

    **Named workers and crash recovery**: a server thread that admits under a
    ``worker`` name goes through a ledgered
    :class:`~repro.coord.RecoverableClient`, so every slot admission leaves a
    durable trail.  When a worker thread dies mid-batch and its supervisor
    starts a replacement, the new thread calls :meth:`recover` with the same
    name: the predecessor's ledger replays and every still-valid slot lease
    is *reclaimed* via the fencing-checked CAS — the replacement resumes the
    batch (same slot, same fencing token) instead of waiting out the TTL or
    double-granting capacity.  Anonymous admissions (no ``worker``) keep the
    bare fast path.
    """

    def __init__(self, num_slots: int = 4, ttl: float = 30.0,
                 svc: Optional[CoordinationService] = None,
                 read_slots: int = 0):
        if num_slots <= 0:
            raise ValueError("num_slots must be > 0")
        if read_slots < 0:
            raise ValueError("read_slots must be >= 0")
        # Single-host table by default: the serving host is the local class
        # for every shard, so admissions cost zero simulated RDMA ops.
        self.svc = svc or CoordinationService(
            num_hosts=1, num_shards=num_slots + read_slots)
        self.num_slots = num_slots
        self.read_slots = read_slots
        self.ttl = ttl
        self._tls = threading.local()
        # Ledgered clients by worker name (the identity that survives a
        # thread death).  A name is bound to one live thread at a time;
        # rebinding happens through recover().
        self._workers: Dict[str, RecoverableClient] = {}
        self._wlock = threading.Lock()
        # One async pipeline per server thread (PR 10): anonymous
        # keepalives ride it, and the table's hedged probes from that
        # thread share its flush postings.  Kept in a list too, so
        # stats() can aggregate across threads.
        self._pipes = []
        #: EXCLUSIVE admissions refused at the gate by the overload layer.
        self.sheds = 0

    def _proc(self):
        # One coordination Process per server thread: the MCS queue keys its
        # descriptors by pid, so sharing one pid across threads would corrupt
        # the shard ALocks (service.host_process: "call once per host thread").
        p = getattr(self._tls, "p", None)
        if p is None:
            p = self._tls.p = self.svc.host_process(0)
        return p

    def _pipe(self):
        # This thread's AsyncClient over the admission table.  On the
        # default single-host table every op is home-class and resolves
        # inline (identical semantics, zero RDMA); over a multi-host
        # service, remote keepalives coalesce into one posting per flush.
        pl = getattr(self._tls, "pipe", None)
        if pl is None:
            pl = self._tls.pipe = self.svc.async_client(self._proc())
            with self._wlock:
                self._pipes.append(pl)
        return pl

    def _worker(self, worker: str) -> RecoverableClient:
        with self._wlock:
            rc = self._workers.get(worker)
            if rc is None:
                rc = self._workers[worker] = self.svc.recoverable(
                    f"serve/{worker}", self._proc())
            return rc

    def recover(self, worker: str):
        """Crash-restart re-entry for a named worker thread.

        The replacement thread (same ``worker`` name, fresh coordination
        process) replays its predecessor's ledger and reclaims every slot
        lease that is still valid — fencing-checked, so a lease the table
        already re-granted comes back as lost, never double-held.  Returns
        the reclaimed leases; the worker resumes those batches (or
        ``complete``\\ s them) under the original fencing tokens.
        """
        client, reclaimed = self.svc.restart(f"serve/{worker}", self._proc())
        with self._wlock:
            self._workers[worker] = client
        return reclaimed

    def _admission_gate(self, key: str) -> None:
        """Brownout shedding: refuse an EXCLUSIVE admission fast when the
        overload layer already knows the slot's home is in trouble (open
        circuit breaker, or a retry budget too dry to fund even one retry
        round).  Read-lane admissions (:meth:`admit_read`) never come
        through here — shared-mode reads keep flowing while exclusive
        admits shed, which is the brownout contract.  A no-op when the
        service carries no :class:`~repro.coord.OverloadPolicy`."""
        ctl = self.svc.table.overload
        if ctl is None:
            return
        home = self.svc.home_of(key)
        if ctl.breaker_open(home):
            self.sheds += 1
            raise Overloaded(
                f"admission shed: breaker open for host {home}",
                reason="breaker", host=home)
        b = ctl.budget(home)
        if b.tokens < b.retry_cost:
            self.sheds += 1
            raise Overloaded(
                f"admission shed: retry budget dry for host {home}",
                reason="budget", host=home)

    def admit(self, timeout: Optional[float] = None,
              worker: Optional[str] = None,
              deadline: Optional[float] = None):
        """Take an EXCLUSIVE lease on any free write slot (round-robin scan,
        then block).

        The deadline and backoff run on the coordination service's injected
        clock/sleep pair, so an admission gate over a sim-backed (or
        fake-clock) table times out in that table's time base instead of
        wall time.  ``deadline`` is the absolute form (the earlier of the
        two wins); under overload control, admissions shed fast at the gate
        instead of scanning a slot list they cannot win (see
        :meth:`_admission_gate`).

        With a ``worker`` name the admission is ledgered (see
        :meth:`recover`); anonymous admissions take the bare path.
        """
        clock, sleep = self.svc.table.clock, self.svc.table.sleep
        if timeout is not None:
            tdl = clock() + timeout
            deadline = tdl if deadline is None else min(deadline, tdl)
        rc = self._worker(worker) if worker is not None else None
        while True:
            for s in range(self.num_slots):
                key = f"serve/slot{s}"
                self._admission_gate(key)
                try:
                    if rc is not None:
                        lease = rc.try_acquire(key, self.ttl)
                    else:
                        lease = self.svc.try_acquire(self._proc(), key,
                                                     self.ttl)
                except Overloaded:
                    self.sheds += 1
                    raise
                if lease is not None:
                    return lease
            if deadline is not None and clock() > deadline:
                raise TimeoutError(f"no admission slot free in {timeout}s")
            sleep(0.002)  # back off: a full scan found no free slot

    def admit_read(self, timeout: Optional[float] = None):
        """Join a read lane with a SHARED lease (a single CAS; readers
        stack, so this only ever blocks while a quiesce drains the lanes).

        Requires ``read_slots > 0``.  The lane is chosen round-robin so
        concurrent readers spread their cohort CASes across lanes.
        Complete (and keepalive) a shared admission **on the thread that
        admitted it**: each server thread is its own coordination process,
        and the table's cohort-slot ledger is per process.  (Exclusive
        admissions are witness CASes and may be completed from any thread.)
        """
        if self.read_slots <= 0:
            raise ValueError("admit_read() needs read_slots > 0")
        clock, sleep = self.svc.table.clock, self.svc.table.sleep
        # Deliberately NOT gated by _admission_gate: the brownout contract
        # is that shared-mode reads keep flowing while exclusive admits
        # shed (a reader join is one CAS, zero RDMA on the serving host —
        # refusing it buys nothing).
        deadline = None if timeout is None else clock() + timeout
        p = self._proc()
        while True:
            for s in range(self.read_slots):
                lane = (p.pid + s) % self.read_slots
                lease = self.svc.try_acquire(
                    p, f"serve/readlane{lane}", self.ttl,
                    mode=LeaseMode.SHARED)
                if lease is not None:
                    return lease
            if deadline is not None and clock() > deadline:
                raise TimeoutError(f"no read lane joinable in {timeout}s")
            sleep(0.002)  # every lane is quiescing: wait out the drain

    def quiesce(self, lane: int = 0, timeout: Optional[float] = None):
        """Take an EXCLUSIVE lease on a read lane — the maintenance path.

        Arms the table's writer-intent barrier on the lane: no new readers
        join, the live cohort drains within one TTL, and the returned lease
        excludes every reader until it is released (``complete``).
        """
        if not (0 <= lane < self.read_slots):
            raise ValueError(f"lane {lane} out of range")
        clock, sleep = self.svc.table.clock, self.svc.table.sleep
        deadline = None if timeout is None else clock() + timeout
        while True:
            lease = self.svc.try_acquire(self._proc(), f"serve/readlane{lane}",
                                         self.ttl)
            if lease is not None:
                return lease
            if deadline is not None and clock() > deadline:
                raise TimeoutError(f"read lane {lane} not drained in {timeout}s")
            sleep(0.002)  # the drain barrier is armed; readers are leaving

    def keepalive(self, lease, worker: Optional[str] = None):
        """Renew mid-batch (call between prefill and decode, or per chunk).

        Rides the lock table's renewal fast path: one fencing-token-checked
        CAS on the expiry register, no shard ALock — and since the serving
        host is the table's local class, the keepalive costs **zero**
        simulated RDMA operations (``stats()['fast_renews']`` counts the
        fast-path hits; ``local_rdma_ops`` stays 0).
        """
        if worker is not None:
            renewed = self._worker(worker).renew(lease)
        else:
            # Anonymous keepalives ride the per-thread async pipeline
            # (PR 10): home renewals resolve inline on the same zero-RDMA
            # fast path; remote ones ride the next flush as one
            # witness-CAS WR sharing a doorbell with queued work.
            pl = self._pipe()
            renewed = pl.sync(pl.renew(lease))
            self.svc.note_renewed(self._proc(), lease, renewed)
        if renewed is None:
            raise RuntimeError(
                f"admission lease on {lease.key} lost (token {lease.token}); "
                "the batch overran its TTL and the slot was re-granted"
            )
        return renewed

    def complete(self, lease, worker: Optional[str] = None) -> bool:
        if worker is not None:
            return self._worker(worker).release(lease)
        return self.svc.release(self._proc(), lease)

    def stats(self) -> Dict:
        totals = self.svc.class_totals()
        rows = self.svc.telemetry()
        return {
            "slots": self.num_slots,
            "read_slots": self.read_slots,
            "grants": sum(r["grants"] for r in rows),
            "rejects": sum(r["rejects"] for r in rows),
            "grants_shared": sum(r["grants_shared"] for r in rows),
            "grants_exclusive": sum(r["grants_exclusive"] for r in rows),
            "shared_joins": sum(r["shared_joins"] for r in rows),
            "shared_releases": sum(r["shared_releases"] for r in rows),
            "intent_blocks": sum(r["intent_blocks"] for r in rows),
            "expirations": sum(r["expirations"] for r in rows),
            "fast_renews": sum(r["fast_renews"] for r in rows),
            "fast_releases": sum(r["fast_releases"] for r in rows),
            "reclaims": sum(r["reclaims"] for r in rows),
            "reclaim_fast": sum(r["reclaim_fast"] for r in rows),
            "reclaim_rejects": sum(r["reclaim_rejects"] for r in rows),
            "orphan_probes": sum(r["orphan_probes"] for r in rows),
            "orphan_adopts": sum(r["orphan_adopts"] for r in rows),
            "workers": len(self._workers),
            "local_rdma_ops": totals[0].rdma_ops,
            "local_ops": totals[0].local_ops,
            # Overload-protection telemetry (PR 9): admission-level sheds
            # plus the table-side shed/hedge/deadline counters; the
            # breaker/budget report appears only when a policy is armed.
            "sheds": self.sheds,
            "table_sheds": sum(r["sheds"] for r in rows),
            "hedges": sum(r["hedges"] for r in rows),
            "deadline_exceeded": sum(r["deadline_exceeded"] for r in rows),
            "overload": self.svc.overload_report(),
            # PR 10 pipeline telemetry, aggregated across server threads.
            "pipeline_flushes": sum(pl.stats["flushes"]
                                    for pl in self._pipes),
            "pipeline_flushed_ops": sum(pl.stats["flushed_ops"]
                                        for pl in self._pipes),
            "pipeline_hedge_rides": sum(pl.stats["hedge_rides"]
                                        for pl in self._pipes),
        }


def serve(
    arch: str,
    *,
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen_len: int = 16,
    mesh_shape=(1, 1),
    mesh_axes=("data", "model"),
    greedy: bool = True,
    seed: int = 0,
    admission_slots: int = 0,
    admission_ttl: float = 30.0,
    admission: Optional[BatchAdmission] = None,
) -> Dict:
    cfg = get_config(arch, smoke=smoke)
    if not cfg.causal:
        raise ValueError(f"{arch} is encoder-only: no decode path")
    # A caller-supplied BatchAdmission is the real gate (shared across serve()
    # calls / server threads, bounding their concurrency); admission_slots
    # alone builds a private table, useful for the telemetry but never
    # contended by anyone else.
    if admission is None and admission_slots > 0:
        admission = BatchAdmission(num_slots=admission_slots, ttl=admission_ttl)
    mesh = make_mesh(mesh_shape, mesh_axes)
    model = Model(cfg)
    max_len = prompt_len + gen_len
    pshape = ShapeConfig("serve", seq_len=prompt_len, global_batch=batch,
                         kind="prefill")

    with set_mesh(mesh):
        prefill_fn, _, (param_sh, batch_sh, cache_sh) = build_prefill_step(
            model, mesh, pshape, max_len
        )
        dshape = ShapeConfig("serve", seq_len=max_len, global_batch=batch,
                             kind="decode")
        decode_fn, _, _ = build_decode_step(model, mesh, dshape, max_len)

        params = jax.device_put(model.init(jax.random.PRNGKey(seed)), param_sh)
        prompts = input_specs(cfg, pshape, concrete=True,
                              rng=jax.random.PRNGKey(seed + 1))
        prompts = jax.device_put(prompts, batch_sh)

        # Admit only now: model build is per-call setup, and the slot TTL
        # must budget batch *execution*, not JIT compilation (a compile
        # outlasting the TTL would expire a healthy batch's lease and let the
        # slot be double-granted).  The first prefill call still compiles, so
        # warm it before taking the slot when the jitted fn supports AOT.
        if admission:
            try:
                prefill_fn.lower(params, prompts).compile()
            except (AttributeError, TypeError):
                pass  # not a jitted callable: compile lands inside the lease
        slot = admission.admit(timeout=admission_ttl) if admission else None
        try:
            t0 = time.time()
            logits, caches = prefill_fn(params, prompts)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            prefill_s = time.time() - t0
            if admission:
                slot = admission.keepalive(slot)  # prefill done; extend

            generated = [np.asarray(tok)]
            t1 = time.time()
            for step in range(gen_len - 1):
                logits, caches = decode_fn(params, caches, tok)
                if greedy:
                    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
                else:
                    tok = jax.random.categorical(
                        jax.random.PRNGKey(int(time.time() * 1e6) % 2**31),
                        logits[:, -1],
                    )[:, None].astype(jnp.int32)
                generated.append(np.asarray(tok))
                if admission and step % 8 == 7:
                    slot = admission.keepalive(slot)  # TTL covers ~8 steps
            decode_s = time.time() - t1
        finally:
            # Release on *every* exit: an exception mid-batch must not hold
            # the slot hostage for the rest of its TTL.
            if admission:
                admission.complete(slot)

    tokens = np.concatenate(generated, axis=1)
    out = {
        "tokens": tokens,
        "prefill_seconds": prefill_s,
        "decode_seconds_per_token": decode_s / max(gen_len - 1, 1),
        "throughput_tok_s": tokens.size / max(decode_s + prefill_s, 1e-9),
    }
    if admission:
        out["admission"] = dict(
            admission.stats(), slot_key=slot.key, fence_token=slot.token,
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--admission-slots", type=int, default=0,
                    help="admit the batch through the sharded lock table")
    args = ap.parse_args()
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_len=args.gen, admission_slots=args.admission_slots)
    print(f"[serve] generated {out['tokens'].shape} tokens; "
          f"prefill {out['prefill_seconds']:.2f}s, "
          f"{out['decode_seconds_per_token'] * 1e3:.1f} ms/token, "
          f"{out['throughput_tok_s']:.1f} tok/s")
    print("[serve] first sequence:", out["tokens"][0][:16])
    if "admission" in out:
        print("[serve] admission:", out["admission"])


if __name__ == "__main__":
    main()
