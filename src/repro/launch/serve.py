"""Batched serving driver: prefill a prompt batch, then greedy decode.

``serve`` is the library entry (used by examples/serve_batch.py and the e2e
tests); ``main`` is the CLI.  Batching model: requests accumulate into fixed
batches (continuous batching is approximated by slot reuse at the example
level; the step functions themselves are batch-static, which is what the
decode dry-run cells lower).
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ShapeConfig, get_config
from ..data import SyntheticLMDataset
from ..models import Model, input_specs
from .mesh import make_mesh
from .steps import build_decode_step, build_prefill_step


def serve(
    arch: str,
    *,
    smoke: bool = True,
    batch: int = 4,
    prompt_len: int = 32,
    gen_len: int = 16,
    mesh_shape=(1, 1),
    mesh_axes=("data", "model"),
    greedy: bool = True,
    seed: int = 0,
) -> Dict:
    cfg = get_config(arch, smoke=smoke)
    if not cfg.causal:
        raise ValueError(f"{arch} is encoder-only: no decode path")
    mesh = make_mesh(mesh_shape, mesh_axes)
    model = Model(cfg)
    max_len = prompt_len + gen_len
    pshape = ShapeConfig("serve", seq_len=prompt_len, global_batch=batch,
                         kind="prefill")

    with jax.set_mesh(mesh):
        prefill_fn, _, (param_sh, batch_sh, cache_sh) = build_prefill_step(
            model, mesh, pshape, max_len
        )
        dshape = ShapeConfig("serve", seq_len=max_len, global_batch=batch,
                             kind="decode")
        decode_fn, _, _ = build_decode_step(model, mesh, dshape, max_len)

        params = jax.device_put(model.init(jax.random.PRNGKey(seed)), param_sh)
        prompts = input_specs(cfg, pshape, concrete=True,
                              rng=jax.random.PRNGKey(seed + 1))
        prompts = jax.device_put(prompts, batch_sh)

        t0 = time.time()
        logits, caches = prefill_fn(params, prompts)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        prefill_s = time.time() - t0

        generated = [np.asarray(tok)]
        t1 = time.time()
        for _ in range(gen_len - 1):
            logits, caches = decode_fn(params, caches, tok)
            if greedy:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            else:
                tok = jax.random.categorical(
                    jax.random.PRNGKey(int(time.time() * 1e6) % 2**31),
                    logits[:, -1],
                )[:, None].astype(jnp.int32)
            generated.append(np.asarray(tok))
        decode_s = time.time() - t1

    tokens = np.concatenate(generated, axis=1)
    return {
        "tokens": tokens,
        "prefill_seconds": prefill_s,
        "decode_seconds_per_token": decode_s / max(gen_len - 1, 1),
        "throughput_tok_s": tokens.size / max(decode_s + prefill_s, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_len=args.gen)
    print(f"[serve] generated {out['tokens'].shape} tokens; "
          f"prefill {out['prefill_seconds']:.2f}s, "
          f"{out['decode_seconds_per_token'] * 1e3:.1f} ms/token, "
          f"{out['throughput_tok_s']:.1f} tok/s")
    print("[serve] first sequence:", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
