"""Sharded lock table: placement stability, leases + fencing, batched
acquisition, and the per-shard mutual-exclusion / cost invariants."""

import random
import threading
import time

import pytest

from repro.core import AsymmetricMemory, make_scheduler
from repro.coord import CoordinationService, ShardedLockTable
from repro.coord.table import LOCAL, REMOTE


class FakeClock:
    """Deterministic lease clock (leases expire only when we say so)."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt

    def ticker(self, dt: float = 1.0):
        """A thread advancing the clock until stopped — for timeout tests,
        where a single jump could race the blocked caller's deadline read."""
        stop = threading.Event()

        def tick():
            while not stop.is_set():
                self.advance(dt)
                time.sleep(0.001)

        t = threading.Thread(target=tick)
        t.start()
        return stop, t


def make_table(num_hosts=4, num_shards=8, clock=None, sched=None):
    mem = AsymmetricMemory(num_hosts, sched=sched)
    return mem, ShardedLockTable(mem, num_shards=num_shards, clock=clock)


def key_homed_on(table, host, salt=""):
    """Find a key whose shard is homed on ``host`` (stable hash ⇒ exists)."""
    for i in range(10_000):
        k = f"key{salt}-{i}"
        if table.home_of(k) == host:
            return k
    raise AssertionError(f"no key homed on host {host}")


# ---------------------------------------------------------------- placement
def test_shard_placement_is_stable_across_instances():
    _, t1 = make_table()
    _, t2 = make_table()
    keys = [f"user/{i}/profile" for i in range(200)]
    assert [t1.shard_of(k) for k in keys] == [t2.shard_of(k) for k in keys]
    # every shard's home follows the s % num_hosts layout
    for s, shard in enumerate(t1.shards):
        assert shard.home_host == s % t1.num_hosts


def test_shard_placement_spreads_keys():
    _, table = make_table(num_hosts=4, num_shards=8)
    hits = [0] * table.num_shards
    for i in range(800):
        hits[table.shard_of(f"record/{i}")] += 1
    assert all(h > 0 for h in hits), f"empty shard: {hits}"
    assert max(hits) < 4 * min(hits), f"badly skewed placement: {hits}"


# ------------------------------------------------------------------- leases
def test_lease_expiry_allows_regrant_with_larger_token():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p0, p1 = mem.spawn(0), mem.spawn(1)

    lease = table.try_acquire(p0, "manifest", ttl=10.0)
    assert lease is not None and lease.holder_pid == p0.pid
    assert table.try_acquire(p1, "manifest", ttl=10.0) is None  # held

    clock.advance(10.0)  # the holder "crashed"; its lease lapses
    regrant = table.try_acquire(p1, "manifest", ttl=10.0)
    assert regrant is not None, "expired lease wedged the shard"
    assert regrant.token > lease.token, "fencing token must increase"
    # The crashed holder's stale lease can no longer release or renew.
    assert table.release(p0, lease) is False
    assert table.renew(p0, lease) is None
    # The live holder still can.
    assert table.release(p1, regrant) is True


def test_fencing_tokens_strictly_increase_per_key():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p = mem.spawn(0)
    tokens = []
    for _ in range(10):
        lease = table.try_acquire(p, "hot-key", ttl=5.0)
        assert lease is not None
        tokens.append(lease.token)
        table.release(p, lease)
    assert tokens == sorted(tokens) and len(set(tokens)) == len(tokens)


def test_acquire_is_not_reentrant_and_renew_extends():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p = mem.spawn(2)
    a = table.try_acquire(p, "k", ttl=5.0)
    assert a is not None
    # Non-reentrant: one process posing as several clients must not be able
    # to steal its own live lease (holders extend via renew instead).
    assert table.try_acquire(p, "k", ttl=5.0) is None
    clock.advance(4.0)
    a2 = table.renew(p, a, ttl=5.0)
    assert a2 is not None and a2.token == a.token and a2.expires_at == 9.0
    clock.advance(6.0)
    assert table.renew(p, a2) is None  # expired: renew must fail
    # ...but the expired key is re-grantable, with a larger token.
    b = table.try_acquire(p, "k", ttl=5.0)
    assert b is not None and b.token > a.token


def test_blocking_acquire_times_out():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p0, p1 = mem.spawn(0), mem.spawn(1)
    table.try_acquire(p0, "k", ttl=1e9)  # held essentially forever

    stop, t = clock.ticker()
    try:
        with pytest.raises(TimeoutError):
            table.acquire(p1, "k", ttl=1.0, timeout=10.0)
    finally:
        stop.set()
        t.join()


# ----------------------------------------------------------------- batches
def test_batch_order_is_total_and_deduplicated():
    _, table = make_table()
    order = table.batch_order(["b", "a", "b", "c", "a"])
    assert sorted(order) == ["a", "b", "c"]
    assert order == table.batch_order(reversed(order))  # order-independent


def test_batched_acquire_deadlock_freedom_under_conflicting_orders():
    """Clients requesting overlapping key sets in *opposite* orders must all
    complete: the table imposes the global (shard, key) order internally."""
    mem, table = make_table(num_hosts=3, num_shards=6)
    keys = [f"row/{i}" for i in range(6)]
    done = []
    errors = []

    def client(host, my_keys, rounds=25):
        p = mem.spawn(host)
        try:
            for _ in range(rounds):
                leases = table.acquire_batch(p, my_keys, ttl=30.0, timeout=20.0)
                assert len(leases) == len(set(my_keys))
                assert table.release_batch(p, leases) == len(leases)
            done.append(host)
        except Exception as e:  # pragma: no cover - surfaced via assert below
            errors.append((host, repr(e)))

    ts = [
        threading.Thread(target=client, args=(0, keys)),
        threading.Thread(target=client, args=(1, list(reversed(keys)))),
        threading.Thread(target=client, args=(2, keys[3:] + keys[:3])),
    ]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not errors, errors
    assert sorted(done) == [0, 1, 2], "batched clients deadlocked"


def test_batch_timeout_releases_partial_grants():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p0, p1 = mem.spawn(0), mem.spawn(1)
    keys = ["x", "y"]
    first, second = table.batch_order(keys)
    blocker = table.try_acquire(p0, second, ttl=1e9)
    assert blocker is not None

    stop, t = clock.ticker()
    try:
        with pytest.raises(TimeoutError):
            # ttl far beyond the test: only an explicit rollback frees `first`
            table.acquire_batch(p1, keys, ttl=1e6, timeout=10.0)
    finally:
        stop.set()
        t.join()
    # the partial grant on `first` was rolled back, not left to expire
    assert table.try_acquire(p0, first, ttl=1.0) is not None


# ------------------------------------------------- mutual exclusion / cost
@pytest.mark.parametrize("seed", [0, 1])
def test_leases_mutually_exclude_per_key_under_stress(seed):
    rng = random.Random(seed)
    mem = AsymmetricMemory(3, sched=make_scheduler(rng, 0.15))
    table = ShardedLockTable(mem, num_shards=4)
    keys = [f"k{i}" for i in range(3)]
    state = {k: {"in": 0, "max": 0, "count": 0} for k in keys}

    def worker(host):
        p = mem.spawn(host)
        r = random.Random(1000 * seed + host)
        for _ in range(60):
            k = r.choice(keys)
            lease = table.acquire(p, k, ttl=60.0, timeout=30.0)
            st = state[k]
            st["in"] += 1
            st["max"] = max(st["max"], st["in"])
            st["count"] += 1  # non-atomic on purpose: the lease protects it
            st["in"] -= 1
            assert table.release(p, lease)

    ts = [threading.Thread(target=worker, args=(h,)) for h in (0, 0, 1, 1, 2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert all(st["max"] == 1 for st in state.values()), state
    assert sum(st["count"] for st in state.values()) == 5 * 60


def test_home_shard_clients_issue_zero_rdma_ops():
    """The tentpole claim: a client touching only keys homed on its own host
    is the paper's local class for those shards — zero fabric operations."""
    mem, table = make_table(num_hosts=4, num_shards=8)
    host = 2
    p = mem.spawn(host)
    for salt in range(5):
        k = key_homed_on(table, host, salt=str(salt))
        lease = table.try_acquire(p, k, ttl=5.0)
        assert lease is not None
        assert table.release(p, lease)
    assert p.counts.rdma_ops == 0, vars(p.counts)
    assert p.counts.local_ops > 0
    # ...and the per-shard telemetry agrees: LOCAL class never pays RDMA.
    for row in table.telemetry():
        assert row["local"].rdma_ops == 0


def test_remote_clients_pay_bounded_rdma_and_telemetry_records_it():
    mem, table = make_table(num_hosts=2, num_shards=2)
    k = key_homed_on(table, 0)
    p = mem.spawn(1)  # remote w.r.t. the key's shard
    lease = table.try_acquire(p, k, ttl=5.0)
    assert lease is not None
    assert 0 < p.counts.rdma_ops <= 12, vars(p.counts)
    totals = table.class_totals()
    assert totals[REMOTE].rdma_ops == p.counts.rdma_ops
    assert totals[LOCAL].rdma_ops == 0


# ------------------------------------------------------- service delegation
def test_service_delegates_to_table_and_keeps_named_locks():
    clock = FakeClock()
    svc = CoordinationService(num_hosts=4, num_shards=8, clock=clock)
    p0, p1 = svc.host_process(0), svc.host_process(1)

    lease = svc.try_acquire(p0, "ckpt/manifest", ttl=5.0)
    assert lease is not None
    assert svc.try_acquire(p1, "ckpt/manifest", ttl=5.0) is None
    assert svc.release(p0, lease)

    batch = svc.acquire_batch(p1, ["a", "b", "c"], ttl=5.0, timeout=5.0)
    assert len(batch) == 3
    assert svc.release_batch(p1, batch) == 3

    rows = svc.telemetry()
    assert len(rows) == 8
    assert sum(r["grants"] for r in rows) == 4
    assert svc.home_of("a") == rows[svc.shard_of("a")]["home_host"]

    # legacy named-lock surface still works alongside the table
    assert svc.elect("writer", p0, epoch=1)
    assert not svc.elect("writer", p1, epoch=1)
