"""Property test (satellite): writer fencing tokens stay strictly monotonic
per key under arbitrary interleavings of shared grants, upgrades, expiries,
releases, downgrades and zombie renewals.

Hypothesis drives a random op sequence against one key of a mode-aware
table on a fake clock.  The invariants checked after every step:

* every EXCLUSIVE grant (acquire or upgrade) carries a token strictly
  larger than every token previously seen for the key;
* every SHARED grant carries a token no smaller than the largest WRITER
  token seen (reader generations reuse the last allocated token, never an
  older one);
* a renewal never changes a lease's token (fencing identity is immutable);
* a zombie renewal — renewing a lease whose key has since been re-granted
  in exclusive mode — never succeeds once the token moved on.
"""

import dataclasses
import random

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import AsymmetricMemory  # noqa: E402
from repro.coord import LeaseMode, ShardedLockTable  # noqa: E402


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


KEY = "contested"
TTL = 5.0

# Op space: (kind, actor index, magnitude).  Magnitude seeds clock advances
# and which held/retired lease an op targets.
OPS = ("acquire_shared", "acquire_exclusive", "renew", "renew_zombie",
       "release", "release_zombie", "upgrade", "downgrade", "advance")

ops_strategy = st.lists(
    st.tuples(st.sampled_from(OPS), st.integers(0, 2), st.integers(0, 7)),
    min_size=4, max_size=40,
)


@settings(max_examples=60, deadline=None)
@given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
def test_writer_tokens_strictly_monotonic_under_mode_interleavings(ops, seed):
    rng = random.Random(seed)
    clock = FakeClock()
    mem = AsymmetricMemory(3)
    table = ShardedLockTable(mem, num_shards=2, clock=clock)
    procs = [mem.spawn(h) for h in range(3)]

    held = {i: [] for i in range(3)}     # live-ish lease objects per actor
    retired = []                          # released/expired objects (zombies)
    max_token = 0        # largest token ever seen on the key
    max_writer_token = 0  # largest EXCLUSIVE token ever granted

    def saw_grant(lease, exclusive):
        nonlocal max_token, max_writer_token
        if exclusive:
            assert lease.token > max_token, (
                f"writer token {lease.token} did not exceed max seen "
                f"{max_token}")
            max_writer_token = lease.token
        else:
            assert lease.token >= max_writer_token, (
                f"reader generation token {lease.token} regressed below "
                f"writer token {max_writer_token}")
        max_token = max(max_token, lease.token)

    for kind, actor, mag in ops:
        p = procs[actor]
        if kind == "advance":
            clock.t += (mag + 1) * TTL / 6  # sometimes past expiry
            continue
        if kind == "acquire_shared":
            lease = table.try_acquire(p, KEY, TTL, mode=LeaseMode.SHARED)
            if lease is not None:
                saw_grant(lease, exclusive=False)
                held[actor].append(lease)
        elif kind == "acquire_exclusive":
            lease = table.try_acquire(p, KEY, TTL)
            if lease is not None:
                saw_grant(lease, exclusive=True)
                held[actor].append(lease)
        elif kind == "renew" and held[actor]:
            lease = held[actor][mag % len(held[actor])]
            renewed = table.renew(p, lease)
            if renewed is not None:
                assert renewed.token == lease.token, "renewal changed a token"
                held[actor][held[actor].index(lease)] = renewed
        elif kind == "renew_zombie" and retired:
            owner, lease = retired[mag % len(retired)]
            renewed = table.renew(procs[owner], lease)
            if renewed is not None:
                # Only legal if the token never moved on past this lease's
                # generation — i.e. no exclusive grant fenced it out.
                assert lease.token >= max_writer_token, (
                    "a fenced-out zombie renewal succeeded")
        elif kind == "release" and held[actor]:
            lease = held[actor].pop(mag % len(held[actor]))
            table.release(p, lease)
            retired.append((actor, lease))
        elif kind == "release_zombie" and retired:
            owner, lease = retired[mag % len(retired)]
            table.release(procs[owner], lease)  # must be harmless (no assert:
            # the double release either no-ops or frees a still-current slot)
        elif kind == "upgrade" and held[actor]:
            shared = [l for l in held[actor] if l.mode == LeaseMode.SHARED]
            if shared:
                lease = shared[mag % len(shared)]
                up = table.upgrade(p, lease)
                if up is not None:
                    saw_grant(up, exclusive=True)
                    held[actor][held[actor].index(lease)] = up
        elif kind == "downgrade" and held[actor]:
            excl = [l for l in held[actor] if l.mode == LeaseMode.EXCLUSIVE]
            if excl:
                lease = excl[mag % len(excl)]
                down = table.downgrade(p, lease)
                if down is not None:
                    assert down.token == lease.token, "downgrade minted a token"
                    held[actor][held[actor].index(lease)] = down
        # Retire anything whose own horizon lapsed (the zombie pool).
        for i in range(3):
            for lease in list(held[i]):
                if clock.t >= lease.expires_at:
                    held[i].remove(lease)
                    retired.append((i, lease))

    # Final sweep: the authoritative fence register never regressed either.
    shard = table.shards[table.shard_of(KEY)]
    state = shard.keys.get(KEY)
    if state is not None:
        fence = state.fence._value
        assert fence >= max_writer_token
        assert fence == max_token


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_forged_tokens_never_validate(seed):
    """Fuzzed fencing: leases with perturbed tokens must never renew,
    release, upgrade or downgrade successfully."""
    rng = random.Random(seed)
    clock = FakeClock()
    mem = AsymmetricMemory(2)
    table = ShardedLockTable(mem, num_shards=2, clock=clock)
    p = mem.spawn(0)
    mode = LeaseMode.SHARED if rng.random() < 0.5 else LeaseMode.EXCLUSIVE
    lease = table.try_acquire(p, KEY, TTL, mode=mode)
    assert lease is not None
    delta = rng.choice([-2, -1, 1, 2, 100])
    forged = dataclasses.replace(lease, token=lease.token + delta)
    assert table.renew(p, forged) is None
    assert table.release(p, forged) is False
    if mode == LeaseMode.SHARED:
        assert table.upgrade(p, forged) is None
    else:
        assert table.downgrade(p, forged) is None
    # The genuine lease is untouched by the forgery attempts.
    assert table.renew(p, lease) is not None
