"""`AsymmetricMemory.post_batch` edge cases (ISSUE 4 satellite).

The WR-list model has three load-bearing edges: an empty posting must be a
true no-op (no doorbell, no completions), a malformed or node-spanning list
must be rejected *before any entry executes* (applied-but-unaccounted WRs
would corrupt the cost claims), and the doorbell-vs-completion accounting
must stay exact under arbitrary interleavings of batched and individual ops
— completions are the paper's cost unit, doorbells are what coalescing
saves, and neither may drift.
"""

import random

import pytest

from repro.core import AsymmetricMemory, OperationNotEnabled


def setup_mem(num_nodes=3):
    mem = AsymmetricMemory(num_nodes)
    regs = [mem.alloc(0, f"r{i}", i) for i in range(4)]
    other = mem.alloc(1, "other", 99)
    return mem, regs, other


# ------------------------------------------------------------ empty posting
def test_empty_wr_list_is_a_true_noop():
    mem, regs, _ = setup_mem()
    p = mem.spawn(1)
    sched_calls = []
    mem._sched = lambda *a: sched_calls.append(a)
    assert mem.post_batch(p, []) == []
    assert mem.post_batch(p, iter(())) == []  # any iterable, not just list
    assert p.counts.as_tuple() == (0,) * 9  # no doorbell, no completions
    assert sched_calls == []  # no doorbell ring even at the sched hook level


# -------------------------------------------- validation precedes execution
def test_cross_node_list_rejected_before_any_entry_executes():
    mem, regs, other = setup_mem()
    p = mem.spawn(2)
    with pytest.raises(ValueError, match="one queue pair"):
        mem.post_batch(p, [("write", regs[0], 555), ("read", other)])
    # The leading (well-formed, same-node) write must NOT have been applied.
    assert mem.rread(p, regs[0]) == 0
    # ...and nothing was accounted beyond that verification read.
    assert p.counts.rdma_ops == 1 and p.counts.remote_doorbell == 1


@pytest.mark.parametrize("bad", [
    ("read",),                      # missing register
    ("write",),                     # missing register and value
    ("cas",),                       # bare op
    (),                             # empty work request
])
def test_short_wr_tuples_rejected_upfront_as_valueerror(bad):
    mem, regs, _ = setup_mem()
    p = mem.spawn(1)
    with pytest.raises(ValueError, match="malformed work request"):
        mem.post_batch(p, [("write", regs[1], 7), bad])
    assert mem.rread(p, regs[1]) == 1  # leading write not applied


@pytest.mark.parametrize("bad", [
    ("read", None, None),           # wrong arity for read
    ("write", None),                # wrong arity for write
    ("cas", None, 1),               # wrong arity for cas
    ("swap", None, 1, 2),           # unknown opcode
])
def test_malformed_wr_arity_rejected_upfront(bad):
    mem, regs, _ = setup_mem()
    p = mem.spawn(1)
    wr = (bad[0], regs[2]) + tuple(bad[2:]) if len(bad) > 2 else (bad[0], regs[2])
    with pytest.raises(ValueError, match="malformed work request"):
        mem.post_batch(p, [("write", regs[1], 7), wr])
    assert mem.rread(p, regs[1]) == 1  # leading write not applied
    assert p.counts.remote_write == 0


def test_local_poster_rejected_with_no_side_effects():
    mem, regs, _ = setup_mem()
    local = mem.spawn(0)
    with pytest.raises(OperationNotEnabled, match="own node"):
        mem.post_batch(local, [("write", regs[0], 123)])
    assert local.counts.as_tuple() == (0,) * 9
    remote = mem.spawn(1)
    assert mem.rread(remote, regs[0]) == 0


# ------------------------------------------- doorbell/completion invariants
def test_doorbell_and_completion_accounting_invariants():
    """Over any mix of batched and individual remote ops:

    * ``remote_doorbell`` == number of non-empty postings + individual ops,
    * completions (``rdma_ops``) == total work requests,
    * batching never changes completion counts, only doorbell counts.
    """
    mem, regs, _ = setup_mem()
    p = mem.spawn(1)
    rng = random.Random(0)
    postings = 0
    wrs_total = 0
    per_class = {"read": 0, "write": 0, "cas": 0}
    for _ in range(50):
        if rng.random() < 0.5:
            n = rng.randint(1, 6)
            wrs = []
            for _ in range(n):
                reg = rng.choice(regs)
                op = rng.choice(("read", "write", "cas"))
                wrs.append({"read": ("read", reg),
                            "write": ("write", reg, rng.randint(0, 9)),
                            "cas": ("cas", reg, 0, 1)}[op])
                per_class[op] += 1
            out = mem.post_batch(p, wrs)
            assert len(out) == n  # one result per WR, even for writes
            postings += 1
            wrs_total += n
        else:
            reg = rng.choice(regs)
            op = rng.choice(("read", "write", "cas"))
            if op == "read":
                mem.rread(p, reg)
            elif op == "write":
                mem.rwrite(p, reg, rng.randint(0, 9))
            else:
                mem.rcas(p, reg, 0, 1)
            per_class[op] += 1
            postings += 1
            wrs_total += 1
    assert p.counts.remote_doorbell == postings
    assert p.counts.rdma_ops == wrs_total
    assert p.counts.remote_read == per_class["read"]
    assert p.counts.remote_write == per_class["write"]
    assert p.counts.remote_cas == per_class["cas"]
    assert p.counts.local_ops == 0  # a remote poster never goes local


def test_single_wr_batch_costs_same_doorbells_as_individual_post():
    mem, regs, _ = setup_mem()
    a, b = mem.spawn(1), mem.spawn(1)
    mem.post_batch(a, [("cas", regs[0], 0, 5)])
    mem.rcas(b, regs[0], 5, 0)
    assert a.counts.as_tuple() == b.counts.as_tuple()
