"""Virtual-time discrete-event engine + sim lock-table workloads.

Covers the engine's scheduling contract (time order, seeded determinism,
livelock guard), the clock/sleep plumbing bugfixes (table poll loops and
barrier deadlines must run on the *injected* time base), and the sim
benchmark's headline guarantees: byte-identical counters per seed, zero
LOCAL-class RDMA at scale, and fencing invariants under a failover storm.
"""

import time

import pytest

from repro.coord import Barrier, CoordinationService, ShardedLockTable
from repro.coord.table import LOCAL, REMOTE
from repro.sim import (FabricLatency, SimEngine, SimFabricMemory,
                       SimLivelockError, VirtualClock, run_lock_table_sim)


# ------------------------------------------------------------------- engine
def test_tasks_run_in_virtual_time_order():
    eng = SimEngine(seed=0)
    trace = []

    def task(name, delays):
        for d in delays:
            trace.append((name, round(eng.clock.now, 9)))
            yield d

    eng.spawn(task("a", [3e-3, 1e-3]), delay=1e-3)
    eng.spawn(task("b", [1e-3, 1e-3]), delay=2e-3)
    eng.run()
    assert trace == [
        ("a", 1e-3), ("b", 2e-3), ("b", 3e-3), ("a", 4e-3),
    ]


def test_same_seed_same_interleaving_different_seed_differs():
    def order_for(seed):
        eng = SimEngine(seed=seed)
        order = []

        def task(i):
            order.append(i)
            yield 0

        for i in range(20):
            eng.spawn(task(i))  # all due at t=0: pure tie-break territory
        eng.run()
        return order

    assert order_for(7) == order_for(7)
    assert order_for(7) != order_for(8)


def test_step_charges_extend_only_the_charging_tasks_timeline():
    """A step's virtual-time charges must not serialise other tasks behind
    it: two clients charging 1 ms each still both finish by ~1 ms."""
    eng = SimEngine(seed=0)
    ends = {}

    def worker(name):
        eng.clock.advance(1e-3)  # a modeled 1 ms operation
        yield 0
        ends[name] = eng.clock.now

    eng.spawn(worker("a"))
    eng.spawn(worker("b"))
    eng.run()
    assert ends["a"] == pytest.approx(1e-3)
    assert ends["b"] == pytest.approx(1e-3)  # overlapped, not 2 ms


def test_run_until_and_max_events():
    eng = SimEngine(seed=0)

    def ticker():
        while True:
            yield 1.0

    eng.spawn(ticker())
    assert eng.run(until=5.5) == 5.5
    with pytest.raises(SimLivelockError, match="max_events"):
        eng.run(max_events=3)


def test_clock_rejects_negative_advance_and_negative_yield():
    clock = VirtualClock()
    with pytest.raises(ValueError):
        clock.advance(-1.0)
    eng = SimEngine(seed=0)

    def bad():
        yield -1e-3

    eng.spawn(bad())
    with pytest.raises(ValueError):
        eng.run()


def test_sleep_inline_budget_is_virtual_time_not_iterations():
    """Regression: a timeout-bounded poll loop may legitimately need more
    sleep-polls than spin_limit (60 s / 0.5 ms = 120k); the sleep guard must
    budget virtual seconds, tripping only on horizon-scale (unbounded)
    sleeping."""
    eng = SimEngine(seed=0, spin_limit=100, sleep_horizon=3600.0)
    for _ in range(5_000):  # 50x spin_limit iterations, 5 virtual seconds
        eng.sleep_inline(1e-3)
    assert eng.clock.now == pytest.approx(5.0)
    with pytest.raises(SimLivelockError, match="sleep_horizon"):
        for _ in range(4_000_000):
            eng.sleep_inline(1e-3)  # an unbounded poll loop: past 1 h virtual


def test_yield_point_livelock_guard_trips_deterministically():
    eng = SimEngine(seed=0, spin_limit=50)

    def spinner():
        while True:  # a cross-task wait that can never be satisfied mid-step
            eng.yield_point()
        yield  # pragma: no cover - makes this a generator

    eng.spawn(spinner())
    with pytest.raises(SimLivelockError, match="spin iterations"):
        eng.run()
    assert eng.spins == 51  # limit + the tripping call: exact, not timing


# ------------------------------------------- clock/sleep plumbing (bugfixes)
def test_table_poll_backoff_runs_on_the_injected_sleep():
    """Regression (ISSUE 4 satellite): `acquire` mixed an injected clock for
    the deadline with wall-clock time.sleep for the backoff.  With a virtual
    clock + charging sleep the timeout must fire in virtual time — i.e.
    instantly in wall time — instead of stalling the poll loop forever."""
    eng = SimEngine(seed=0)
    mem = SimFabricMemory(2, eng)
    table = ShardedLockTable(mem, num_shards=4, clock=eng.clock,
                             sleep=eng.sleep_inline)
    holder, waiter = mem.spawn(0), mem.spawn(1)
    assert table.try_acquire(holder, "k", ttl=1e9) is not None
    wall0 = time.perf_counter()
    with pytest.raises(TimeoutError):
        table.acquire(waiter, "k", ttl=1.0, timeout=0.05)
    assert time.perf_counter() - wall0 < 1.0  # virtual wait, not wall wait
    assert eng.clock.now > 0.05  # the backoff charged the virtual clock


def test_batch_poll_backoff_runs_on_the_injected_sleep():
    eng = SimEngine(seed=0)
    mem = SimFabricMemory(2, eng)
    table = ShardedLockTable(mem, num_shards=4, clock=eng.clock,
                             sleep=eng.sleep_inline)
    holder, waiter = mem.spawn(0), mem.spawn(1)
    keys = [f"b/{i}" for i in range(4)]
    blocked = table.batch_order(keys)[2]
    assert table.try_acquire(holder, blocked, ttl=1e9) is not None
    with pytest.raises(TimeoutError):
        table.acquire_batch(waiter, keys, ttl=1.0, timeout=0.05)
    # rollback released the earlier keys despite the virtual-time timeout
    for k in table.batch_order(keys):
        if k != blocked:
            assert table.try_acquire(waiter, k, ttl=1.0) is not None


def test_barrier_timeout_uses_the_service_clock():
    """Regression (ISSUE 4 satellite): Barrier.wait hardcoded time.monotonic
    for its deadline even when the service was built with a custom clock."""
    clock = VirtualClock()
    svc = CoordinationService(
        num_hosts=2, num_shards=4, clock=clock,
        sleep=clock.advance, yield_point=lambda: clock.advance(0.5),
    )
    bar = Barrier(svc, "epoch", parties=2)
    p = svc.host_process(0)
    wall0 = time.perf_counter()
    with pytest.raises(TimeoutError, match="barrier timeout"):
        bar.wait(p, timeout=10.0)  # 10 *virtual* seconds
    assert time.perf_counter() - wall0 < 1.0
    assert clock.now > 10.0


# -------------------------------------------------------- sim bench results
SMALL = dict(num_hosts=8, clients_per_host=4, num_shards=16, total_ops=3000)


@pytest.mark.parametrize("workload", ["home", "uniform", "zipfian",
                                      "failover", "read_heavy",
                                      "reader_flood"])
def test_sim_workloads_are_deterministic_per_seed(workload):
    a = run_lock_table_sim(workload, seed=5, **SMALL)
    b = run_lock_table_sim(workload, seed=5, **SMALL)
    assert a.row() == b.row()
    # wall time is the one field allowed (expected, even) to differ
    assert a.ops >= SMALL["total_ops"]


def test_sim_different_seeds_explore_different_histories():
    a = run_lock_table_sim("zipfian", seed=0, **SMALL)
    b = run_lock_table_sim("zipfian", seed=1, **SMALL)
    assert a.row() != b.row()
    # ...but the invariants hold in every history
    for r in (a, b):
        assert r.cost["local"]["remote_cas"] == 0
        assert r.cost["local"]["remote_read"] == 0
        assert r.cost["local"]["remote_write"] == 0
        assert r.token_regressions == 0


def test_sim_home_workload_is_entirely_rdma_free():
    r = run_lock_table_sim("home", seed=2, **SMALL)
    # Placement-aware clients: the REMOTE class never even appears.
    assert all(v == 0 for v in r.cost["remote"].values()), r.cost
    assert r.ops == r.grants  # one grant per counted op, none lost


def test_sim_zipfian_contention_shows_up_as_rejects_not_unfairness_collapse():
    r = run_lock_table_sim("zipfian", seed=3, zipf_s=1.2, **SMALL)
    assert r.rejects > 0  # hot keys actually contended
    assert 0.5 < r.jain <= 1.0
    assert r.ops >= SMALL["total_ops"]


def test_sim_failover_storm_expires_and_fences():
    r = run_lock_table_sim("failover", seed=4, crash_prob=0.3, **SMALL)
    assert r.expirations > 0          # crashed holders' leases lapsed
    assert r.zombie_renews == 0       # every woken zombie was fenced off
    assert r.token_regressions == 0   # grant tokens strictly monotonic
    assert r.fast_renews > 0          # healthy holders used the fast path
    assert r.grants >= r.ops


def test_sim_scale_smoke_64_hosts():
    """A shrunken version of the acceptance sweep: 64 hosts x 4 clients,
    10k zipfian ops, must finish fast and RDMA-free for the LOCAL class."""
    wall0 = time.perf_counter()
    r = run_lock_table_sim("zipfian", num_hosts=64, clients_per_host=4,
                           num_shards=128, total_ops=10_000, seed=0)
    assert time.perf_counter() - wall0 < 60.0
    assert r.ops >= 10_000
    assert r.cost["local"]["remote_cas"] == 0
    assert r.cost["local"]["remote_read"] == 0
    assert r.cost["local"]["remote_write"] == 0
    assert r.num_hosts * r.clients_per_host == 256  # tasks actually at scale


def test_sim_fabric_prices_doorbells_not_work_requests():
    """One posting of N WRs must cost one doorbell charge + N WR charges —
    cheaper than N postings; and virtual charges never touch wall time."""
    lat = FabricLatency(local_op=1e-6, doorbell=10e-6, wr=1e-6)
    eng = SimEngine(seed=0)
    mem = SimFabricMemory(2, eng, lat)
    a = mem.alloc(0, "a", 0)
    b = mem.alloc(0, "b", 0)
    p = mem.spawn(1)
    t0 = eng.clock.now
    mem.post_batch(p, [("read", a), ("read", b)])
    batched = eng.clock.now - t0
    t1 = eng.clock.now
    mem.rread(p, a)
    mem.rread(p, b)
    individual = eng.clock.now - t1
    assert batched == pytest.approx(12e-6)
    assert individual == pytest.approx(22e-6)
    assert p.counts.remote_doorbell == 3
    assert p.counts.remote_read == 4


def test_sim_rejects_unknown_workload():
    with pytest.raises(ValueError, match="unknown sim workload"):
        run_lock_table_sim("renew", **SMALL)


# ------------------------------------------------------- mode-aware workloads
def test_sim_read_heavy_mode_counters_partition_and_local_stays_free():
    r = run_lock_table_sim("read_heavy", seed=6, write_frac=0.05, **SMALL)
    assert r.grants_shared > 0 and r.grants_exclusive > 0
    assert r.grants_shared + r.grants_exclusive == r.grants
    # The realised mix tracks the configured 95:5 (seeded draws, loose band).
    assert r.grants_shared / r.ops > 0.85
    assert r.cost["local"]["remote_cas"] == 0
    assert r.cost["local"]["remote_read"] == 0
    assert r.cost["local"]["remote_write"] == 0
    # Per-mode costs partition the per-class totals exactly.
    for cls in ("local", "remote"):
        for op, total in r.cost[cls].items():
            assert (r.mode_cost[f"shared_{cls}"][op]
                    + r.mode_cost[f"exclusive_{cls}"][op]) == total
    # The home-class reader claim: shared-mode LOCAL ops touch no fabric.
    assert all(v == 0 for k, v in r.mode_cost["shared_local"].items()
               if k.startswith("remote_"))


def test_sim_read_heavy_remote_shared_acquires_are_at_most_one_rcas():
    r = run_lock_table_sim("read_heavy", seed=7, write_frac=0.05, **SMALL)
    assert r.shared_remote_grants > 0
    assert r.shared_acquire_rcas <= r.shared_remote_grants  # ≤ 1 rCAS each


def test_sim_shared_reads_beat_exclusive_only_at_95_to_5():
    """A sim-scale slice of the acceptance sweep: same seed, same draws,
    shared readers vs every-op-exclusive — sharing must win clearly."""
    cfg = dict(num_hosts=8, clients_per_host=16, num_shards=16,
               total_ops=4000, keys_per_host=1, zipf_s=1.2, hold=100e-6,
               home_frac=0.9)
    shared = run_lock_table_sim("read_heavy", seed=1, write_frac=0.05, **cfg)
    excl = run_lock_table_sim("read_heavy", seed=1, write_frac=0.05,
                              shared_reads=False, **cfg)
    assert excl.grants_shared == 0  # the degraded baseline is exclusive-only
    assert shared.virtual_throughput > 2.5 * excl.virtual_throughput


def test_sim_reader_flood_cannot_starve_the_writer():
    """The satellite claim: a saturating reader flood on ONE key leaves the
    queued writer with bounded grant latency in virtual time (the
    run itself asserts max wait <= 10*ttl; we pin tighter numbers here)."""
    r = run_lock_table_sim("reader_flood", seed=8, **SMALL)
    assert r.writer_grants >= 3          # the writer kept making progress
    assert r.writer_max_wait <= 5 * 300e-6   # well inside the drain bound
    assert r.grants_shared > 50 * r.writer_grants  # the flood was saturating
    assert r.intent_blocks > 0           # the drain barrier actually engaged
    assert r.token_regressions == 0
