"""Overload-safe client stack: deadline propagation, retry budgets, circuit
breakers, hedged probes, graceful shedding — and their composition rules
(breaker evidence is SUSPECT, never DEAD; brownout keeps shared reads
flowing; a mid-batch RemoteTimeout rolls the whole batch back).

Everything here is deterministic: fake or virtual clocks, seeded RNGs, and
the sim engine's atomic steps.  The CI ``overload-smoke`` job re-runs the
storm legs at scale; these tests pin each mechanism in isolation.
"""

import json
import random

import pytest

from repro.core import (TIMEOUT, AsymmetricMemory, DeadlineExceeded,
                        Overloaded, RemoteTimeout)
from repro.coord import (ALIVE, DEAD, SUSPECT, CircuitBreaker,
                         CoordinationService, FaultInjector, LatencyTracker,
                         LeaseMode, LedgerStore, OverloadControl,
                         OverloadPolicy, RecoverableClient, RetryBudget,
                         ShardedLockTable, SuspicionEstimator,
                         SuspicionPolicy)
from repro.launch.serve import BatchAdmission
from repro.sim import SimEngine, run_lock_table_sim
from repro.sim.fabric import FabricFaults, FabricLatency, SimFabricMemory

TTL = 5.0


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_table(num_hosts=1, num_shards=4, clock=None, sleep=None, **kw):
    mem = AsymmetricMemory(num_hosts)
    table = ShardedLockTable(mem, num_shards=num_shards, clock=clock,
                             sleep=sleep, **kw)
    return mem, table


def sim_stack(num_hosts=2, num_shards=2, seed=0, overload=None, **fault_kw):
    engine = SimEngine(seed)
    faults = FabricFaults(seed=seed, **fault_kw)
    mem = SimFabricMemory(num_hosts, engine, FabricLatency(), faults=faults)
    table = ShardedLockTable(mem, num_shards=num_shards, clock=engine.clock,
                             sleep=engine.sleep_inline, seed=seed,
                             overload=overload)
    return engine, faults, mem, table


# ------------------------------------------------------- deadline propagation
class TestDeadlinePropagation:
    def test_expired_deadline_fails_fast_on_every_op(self):
        clock = FakeClock(10.0)
        mem, table = make_table(clock=clock)
        p = mem.spawn(0)
        lease = table.try_acquire(p, "k", TTL)
        assert lease is not None
        for op in (
            lambda: table.acquire(p, "other", TTL, deadline=9.0),
            lambda: table.acquire_batch(p, ["a", "b"], TTL, deadline=9.0),
            lambda: table.renew(p, lease, deadline=9.0),
            lambda: table.release(p, lease, deadline=9.0),
            lambda: table.reclaim(p, lease, deadline=9.0),
        ):
            before = p.counts.as_tuple()
            with pytest.raises(DeadlineExceeded):
                op()
            # Fail fast means ZERO ops — nothing was posted anywhere.
            assert p.counts.as_tuple() == before
        # The typed refusal is a TimeoutError subclass: legacy handlers
        # (batch rollback, callers with blanket patience handling) work.
        assert issubclass(DeadlineExceeded, TimeoutError)
        assert sum(row["deadline_exceeded"] for row in table.telemetry()) >= 5

    def test_backoff_sleeps_clamp_to_remaining_budget(self):
        clock = FakeClock()
        sleeps = []

        def sleep(dt):
            sleeps.append(dt)
            clock.advance(dt)

        mem, table = make_table(clock=clock, sleep=sleep)
        holder = mem.spawn(0)
        assert table.try_acquire(holder, "hot", 1000.0) is not None
        p = mem.spawn(0)
        with pytest.raises(DeadlineExceeded):
            table.acquire(p, "hot", 1000.0, poll=2.0, deadline=5.0)
        # Unclamped, the doubling ladder (jittered 1..3, 2..6, ...) would
        # overshoot 5.0 by whole poll intervals.  The clamp lands the clock
        # exactly on the deadline instead of past it.
        assert sleeps, "the blocked acquire never backed off"
        assert clock.t == pytest.approx(5.0)
        assert all(dt >= 0.0 for dt in sleeps)

    def test_legacy_timeout_path_keeps_plain_timeout_error(self):
        clock = FakeClock()
        mem, table = make_table(clock=clock, sleep=clock.advance)
        holder = mem.spawn(0)
        assert table.try_acquire(holder, "hot", 1000.0) is not None
        p = mem.spawn(0)
        with pytest.raises(TimeoutError) as exc:
            table.acquire(p, "hot", 1000.0, poll=0.5, timeout=3.0)
        assert not isinstance(exc.value, DeadlineExceeded)


# ------------------------------------------------- retry budgets and breakers
class TestRetryBudget:
    def test_spend_refill_bounds(self):
        b = RetryBudget(OverloadPolicy(budget_capacity=2.0,
                                       budget_refill=0.5))
        assert b.spend(1.0) and b.spend(1.0)
        assert not b.spend(1.0)          # dry: refused, tokens unchanged
        assert b.tokens == 0.0
        for _ in range(10):
            b.refill()
        assert b.tokens == 2.0           # capped at capacity

    def test_control_raises_typed_budget_refusal(self):
        ctl = OverloadControl(OverloadPolicy(budget_capacity=1.0))
        ctl.spend_retry(3)
        with pytest.raises(Overloaded) as exc:
            ctl.spend_retry(3)
        assert exc.value.reason == "budget" and exc.value.host == 3
        assert ctl.report()["budget_refusals"] == 1


class TestCircuitBreaker:
    POLICY = OverloadPolicy(breaker_min_samples=4, breaker_threshold=0.5,
                            breaker_cooldown=1.0, breaker_max_cooldown=4.0)

    def test_trips_refuses_and_recovers_through_half_open(self):
        br = CircuitBreaker(self.POLICY, random.Random(0))
        for _ in range(4):
            br.record(False, now=0.0)
        assert br.state == "open" and br.trips == 1
        assert not br.allow(0.0)          # refusing, zero fabric ops
        # After the (jittered, <= 1.5x) cooldown: exactly ONE trial probe.
        t = br.retry_at
        assert 0.75 <= t <= 1.5
        assert br.allow(t) and br.state == "half_open"
        assert not br.allow(t)            # second caller still refused
        br.record(True, now=t)
        assert br.state == "closed"       # trial won: closed, window reset
        assert br.allow(t)

    def test_failed_trial_reopens_with_longer_cooldown(self):
        br = CircuitBreaker(self.POLICY, random.Random(0))
        for _ in range(4):
            br.record(False, now=0.0)
        first_wait = br.retry_at
        assert br.allow(first_wait)
        br.record(False, now=first_wait)  # trial lost
        assert br.state == "open" and br.trips == 2
        # Exponential cooldown: the second OPEN waits ~2x the first.
        assert br.retry_at - first_wait > first_wait * 1.2

    def test_control_is_seed_deterministic(self):
        def trace(seed):
            ctl = OverloadControl(self.POLICY, seed=seed)
            out = []
            for host in (0, 1):
                for _ in range(4):
                    ctl.on_outcome(host, False, 0.0)
                out.append(round(ctl.breaker(host).retry_at, 12))
                try:
                    ctl.admit_remote(host, 0.0)
                except Overloaded as e:
                    out.append(e.reason)
            out.append(json.dumps(ctl.report(), sort_keys=True))
            return out

        assert trace(5) == trace(5)
        assert trace(5) != trace(6)

    def test_open_breaker_is_suspect_evidence_never_dead(self):
        # The membership composition rule: an open breaker means "slow or
        # unreachable FROM HERE" — it may suspect a host, but only missed
        # heartbeats are allowed to kill it.
        est = SuspicionEstimator(SuspicionPolicy(ttl=1.0))
        assert est.suspect(7, now=0.0) == SUSPECT
        for i in range(200):              # breaker stays open a long time
            est.suspect(7, now=0.1 * i)
        assert est._entry(7).verdict == SUSPECT
        assert all(new != DEAD for _t, _h, _old, new in est.transitions)
        for i in range(3):                # heartbeats return: full recovery
            est.beat(7, now=30.0 + i)
        assert est._entry(7).verdict == ALIVE


# -------------------------------------------------------------- hedged probes
class TestHedgedProbes:
    def test_latency_tracker_cold_then_quantile(self):
        tr = LatencyTracker(OverloadPolicy(hedge_min_samples=4,
                                           hedge_window=8))
        assert tr.threshold() == float("inf")
        for dt in (1.0, 2.0, 3.0, 4.0):
            tr.record(dt)
        assert tr.threshold() == 4.0
        for dt in range(100):             # ring stays bounded
            tr.record(float(dt))
        assert len(tr.samples) == 8

    def test_hedges_ride_the_retry_budget(self):
        ctl = OverloadControl(OverloadPolicy(budget_capacity=2.0,
                                             hedge_cost=1.0))
        assert ctl.allow_hedge(0) and ctl.allow_hedge(0)
        assert not ctl.allow_hedge(0)     # dry bucket: no speculative post
        assert ctl.report()["hedges"] == 2

    def test_probe_hedges_once_past_p99_and_wins(self):
        policy = OverloadPolicy(hedge_min_samples=4)
        # Host 1's link flaps across the first probe only: the first
        # posting is eaten (op timeout), but by then the link is back.
        engine, faults, mem, table = sim_stack(
            overload=policy, flaps=((1, 0.0, 50e-6),))
        ctl = table.overload
        p = mem.spawn(0)
        reg = mem.alloc(1, "w", 42)
        shard = table.shards[1]
        for _ in range(4):                # warm the p99 tracker
            ctl.observe_latency(1, 1e-6)
        # The flap eats the first posting -> op timeout >> p99 -> the probe
        # re-posts once, and the hedge (second posting) answers.
        assert table._probe(p, reg, shard) == 42
        assert shard.hedges == 1 and ctl.report()["hedges"] == 1
        assert faults.stats["probe_losses"] == 1

    def test_probe_does_not_hedge_when_budget_is_dry(self):
        policy = OverloadPolicy(hedge_min_samples=4)
        engine, faults, mem, table = sim_stack(
            overload=policy, flaps=((1, 0.0, 50e-6),))
        ctl = table.overload
        p = mem.spawn(0)
        reg = mem.alloc(1, "w", 42)
        shard = table.shards[1]
        for _ in range(4):
            ctl.observe_latency(1, 1e-6)
        ctl.budget(1).tokens = 0.0        # congested host: bucket is dry
        assert table._probe(p, reg, shard) is TIMEOUT
        assert shard.hedges == 0 and ctl.report()["hedges"] == 0


# ------------------------------------------------------------ congested hosts
class TestCongestion:
    def test_capacity_model_prices_bursts_deterministically(self):
        def burst(seed):
            engine, faults, mem, _ = sim_stack(
                seed=seed, congest_capacity=2, congest_delay=50e-6)
            p = mem.spawn(0)
            reg = mem.alloc(1, "w", 0)
            for i in range(12):
                mem.rwrite(p, reg, i)
            return engine.clock.now, dict(faults.stats)

        t_cong, stats = burst(0)
        assert stats["congested"] > 0
        engine, faults, mem, _ = sim_stack(seed=0)
        p = mem.spawn(0)
        reg = mem.alloc(1, "w", 0)
        for i in range(12):
            mem.rwrite(p, reg, i)
        assert t_cong > engine.clock.now  # congestion actually cost time
        assert burst(0) == (t_cong, stats)  # and is byte-deterministic

    def test_fabric_congest_point_forces_one_quantum(self):
        fi = FaultInjector().at("fabric.congest", nth=2)
        engine, faults, mem, _ = sim_stack(injector=fi)
        p = mem.spawn(0)
        reg = mem.alloc(1, "w", 0)
        mem.rwrite(p, reg, 1)
        mem.rwrite(p, reg, 2)             # exactly this posting queues
        assert faults.stats["congested"] == 1
        assert [f[0] for f in fi.fired] == ["fabric.congest"]


# ------------------------------------------------------------- load shedding
class TestFeasibilityShed:
    def _burned_table(self):
        """A table whose one shard has a warm time-to-completion EWMA
        (4.0s), learned the honest way: a blocked acquire burned its whole
        deadline budget against a held key."""
        clock = FakeClock()
        mem, table = make_table(num_shards=1, clock=clock,
                                sleep=clock.advance)
        holder = mem.spawn(0)
        assert table.try_acquire(holder, "hot", 1000.0) is not None
        p = mem.spawn(0)
        with pytest.raises(DeadlineExceeded):
            table.acquire(p, "hot", 1000.0, poll=0.5, deadline=4.0)
        shard = table.shards[0]
        assert shard.svc_time == pytest.approx(4.0)
        return clock, table, shard, holder, p

    def test_infeasible_deadline_sheds_before_posting(self):
        clock, table, shard, _holder, p = self._burned_table()
        before = p.counts.as_tuple()
        with pytest.raises(Overloaded) as exc:
            # remaining 5.0 < 1.5 * svc 4.0: statistically doomed.
            table.acquire(p, "hot", 1000.0, deadline=clock() + 5.0)
        assert exc.value.reason == "shed"
        assert p.counts.as_tuple() == before    # zero ops: a local refusal
        assert shard.sheds == 1

    def test_positive_priority_is_never_shed(self):
        clock, table, shard, _holder, p = self._burned_table()
        with pytest.raises(DeadlineExceeded):
            table.acquire(p, "hot", 1000.0, poll=0.5,
                          deadline=clock() + 5.0, priority=1)
        assert shard.sheds == 0                 # it burned, but wasn't shed

    def test_legacy_timeout_callers_are_never_shed(self):
        clock, table, shard, _holder, p = self._burned_table()
        with pytest.raises(TimeoutError) as exc:
            table.acquire(p, "hot", 1000.0, poll=0.5, timeout=5.0)
        assert not isinstance(exc.value, (DeadlineExceeded, Overloaded))
        assert shard.sheds == 0

    def test_completion_ewma_recovers_on_fast_grants(self):
        clock, table, shard, _holder, p = self._burned_table()
        # Let the holder's lease expire; quick grants then pull the EWMA
        # back down, so shedding relaxes when the overload drains.
        clock.advance(2000.0)
        svc0 = shard.svc_time
        lease = table.acquire(p, "hot", 1000.0, deadline=clock() + 100.0)
        assert lease is not None
        assert shard.svc_time < svc0


# ----------------------------------------------- admission brownout (serve)
class TestBatchAdmissionBrownout:
    def _adm(self):
        svc = CoordinationService(num_hosts=1, num_shards=4,
                                  overload=OverloadPolicy())
        return BatchAdmission(num_slots=2, ttl=30.0, svc=svc,
                              read_slots=2), svc.table.overload

    def test_open_breaker_sheds_exclusive_but_reads_flow(self):
        adm, ctl = self._adm()
        for _ in range(8):
            ctl.breaker(0).record(False, 0.0)
        assert ctl.breaker_open(0)
        with pytest.raises(Overloaded) as exc:
            adm.admit(timeout=0.0)
        assert exc.value.reason == "breaker"
        assert adm.stats()["sheds"] == 1
        # Brownout: the read lane is ungated — shared-mode reads keep
        # flowing while exclusive admissions shed.
        lease = adm.admit_read()
        assert lease is not None and lease.mode == LeaseMode.SHARED
        assert adm.complete(lease)

    def test_dry_budget_sheds_at_admission(self):
        adm, ctl = self._adm()
        ctl.budget(0).tokens = 0.0
        with pytest.raises(Overloaded) as exc:
            adm.admit(timeout=0.0)
        assert exc.value.reason == "budget"
        assert adm.stats()["sheds"] == 1

    def test_ungated_without_policy(self):
        adm = BatchAdmission(num_slots=2, ttl=30.0, read_slots=1)
        lease = adm.admit(timeout=0.0)
        assert lease is not None
        assert adm.complete(lease)
        assert adm.stats()["sheds"] == 0


# ----------------------------------------- mid-batch rollback under timeouts
class TestBatchRollbackUnderRemoteTimeout:
    def test_remote_timeout_mid_batch_leaves_no_orphan_grants(self):
        engine, faults, mem, table = sim_stack(num_shards=2)
        p = mem.spawn(0)
        store = LedgerStore()
        rc = RecoverableClient(table, p, store.ledger("victim"))
        k_local = next(f"k{i}" for i in range(64)
                       if table.shard_of(f"k{i}") == 0)
        k_remote = next(f"k{i}" for i in range(64)
                        if table.shard_of(f"k{i}") == 1)
        faults.fail_host(1, 0.0)
        # The local group grants, then the remote group's postings die at
        # the fabric: the table must roll the held prefix back.
        with pytest.raises(RemoteTimeout):
            rc.acquire_batch([k_remote, k_local], ttl=10.0, timeout=5.0)
        # No orphan grants: the local key is immediately grantable again
        # (a leaked lease would block this until TTL expiry).
        p2 = mem.spawn(0)
        lease2 = table.try_acquire(p2, k_local, 10.0)
        assert lease2 is not None
        # Ledger-reclaimable: RemoteTimeout is NOT a TimeoutError, so the
        # intents stay dangling — restart's orphan probe must resolve them
        # against the (released) words without adopting anything.  The
        # fabric heals first (a dead destination would eat the probe too).
        faults.dead.clear()
        restarted = mem.spawn(0)
        reclaimed = rc.restart(restarted)
        assert reclaimed == []
        view = rc.ledger.replay()
        assert k_local not in view.live and k_local not in view.intents
        # The fresh grant was never disturbed by the probe (fencing held).
        assert table.renew(p2, lease2) is not None

    def test_batch_mid_crash_crossed_with_congestion_cell(self):
        # The crash matrix's overload axis: a holder dies between two shard
        # groups of a batch WHILE the fabric is congesting postings — the
        # recovery path must hold under both at once.
        fi = (FaultInjector()
              .at("batch.mid", nth=5)
              .at("fabric.congest", nth=31))
        r = run_lock_table_sim(
            "crash_restart", fault=fi, num_hosts=8, clients_per_host=4,
            total_ops=3000, seed=5, failover_ttl=1e-3, crash_warmup=2e-3,
            crash_spacing=1e-3 / 8, restart_delay=1e-3 / 8)
        labels = {lab for lab, _pid, _n in fi.fired}
        assert "batch.mid" in labels, "the batch crash cell never armed"
        assert "fabric.congest" in labels, "the congestion cell never armed"
        assert r.fabric["congested"] >= 1
        assert r.token_regressions == 0
        assert r.zombie_renews == 0
        assert r.ops > 0 and r.crashes > 0


# --------------------------------------------------------- storm workload
class TestOverloadStormWorkload:
    CFG = dict(num_hosts=8, clients_per_host=2, num_shards=16,
               total_ops=1500, deadline_budget=600e-6)

    def test_storm_legs_are_seed_deterministic(self):
        def leg(shedding):
            r = run_lock_table_sim(
                "overload_storm", seed=3, offered_load=6.0,
                shedding=shedding,
                overload=OverloadPolicy() if shedding else None, **self.CFG)
            return json.dumps(r.row(), sort_keys=True)

        assert leg(True) == leg(True)
        assert leg(False) == leg(False)

    def test_shedding_leg_protects_goodput_and_brownout(self):
        r = run_lock_table_sim("overload_storm", seed=3, offered_load=6.0,
                               shedding=True, overload=OverloadPolicy(),
                               **self.CFG)
        assert r.storm_offered > r.ops // 2
        assert r.storm_goodput > 0
        assert r.storm_shed + r.sheds > 0, "overload never shed anything"
        # Brownout: the SHARED reader class (priority 1) is never shed and
        # keeps landing grants through the storm.
        assert r.storm_goodput_shared > 0
        assert r.token_regressions == 0 and r.zombie_renews == 0
        assert r.storm_acquire_p99 <= 1.5 * self.CFG["deadline_budget"]

    def test_control_leg_never_sheds(self):
        r = run_lock_table_sim("overload_storm", seed=3, offered_load=6.0,
                               shedding=False, overload=None, **self.CFG)
        assert r.sheds == 0 and r.storm_shed == 0
        assert r.hedges == 0 and r.breaker_trips == 0


# -------------------------------------------------- telemetry (satellite b)
class TestOverloadTelemetry:
    def test_hot_keys_surface_op_timeouts_and_fabric_retries(self):
        engine, faults, mem, table = sim_stack(num_shards=2)
        p = mem.spawn(0)
        key = next(f"k{i}" for i in range(64)
                   if table.shard_of(f"k{i}") == 1)
        faults.fail_host(1, 0.0)
        with pytest.raises(RemoteTimeout):
            table.try_acquire(p, key, 10.0)
        rows = table.hot_keys()
        row = next(r for r in rows if r[0] == key)
        assert len(row) == 4
        _key, _blocked, op_timeouts, fab_retries = row
        assert op_timeouts >= 1 and fab_retries >= 1

    def test_telemetry_carries_overload_counters(self):
        clock = FakeClock()
        mem, table = make_table(clock=clock, sleep=clock.advance)
        for row in table.telemetry():
            for k in ("sheds", "hedges", "deadline_exceeded", "timeouts",
                      "fabric_retries"):
                assert k in row
