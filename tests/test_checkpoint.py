"""Checkpointing: atomicity, integrity fallback, election, resume."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.coord import CoordinationService


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros((8,))},
        "opt": {"step": jnp.int32(7), "mu": {"w": jnp.ones((4, 8))}},
    }


def test_roundtrip(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 7, s, extra={"arch": "x"})
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    restored, step, extra = load_checkpoint(str(tmp_path), like)
    assert step == 7 and extra == {"arch": "x"}
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupted_latest_falls_back(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 1, s)
    save_checkpoint(str(tmp_path), 2, s)
    # corrupt the newest npz
    path = tmp_path / "step_00000002.npz"
    path.write_bytes(b"garbage" * 100)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    _, step, _ = load_checkpoint(str(tmp_path), like)
    assert step == 1


def test_checksum_mismatch_detected(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 3, s)
    # tamper with the manifest crc
    mpath = tmp_path / "step_00000003.json"
    m = json.loads(mpath.read_text())
    first = next(iter(m["arrays"]))
    m["arrays"][first]["crc"] += 1
    mpath.write_text(json.dumps(m))
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), s)
    with pytest.raises(Exception):
        load_checkpoint(str(tmp_path), like)


def test_shape_mismatch_rejected(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 1, s)
    bad = {
        "params": {"w": jax.ShapeDtypeStruct((5, 8), jnp.float32),
                   "b": jax.ShapeDtypeStruct((8,), jnp.float32)},
        "opt": {"step": jax.ShapeDtypeStruct((), jnp.int32),
                "mu": {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32)}},
    }
    with pytest.raises(ValueError, match="shape mismatch"):
        load_checkpoint(str(tmp_path), bad)


def test_manager_elects_single_writer_and_gcs(tmp_path):
    svc = CoordinationService(num_hosts=3)
    mgrs = [
        CheckpointManager(str(tmp_path), every=1, keep=2, svc=svc, host=h)
        for h in range(3)
    ]
    s = _state()
    for step in (1, 2, 3, 4):
        wrote = [m.maybe_save(step, s) for m in mgrs]
        assert sum(wrote) == 1, f"step {step}: {wrote}"
    for m in mgrs:
        m.wait()
    steps = sorted(
        int(f[len("step_"):-len(".json")])
        for f in os.listdir(tmp_path) if f.endswith(".json")
    )
    assert steps == [3, 4]  # keep=2 retention
