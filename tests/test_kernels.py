"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

FLASH_CASES = [
    # B, T, H, K, dk, dv, qb, kb, causal, window, dtype
    (2, 64, 4, 2, 32, 32, 16, 32, True, 0, jnp.float32),
    (1, 96, 8, 8, 64, 64, 32, 32, True, 24, jnp.float32),
    (2, 48, 4, 1, 16, 16, 16, 16, False, 0, jnp.float32),
    (1, 80, 4, 2, 32, 16, 32, 16, True, 0, jnp.bfloat16),  # MLA-style dk!=dv
    (1, 50, 2, 2, 16, 16, 16, 16, True, 0, jnp.float32),   # ragged T
    (3, 32, 6, 3, 8, 8, 32, 32, True, 0, jnp.float32),     # single block
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    B, T, H, K, dk, dv, qb, kb, causal, window, dt = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, dk), dt)
    k = jax.random.normal(ks[1], (B, T, K, dk), dt)
    v = jax.random.normal(ks[2], (B, T, K, dv), dt)
    out = ops.flash_attention(q, k, v, causal, window, qb, kb, None)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
    )


def test_flash_attention_grads_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, True, 0, 16, 16, None) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, causal=True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("B,T,W,tb,wb", [
    (2, 100, 48, 32, 16),
    (1, 64, 128, 64, 128),
    (3, 33, 20, 16, 8),
])
def test_rglru_kernel_matches_ref(B, T, W, tb, wb):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a = (jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, W))) * 0.6 + 0.3).astype(
        jnp.float32
    )
    b = (jax.random.normal(ks[1], (B, T, W)) * 0.1).astype(jnp.float32)
    h0 = (jax.random.normal(ks[2], (B, W)) * 0.1).astype(jnp.float32)
    from repro.kernels.rglru_scan import rglru_scan_fwd

    out = rglru_scan_fwd(a, b, h0, t_block=tb, w_block=wb, interpret=True)
    expect = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_rglru_kernel_grad_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 32, 16))) * 0.5 + 0.3
    b = jax.random.normal(ks[1], (1, 32, 16)) * 0.1
    h0 = jax.random.normal(ks[2], (1, 16)) * 0.1
    gk = jax.grad(lambda a: ops.rglru_scan(a, b, h0).sum())(a)
    gr = jax.grad(lambda a: ref.rglru_scan_ref(a, b, h0).sum())(a)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-5)


def test_online_attention_equals_kernel_contract():
    """The XLA online-softmax path (the dry-run implementation) and the
    Pallas kernel implement the same function."""
    from repro.models.attention import online_attention

    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, 64, 2, 32), jnp.float32)
    a = online_attention(q, k, v, causal=True, q_block=16, k_block=32)
    b = ops.flash_attention(q, k, v, True, 0, 16, 32, None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
