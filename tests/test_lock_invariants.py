"""ALock behaviour: mutual exclusion, cost claims, fairness (paper §3)."""

import random
import threading

import pytest

from repro.core import (
    ALock,
    AsymmetricMemory,
    FilterLock,
    NaiveRCASLock,
    RPCLock,
    make_scheduler,
)


def _hammer(mem, lock, nodes, iters=150, unlock=None):
    """Run one thread per entry of ``nodes``; returns (count, max_overlap)."""
    state = {"count": 0, "in": 0, "max": 0}
    guard_err = []

    def worker(node):
        p = mem.spawn(node)
        for _ in range(iters):
            lock.lock(p)
            state["in"] += 1
            state["max"] = max(state["max"], state["in"])
            state["count"] += 1  # non-atomic on purpose: CS protects it
            state["in"] -= 1
            (unlock or lock.unlock)(p)

    ts = [threading.Thread(target=worker, args=(n,)) for n in nodes]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not guard_err
    return state["count"], state["max"]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_alock_mutual_exclusion_stress(seed):
    rng = random.Random(seed)
    mem = AsymmetricMemory(3, sched=make_scheduler(rng, 0.2))
    lock = ALock(mem, home_node=0, init_budget=3)
    nodes = [0, 0, 0, 1, 1, 2]
    count, max_in = _hammer(mem, lock, nodes)
    assert max_in == 1, "two processes in the critical section"
    assert count == len(nodes) * 150, "lost update inside the CS"


def test_local_processes_use_zero_rdma_ops():
    mem = AsymmetricMemory(2)
    lock = ALock(mem, home_node=0)
    p = mem.spawn(0)
    for _ in range(10):
        lock.lock(p)
        lock.unlock(p)
    assert p.counts.rdma_ops == 0
    assert p.counts.local_ops > 0


def test_lone_remote_acquire_is_one_rcas_on_queue():
    """Paper: 'When the queue is empty, a lone process requires only a single
    rCAS to acquire the [cohort] lock'; the Peterson engagement adds one
    rWrite (victim) and rReads while waiting."""
    mem = AsymmetricMemory(2)
    lock = ALock(mem, home_node=0)
    p = mem.spawn(1)
    snap = p.counts.snapshot()
    lock.lock(p)
    d = p.counts.delta(snap)
    assert d.remote_cas == 1          # the single queue rCAS
    assert d.remote_write == 1        # victim := id
    snap = p.counts.snapshot()
    lock.unlock(p)
    d = p.counts.delta(snap)
    # Release: at worst rCAS + rWrite; lone process needs just the rCAS.
    assert d.remote_cas == 1 and d.remote_write == 0


def test_queued_remote_acquire_adds_one_rwrite_then_local_spin():
    """Queued acquire: +1 rWrite to link; spinning is on the OWN descriptor
    (local reads), so RDMA ops stay bounded regardless of wait time."""
    mem = AsymmetricMemory(3)
    lock = ALock(mem, home_node=0, init_budget=8)
    holder = mem.spawn(1)
    lock.lock(holder)

    waiter = mem.spawn(2)
    counts = {}
    done = threading.Event()

    def wait_thread():
        snap = waiter.counts.snapshot()
        lock.lock(waiter)
        counts["d"] = waiter.counts.delta(snap)
        lock.unlock(waiter)
        done.set()

    t = threading.Thread(target=wait_thread)
    t.start()
    # Let the waiter enqueue and spin for a while on its local descriptor.
    import time

    time.sleep(0.2)
    lock.unlock(holder)
    assert done.wait(5)
    t.join()
    d = counts["d"]
    assert d.remote_cas >= 1
    assert d.remote_write >= 1                   # the link write
    # Bounded remote ops despite ~0.2 s of spinning:
    assert d.rdma_ops <= 6, f"remote spinning detected: {vars(d)}"
    assert d.local_read > 10                     # local spin happened


def test_budget_bounds_same_class_hand_offs():
    """With budget B, a class hands off at most B times before pReacquire
    lets the other class in: no starvation of the remote class."""
    rng = random.Random(7)
    mem = AsymmetricMemory(2, sched=make_scheduler(rng, 0.1))
    lock = ALock(mem, home_node=0, init_budget=2)
    order = []
    stop = threading.Event()

    def local_worker():
        p = mem.spawn(0)
        while not stop.is_set():
            lock.lock(p)
            order.append("L")
            lock.unlock(p)

    def remote_worker(results):
        p = mem.spawn(1)
        lock.lock(p)
        order.append("R")
        lock.unlock(p)
        results.append(True)

    locals_ = [threading.Thread(target=local_worker) for _ in range(3)]
    for t in locals_:
        t.start()
    import time

    time.sleep(0.05)  # let locals saturate the lock
    res = []
    rt = threading.Thread(target=remote_worker, args=(res,))
    rt.start()
    rt.join(timeout=10)
    stop.set()
    for t in locals_:
        t.join()
    assert res, "remote process starved by local class"


def test_baselines_mutual_exclusion():
    rng = random.Random(3)
    mem = AsymmetricMemory(2, sched=make_scheduler(rng, 0.2))
    naive = NaiveRCASLock(mem, 0)
    count, max_in = _hammer(mem, naive, [0, 0, 1, 1], iters=80)
    assert max_in == 1 and count == 4 * 80

    mem2 = AsymmetricMemory(2, sched=make_scheduler(random.Random(4), 0.2))
    pids = []
    procs = [mem2.spawn(n) for n in (0, 0, 1, 1)]
    flock = FilterLock(mem2, 0, [p.pid for p in procs])
    state = {"in": 0, "max": 0, "count": 0}

    def fworker(p):
        for _ in range(60):
            flock.lock(p)
            state["in"] += 1
            state["max"] = max(state["max"], state["in"])
            state["count"] += 1
            state["in"] -= 1
            flock.unlock(p)

    ts = [threading.Thread(target=fworker, args=(p,)) for p in procs]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert state["max"] == 1 and state["count"] == 4 * 60


def test_rpc_lock_grants_fifo_and_counts_messages():
    mem = AsymmetricMemory(2)
    lock = RPCLock(mem, 0)
    try:
        count, max_in = _hammer(mem, lock, [0, 1], iters=50)
        assert max_in == 1 and count == 100
        # every acquisition costs a request+reply, release costs a message
        total = sum(lock.messages_sent.values())
        assert total == 2 * 100 + 100
    finally:
        lock.shutdown()


def test_naive_lock_charges_local_processes_rdma():
    """The contrast the paper draws: loopback forces RDMA ops on locals."""
    mem = AsymmetricMemory(1)
    lock = NaiveRCASLock(mem, 0)
    p = mem.spawn(0)
    lock.lock(p)
    lock.unlock(p)
    assert p.counts.rdma_ops >= 2  # rCAS + rWrite via loopback
