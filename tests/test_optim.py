"""AdamW math, clipping, schedules."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw_init, adamw_update, cosine_schedule, global_norm


def test_adamw_matches_hand_math():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.0
    newp, newst, _ = adamw_update(p, g, st, lr, b1=b1, b2=b2, eps=eps,
                                  weight_decay=wd, grad_clip=0.0)
    m = (1 - b1) * np.array([0.5, 0.5])
    v = (1 - b2) * np.array([0.25, 0.25])
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    expect = np.array([1.0, -2.0]) - lr * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=1e-6)
    assert int(newst.step) == 1


def test_weight_decay_decoupled_and_matrix_only():
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = jax.tree.map(jnp.zeros_like, p)
    st = adamw_init(p)
    newp, _, _ = adamw_update(p, g, st, lr=0.5, weight_decay=0.1, grad_clip=0.0)
    np.testing.assert_allclose(np.asarray(newp["w"]), 0.95 * np.ones((2, 2)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(newp["b"]), np.ones((2,)), rtol=1e-6)


def test_grad_clipping_scales_update():
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.array([30.0, 40.0, 0.0])}  # norm 50
    st = adamw_init(p)
    _, _, m = adamw_update(p, g, st, lr=0.1, grad_clip=1.0)
    np.testing.assert_allclose(float(m["grad_norm"]), 50.0, rtol=1e-6)


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == 5.0


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 0.06          # warmup peak
    assert lrs[99] < 0.2                       # decayed
    assert min(lrs[10:]) >= 0.1 - 1e-6         # floor
