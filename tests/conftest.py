import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidevice(code: str, devices: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a subprocess with N fake host devices.

    Tests in this process must see exactly one device (the dry-run is the
    only consumer of the 512-device flag), so anything needing a mesh runs
    out-of-process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice
