"""Elastic scaling: checkpoints are mesh-agnostic — train on mesh A, lose
devices, restore and continue on mesh B (deliverable: fault tolerance)."""

import pytest


@pytest.mark.slow
def test_remesh_restore_preserves_state(multidevice, tmp_path):
    out = multidevice(
        f"""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro.configs import get_config, ShapeConfig, RunConfig
from repro.models import Model, input_specs
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_train_step, init_train_state
from repro.checkpoint import save_checkpoint, load_checkpoint

ckpt_dir = {str(tmp_path)!r}
cfg = get_config('llama3.2-1b', smoke=True).with_overrides(dtype='float32')
run = RunConfig(sync_mode='flat', total_steps=20)
shp = ShapeConfig('t', 32, 8, 'train')
model = Model(cfg)

# Phase 1: train 2 steps on an 8-device (4, 2) mesh, checkpoint.
mesh_a = make_mesh((4, 2), ('data', 'model'))
with set_mesh(mesh_a):
    step, shapes, sh_a, bsh_a = build_train_step(model, run, mesh_a, shp)
    state = jax.device_put(init_train_state(model, run, jax.random.PRNGKey(0)), sh_a)
    batch = jax.device_put(input_specs(cfg, shp, concrete=True, dtype=jnp.float32), bsh_a)
    for _ in range(2):
        state, m1 = step(state, batch)
    host_state = jax.tree.map(np.asarray, state)
    save_checkpoint(ckpt_dir, 2, host_state)
    state, m_ref = step(state, batch)
    ref_loss = float(m_ref['loss'])

# Phase 2: "lose half the fleet" — restore on a (2, 2) mesh and continue.
mesh_b = make_mesh((2, 2), ('data', 'model'), devices=jax.devices()[:4])
with set_mesh(mesh_b):
    step_b, shapes_b, sh_b, bsh_b = build_train_step(model, run, mesh_b, shp)
    restored, step_no, _ = load_checkpoint(ckpt_dir, shapes_b, shardings=sh_b)
    batch_b = jax.device_put(input_specs(cfg, shp, concrete=True, dtype=jnp.float32), bsh_b)
    restored, m2 = step_b(restored, batch_b)
new_loss = float(m2['loss'])
assert step_no == 2
assert abs(new_loss - ref_loss) < 1e-4, (new_loss, ref_loss)
print('OK remesh', ref_loss, new_loss)
""",
        devices=8,
    )
    assert "OK remesh" in out
