"""Crash-recoverable lease stack: ledger replay, reclaim, orphan probes,
shard reconstruction, engine kill delivery, and the recovery workload."""

import json

import pytest

from repro.core import AsymmetricMemory
from repro.coord import (CRASH_POINTS, ClientCrash, CoordinationService,
                         FaultInjector, LeaseLedger, LedgerStore, LeaseMode,
                         RecoverableClient, ShardedLockTable, replay_records)
from repro.sim import SimEngine, run_lock_table_sim


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_stack(num_hosts=4, num_shards=8, clock=None, fault=None):
    mem = AsymmetricMemory(num_hosts)
    table = ShardedLockTable(mem, num_shards=num_shards, clock=clock,
                             fault=fault)
    return mem, table, LedgerStore()


# ------------------------------------------------------------------- ledger
def test_replay_folds_grant_renew_release():
    led = LeaseLedger("c")
    led.append("session", pid=1)
    led.append("intent", key="a", ttl=5.0, pid=1)
    led.append("grant", key="a", shard=0, token=3, mode=1, expires_at=10.0,
               ttl=5.0, pid=1)
    led.append("renew", key="a", shard=0, token=3, mode=1, expires_at=15.0,
               ttl=5.0, pid=1)
    view = led.replay()
    assert view.live["a"].expires_at == 15.0
    assert "a" not in view.intents
    assert view.pids == [1]
    led.append("release", key="a", token=3)
    assert led.replay().live == {}


def test_replay_renew_for_other_token_is_ignored():
    led = LeaseLedger("c")
    led.append("grant", key="a", token=3, expires_at=10.0)
    led.append("renew", key="a", token=2, expires_at=99.0)  # stale stream
    assert led.replay().live["a"].expires_at == 10.0


def test_replay_release_for_other_token_keeps_live():
    led = LeaseLedger("c")
    led.append("grant", key="a", token=3, expires_at=10.0)
    led.append("release", key="a", token=2)
    assert led.replay().live["a"].token == 3


def test_replay_is_idempotent_and_duplication_tolerant():
    led = LeaseLedger("c")
    led.append("session", pid=1)
    led.append("intent", key="a", ttl=5.0, pid=1)
    led.append("grant", key="a", token=1, expires_at=10.0, ttl=5.0, pid=1)
    led.append("intent", key="b", ttl=5.0, pid=1)
    v1, v2 = led.replay(), led.replay()
    assert v1.live.keys() == v2.live.keys()
    assert v1.intents.keys() == v2.intents.keys()
    # Crash-retry append: re-appending the most recent record changes nothing.
    recs = list(led.records)
    dup = replay_records(recs + [recs[-1]])
    assert dup.live.keys() == v1.live.keys()
    assert dup.intents.keys() == v1.intents.keys()
    assert dup.pids == v1.pids


def test_ledger_jsonl_round_trip(tmp_path):
    led = LeaseLedger("c")
    led.append("session", pid=7)
    led.append("grant", key="x", shard=2, token=9, mode=0, expires_at=1.5,
               ttl=0.5, pid=7)
    path = str(tmp_path / "ledger.jsonl")
    led.dump_jsonl(path)
    back = LeaseLedger.load_jsonl(path, name="c")
    assert back.records == led.records
    # The reloaded ledger appends after the highest persisted seq.
    rec = back.append("release", key="x", token=9)
    assert rec.seq == led.records[-1].seq + 1


def test_ledger_rejects_unknown_op():
    with pytest.raises(ValueError):
        LeaseLedger("c").append("frobnicate")


def _dumped_ledger(tmp_path):
    led = LeaseLedger("c")
    led.append("session", pid=7)
    led.append("grant", key="x", shard=2, token=9, mode=0, expires_at=1.5,
               ttl=0.5, pid=7)
    led.append("renew", key="x", token=9, expires_at=2.5, ttl=0.5, pid=7)
    path = str(tmp_path / "ledger.jsonl")
    led.dump_jsonl(path)
    with open(path, "rb") as f:
        return led, f.read()


def test_ledger_torn_tail_truncated_at_every_offset(tmp_path):
    # A crash mid-append tears the FINAL line at an arbitrary byte; every
    # such prefix must load as the ledger minus the torn record, with a
    # warning — the write-ahead intent covers the loss.
    led, data = _dumped_ledger(tmp_path)
    tail_start = data[:-1].rfind(b"\n") + 1
    torn = str(tmp_path / "torn.jsonl")
    for cut in range(tail_start + 1, len(data) - 1):
        with open(torn, "wb") as f:
            f.write(data[:cut])
        with pytest.warns(RuntimeWarning, match="torn final"):
            back = LeaseLedger.load_jsonl(torn, name="c")
        assert back.records == led.records[:-1], f"cut at byte {cut}"
        # The survivor keeps appending after the highest surviving seq.
        assert back.append("release", key="x", token=9).seq == \
            led.records[-2].seq + 1


def test_ledger_tail_edge_cases_are_not_tears(tmp_path):
    led, data = _dumped_ledger(tmp_path)
    tail_start = data[:-1].rfind(b"\n") + 1
    # Truncated exactly at the last line's start: a clean shorter ledger.
    clean = str(tmp_path / "clean.jsonl")
    with open(clean, "wb") as f:
        f.write(data[:tail_start])
    back = LeaseLedger.load_jsonl(clean, name="c")
    assert back.records == led.records[:-1]
    # Only the final newline missing: the record itself is whole.
    nonl = str(tmp_path / "nonl.jsonl")
    with open(nonl, "wb") as f:
        f.write(data[:-1])
    back = LeaseLedger.load_jsonl(nonl, name="c")
    assert back.records == led.records
    # An empty file is an empty ledger, not an error.
    empty = str(tmp_path / "empty.jsonl")
    with open(empty, "wb") as f:
        pass
    assert LeaseLedger.load_jsonl(empty, name="c").records == []


def test_ledger_corruption_mid_file_raises(tmp_path):
    # Append-only files do not tear in the middle: a mangled non-final
    # record is damage, not a crash artifact, and must refuse loudly.
    led, data = _dumped_ledger(tmp_path)
    lines = data.split(b"\n")
    lines[1] = lines[1][: len(lines[1]) // 2]
    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "wb") as f:
        f.write(b"\n".join(lines))
    with pytest.raises(ValueError, match="mid-file"):
        LeaseLedger.load_jsonl(bad, name="c")


# ------------------------------------------------------------------ reclaim
def test_reclaim_fast_path_keeps_token_and_retimes():
    clock = FakeClock()
    mem, table, store = make_stack(clock=clock)
    p1 = mem.spawn(0)
    rc = RecoverableClient(table, p1, store.ledger("c"))
    lease = rc.try_acquire("k", ttl=10.0)
    clock.advance(4.0)  # crash; restart well inside the lease
    got = rc.restart(mem.spawn(0))
    assert [l.key for l in got] == ["k"]
    assert got[0].token == lease.token
    assert got[0].holder_pid == p1.pid  # grant identity survives restart
    assert got[0].expires_at == clock() + 10.0
    rows = table.telemetry()
    assert sum(r["reclaim_fast"] for r in rows) == 1
    assert sum(r["reclaim_rejects"] for r in rows) == 0


def test_reclaim_word_probe_covers_stale_low_witness():
    # A renewal whose CAS landed but whose ledger record died with the
    # client: the ledger witness expires EARLIER than the word.  The fast
    # CAS misses; the CS-free word probe must still reclaim.
    clock = FakeClock()
    mem, table, store = make_stack(clock=clock)
    p1 = mem.spawn(0)
    rc = RecoverableClient(table, p1, store.ledger("c"))
    lease = rc.try_acquire("k", ttl=10.0)
    clock.advance(5.0)
    assert table.renew(p1, lease) is not None  # bypass rc: record "lost"
    clock.advance(7.0)  # ledger witness (exp t=10) is stale; word lives to 15
    got = rc.restart(mem.spawn(0))
    assert [l.key for l in got] == ["k"]
    assert got[0].token == lease.token
    rows = table.telemetry()
    assert sum(r["reclaim_slow"] for r in rows) == 1


def test_reclaim_rejects_expired_lease():
    clock = FakeClock()
    mem, table, store = make_stack(clock=clock)
    rc = RecoverableClient(table, mem.spawn(0), store.ledger("c"))
    rc.try_acquire("k", ttl=10.0)
    clock.advance(11.0)  # past the word's own expiry: dead, no resurrection
    got = rc.restart(mem.spawn(0))
    assert got == []
    assert "k" not in rc.ledger.replay().live  # tombstoned as lost
    rows = table.telemetry()
    assert sum(r["reclaim_rejects"] for r in rows) == 1
    assert sum(r["reclaims"] for r in rows) == 0


def test_reclaim_rejects_regranted_key_and_never_wedges_successor():
    clock = FakeClock()
    mem, table, store = make_stack(clock=clock)
    rc = RecoverableClient(table, mem.spawn(0), store.ledger("c"))
    rc.try_acquire("k", ttl=10.0)
    clock.advance(11.0)
    stranger = mem.spawn(1)
    s_lease = table.try_acquire(stranger, "k", ttl=10.0)
    assert s_lease is not None  # expired: re-granted with a larger token
    got = rc.restart(mem.spawn(0))
    assert got == []  # fencing: the world moved past our grant
    assert table.renew(stranger, s_lease) is not None  # successor unharmed


def test_shared_reclaim_readopts_cohort_slot():
    clock = FakeClock()
    mem, table, store = make_stack(clock=clock)
    rc = RecoverableClient(table, mem.spawn(0), store.ledger("c"))
    other = mem.spawn(1)
    mine = rc.try_acquire("k", ttl=10.0, mode=LeaseMode.SHARED)
    assert table.try_acquire(other, "k", ttl=10.0,
                             mode=LeaseMode.SHARED) is not None
    clock.advance(4.0)
    p2 = mem.spawn(0)
    got = rc.restart(p2)
    assert [l.key for l in got] == ["k"]
    assert got[0].mode == LeaseMode.SHARED
    assert got[0].token == mine.token  # same reader generation
    assert got[0].holder_pid == p2.pid  # slots are owned per live process
    # The re-adopted slot is a real slot: release decrements the cohort.
    assert rc.release(got[0])
    rows = table.telemetry()
    assert sum(r["reclaim_shared"] for r in rows) == 1


def test_shared_reclaim_rejects_past_slot_horizon():
    clock = FakeClock()
    mem, table, store = make_stack(clock=clock)
    rc = RecoverableClient(table, mem.spawn(0), store.ledger("c"))
    rc.try_acquire("k", ttl=10.0, mode=LeaseMode.SHARED)
    clock.advance(11.0)  # the slot died with its horizon
    assert rc.restart(mem.spawn(0)) == []


# ------------------------------------------------------------ orphan probes
def test_orphan_probe_adopts_unrecorded_grant():
    # Crash between the grant CAS and the grant record: the lease exists
    # under a dead pid with no ledger witness beyond the dangling intent.
    fi = FaultInjector().at("grant.pre_ledger")
    clock = FakeClock()
    mem = AsymmetricMemory(4)
    table = ShardedLockTable(mem, num_shards=8, clock=clock, fault=fi)
    store = LedgerStore()
    p1 = mem.spawn(0)
    rc = RecoverableClient(table, p1, store.ledger("c"))
    with pytest.raises(ClientCrash):
        rc.try_acquire("k", ttl=10.0)
    view = rc.ledger.replay()
    assert view.live == {} and "k" in view.intents
    clock.advance(2.0)
    p2 = mem.spawn(0)
    got = rc.restart(p2)
    assert [l.key for l in got] == ["k"]
    assert got[0].holder_pid == p2.pid  # adopted under the new incarnation
    assert "k" not in rc.ledger.replay().intents  # intent resolved
    rows = table.telemetry()
    assert sum(r["orphan_adopts"] for r in rows) == 1


def test_orphan_probe_resolves_never_granted_intent():
    # Crash after the intent, before the CAS: the probe finds a free (or
    # stranger-held) word and resolves the intent without adopting.
    fi = FaultInjector().at("ledger.post_intent")
    mem = AsymmetricMemory(4)
    table = ShardedLockTable(mem, num_shards=8, fault=fi)
    store = LedgerStore()
    rc = RecoverableClient(table, mem.spawn(0), store.ledger("c"))
    with pytest.raises(ClientCrash):
        rc.try_acquire("k", ttl=10.0)
    got = rc.restart(mem.spawn(0))
    assert got == []
    assert rc.ledger.replay().intents == {}
    rows = table.telemetry()
    assert sum(r["orphan_probes"] for r in rows) == 1
    assert sum(r["orphan_adopts"] for r in rows) == 0


def test_orphan_probe_never_adopts_a_strangers_lease():
    fi = FaultInjector().at("ledger.post_intent")
    mem = AsymmetricMemory(4)
    table = ShardedLockTable(mem, num_shards=8, fault=fi)
    store = LedgerStore()
    rc = RecoverableClient(table, mem.spawn(0), store.ledger("c"))
    with pytest.raises(ClientCrash):
        rc.try_acquire("k", ttl=60.0)
    stranger = mem.spawn(1)
    s_lease = table.try_acquire(stranger, "k", ttl=60.0)
    assert s_lease is not None
    got = rc.restart(mem.spawn(0))
    assert got == []
    assert table.renew(stranger, s_lease) is not None


# ----------------------------------------------------------- fault injector
def test_fault_injector_nth_and_pid_filters():
    fi = FaultInjector().at("renew.pre_cas", nth=2, pid=7)
    fi.crash_point("renew.pre_cas", 3)   # other pid: not counted
    fi.crash_point("renew.pre_cas", 7)   # pid 7 arrival #1
    with pytest.raises(ClientCrash):
        fi.crash_point("renew.pre_cas", 7)  # arrival #2 fires
    fi.crash_point("renew.pre_cas", 7)   # one-shot: disarmed
    assert fi.fired == [("renew.pre_cas", 7, 3)]
    assert fi.hits["renew.pre_cas"] == 4


def test_fault_injector_seeded_storm_is_reproducible():
    def storm():
        fi = FaultInjector.seeded(11, prob=0.5)
        for i in range(50):
            try:
                fi.crash_point(CRASH_POINTS[i % len(CRASH_POINTS)], i)
            except ClientCrash:
                pass
        return fi.fired

    assert storm() == storm()
    assert storm()  # prob 0.5 over 50 arrivals: fires


def test_fault_injector_rejects_unknown_label():
    with pytest.raises(ValueError):
        FaultInjector().at("nonsense.window")


# --------------------------------------------------------------- engine.kill
def test_engine_kill_delivers_at_next_dispatch():
    engine = SimEngine(seed=0)
    log = []

    def victim():
        while True:
            try:
                yield 1.0
                log.append("step")
            except ClientCrash:
                log.append("crash")
                yield 5.0  # restart pause

    task = engine.spawn(victim())

    def reaper():
        yield 2.5
        engine.kill(task, ClientCrash("host.crash"))

    engine.spawn(reaper())
    engine.run(until=20.0)
    assert "crash" in log
    assert engine.kills == 1
    assert log.index("crash") == 2  # steps at t=1,2 ran before delivery


def test_engine_kill_uncaught_propagates_out_of_run():
    engine = SimEngine(seed=0)

    def victim():
        while True:
            yield 1.0

    task = engine.spawn(victim())
    engine.kill(task, ClientCrash("host.crash"))
    with pytest.raises(ClientCrash):
        engine.run(until=10.0)


# ------------------------------------------------- service + reconstruction
def test_service_restart_reclaims_and_caches():
    clock = FakeClock()
    svc = CoordinationService(num_hosts=4, num_shards=8, clock=clock)
    p1 = svc.host_process(0)
    client = svc.recoverable("worker", p1)
    lease = client.try_acquire("job", ttl=10.0)
    clock.advance(3.0)
    p2 = svc.host_process(0)
    client2, reclaimed = svc.restart("worker", p2)
    assert [l.key for l in reclaimed] == ["job"]
    assert reclaimed[0].token == lease.token
    assert client2.release(reclaimed[0])


def test_reconstruct_shard_reseeds_fence_past_every_witness():
    # Home-host death: rebuild a shard's key registers from the surviving
    # clients' ledgers.  The reconstructed fence must exceed every token
    # any ledger ever witnessed, so no post-reconstruction grant can reuse
    # a token a downstream fencing check may have seen.
    clock = FakeClock()
    mem, table, store = make_stack(num_shards=2, clock=clock)
    rcs = [RecoverableClient(table, mem.spawn(h % 4),
                             store.ledger(f"c{h}")) for h in range(3)]
    keys = [f"key-{i}" for i in range(12)]
    max_token = {}
    for rnd in range(3):
        for i, rc in enumerate(rcs):
            for key in keys[i::3]:
                lease = rc.try_acquire(key, ttl=5.0)
                if lease is not None:
                    max_token[key] = max(max_token.get(key, 0), lease.token)
                    if rnd % 2 == 0:
                        rc.release(lease)
        clock.advance(6.0)  # expire the held ones between rounds
    p = mem.spawn(0)
    for shard_index in range(table.num_shards):
        report = table.reconstruct_shard(p, shard_index,
                                         store.all_records())
        assert set(report) >= {"intact", "fence_repaired", "reset"}
    # Every key's next grant must carry a token beyond anything witnessed.
    clock.advance(100.0)
    g = mem.spawn(1)
    for key in keys:
        lease = table.try_acquire(g, key, ttl=5.0)
        assert lease is not None
        assert lease.token > max_token.get(key, 0)


def test_batch_admission_worker_recovery():
    import threading

    from repro.launch.serve import BatchAdmission

    adm = BatchAdmission(num_slots=2, ttl=60.0)
    box = {}

    def worker():
        box["lease"] = adm.admit(worker="w0")

    t = threading.Thread(target=worker)
    t.start()
    t.join()

    def replacement():
        box["reclaimed"] = adm.recover("w0")

    t2 = threading.Thread(target=replacement)
    t2.start()
    t2.join()
    (lease,), reclaimed = (box["lease"],), box["reclaimed"]
    assert [l.key for l in reclaimed] == [lease.key]
    assert reclaimed[0].token == lease.token  # resumed, not re-queued
    assert adm.complete(reclaimed[0], worker="w0")
    s = adm.stats()
    assert s["reclaims"] == 1 and s["local_rdma_ops"] == 0


# --------------------------------------------------------------- sim smoke
def test_crash_restart_sim_is_deterministic_and_recovers():
    cfg = dict(num_hosts=8, clients_per_host=4, total_ops=2500, seed=3,
               failover_ttl=1e-3, crash_warmup=2e-3, crash_spacing=1e-3 / 8,
               restart_delay=1e-3 / 8)
    a = run_lock_table_sim("crash_restart", **cfg)
    b = run_lock_table_sim("crash_restart", **cfg)
    assert json.dumps(a.row(), sort_keys=True) == \
        json.dumps(b.row(), sort_keys=True)
    assert a.crashes > 0 and a.kills > 0
    assert a.reclaims > 0  # restarts reclaim rather than wait out the TTL
    assert a.recovery_max < 1e-3  # every recovery beat the TTL wedge
    assert a.token_regressions == 0 and a.zombie_renews == 0


def test_crash_restart_amnesiac_baseline_pays_the_wedge():
    cfg = dict(num_hosts=8, clients_per_host=4, total_ops=2500, seed=3,
               failover_ttl=1e-3, crash_warmup=2e-3, crash_spacing=1e-3 / 8,
               restart_delay=1e-3 / 8)
    rec = run_lock_table_sim("crash_restart", reclaim=True, **cfg)
    amn = run_lock_table_sim("crash_restart", reclaim=False, **cfg)
    assert rec.reclaims > 0
    if amn.reclaims:  # the wedge: re-entry waits out expiry + contention
        assert amn.recovery_p99 > rec.recovery_p99
