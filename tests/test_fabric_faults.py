"""Faulty-fabric unit tests: seeded loss plans, op-level timeouts, bounded
retry, the never-blocking probe — and the kill race: a task parked mid-step
on a fabric timeout must die cleanly, leaving no timer behind and no trace
in any later run's event log.
"""

import json

import pytest

from repro.core import TIMEOUT, OpCounts, RemoteTimeout
from repro.coord import ClientCrash, FaultInjector
from repro.sim import SimEngine, run_lock_table_sim
from repro.sim.fabric import FabricFaults, FabricLatency, SimFabricMemory


def test_opcounts_carry_fault_fields():
    c = OpCounts()
    t = c.as_tuple()
    assert len(t) == 9
    c.timeouts += 3
    c.retries += 2
    assert c.as_tuple()[7:] == (3, 2)
    # The fault fields are accounting-only: they do not inflate the
    # paper's per-class RDMA cost claims.
    assert c.rdma_ops == 0 and c.local_ops == 0


class TestFaultPlan:
    def test_cut_until_partition_boundary(self):
        f = FabricFaults(seed=0,
                         partitions=(({0, 1}, 1e-3, 2e-3),))
        # Crossing the boundary inside the window: cut until the heal.
        assert f.cut_until(0, 2, 1.5e-3) == 2e-3
        assert f.cut_until(2, 0, 1.5e-3) == 2e-3
        # Same side (either side), or outside the window: path is up.
        assert f.cut_until(0, 1, 1.5e-3) is None
        assert f.cut_until(2, 3, 1.5e-3) is None
        assert f.cut_until(0, 2, 0.5e-3) is None
        assert f.cut_until(0, 2, 2e-3) is None  # heal instant is healed

    def test_cut_until_flap_and_death(self):
        f = FabricFaults(seed=0, flaps=((1, 1e-3, 2e-3),))
        assert f.cut_until(0, 1, 1.5e-3) == 2e-3
        assert f.cut_until(1, 0, 1.5e-3) == 2e-3
        assert f.cut_until(0, 1, 3e-3) is None
        f.fail_host(1, 4e-3)
        assert f.cut_until(0, 1, 5e-3) == float("inf")
        # Death is one-way: the dead host as SOURCE is the engine's
        # business (its tasks are killed); the fabric cuts the target.
        assert f.cut_until(1, 0, 5e-3) is None

    def test_seeded_draws_are_reproducible(self):
        def draws(seed):
            f = FabricFaults(seed=seed, drop_prob=0.3)
            p = type("P", (), {"node": 0, "pid": 1})()
            return ([f.draw_drop(p, 1, 0.0) for _ in range(64)],
                    [round(f.backoff(i % 7 + 1), 12) for i in range(64)])

        assert draws(5) == draws(5)
        assert draws(5) != draws(6)

    def test_backoff_is_bounded_and_grows(self):
        f = FabricFaults(seed=1, retry_base=25e-6, retry_cap=400e-6)
        for attempt in range(1, 12):
            b = f.backoff(attempt)
            assert 0.5 * 25e-6 <= b <= 1.5 * 400e-6


class TestLossyOps:
    def _fabric(self, seed=0, **kw):
        engine = SimEngine(seed)
        faults = FabricFaults(seed=seed, **kw)
        mem = SimFabricMemory(2, engine, FabricLatency(), faults=faults)
        return engine, faults, mem

    def test_dead_host_raises_after_bounded_retries(self):
        engine, faults, mem = self._fabric()
        reg = mem.alloc(1, "w", 7)
        p = mem.spawn(0)
        faults.fail_host(1, 0.0)
        with pytest.raises(RemoteTimeout):
            mem.rread(p, reg)
        # One initial transmission plus max_retries reposts, each paying
        # one op timeout; the op then fails rather than blocking forever.
        assert p.counts.timeouts == faults.max_retries + 1
        assert p.counts.retries == faults.max_retries
        assert faults.stats["drops"] == faults.max_retries + 1

    def test_transient_cut_blocks_until_heal(self):
        engine, faults, mem = self._fabric(
            partitions=(({0}, 0.0, 2e-3),))
        reg = mem.alloc(1, "w", 7)
        p = mem.spawn(0)
        assert mem.rread(p, reg) == 7    # rides timeouts across the heal
        assert engine.clock.now >= 2e-3
        assert p.counts.timeouts > 0 and p.counts.retries > 0

    def test_probe_never_blocks(self):
        engine, faults, mem = self._fabric()
        reg = mem.alloc(1, "w", 9)
        p = mem.spawn(0)
        faults.fail_host(1, 0.0)
        t0 = engine.clock.now
        assert mem.probe(p, reg) is TIMEOUT
        # Exactly one op-timeout charge, no retries, no exception.
        assert engine.clock.now - t0 == pytest.approx(faults.op_timeout)
        assert p.counts.timeouts == 1 and p.counts.retries == 0
        assert faults.stats["probe_losses"] == 1

    def test_injector_oneshots_hit_exact_postings(self):
        fi = (FaultInjector().at("fabric.drop", nth=2)
                             .at("fabric.dup", nth=3)
                             .at("fabric.delay", nth=4))
        engine, faults, mem = self._fabric(injector=fi)
        reg = mem.alloc(1, "w", 0)
        p = mem.spawn(0)
        for i in range(5):
            mem.rwrite(p, reg, i)
        assert mem.rread(p, reg) == 4
        assert faults.stats["drops"] == 1
        assert faults.stats["dups"] == 1
        assert faults.stats["delays"] == 1
        assert {lab for lab, _p, _n in fi.fired} == {
            "fabric.drop", "fabric.dup", "fabric.delay"}
        # The drop cost the poster a timeout and a repost.
        assert p.counts.timeouts == 1 and p.counts.retries == 1


class TestKillRace:
    """SimEngine.kill racing a task whose current step is parked on a
    fabric timeout (its timeline extended across a partition heal)."""

    @staticmethod
    def _scenario(seed):
        engine = SimEngine(seed)
        faults = FabricFaults(seed=seed,
                              partitions=(({0}, 1e-3, 3e-3),))
        mem = SimFabricMemory(2, engine, FabricLatency(), faults=faults)
        reg = mem.alloc(1, "w", 0)
        p = mem.spawn(0)
        log = []

        def victim():
            try:
                while True:
                    mem.rread(p, reg)
                    log.append(round(engine.clock.now, 9))
                    yield 100e-6
            except ClientCrash:
                log.append(("crashed", round(engine.clock.now, 9)))

        vt = engine.spawn(victim())

        def killer():
            # Land the kill while the victim's in-flight step is still
            # riding timeout+backoff rounds across the cut: delivery must
            # wait for the step boundary, then terminate the task.
            yield 2e-3
            engine.kill(vt, ClientCrash("host.death", pid=0))

        engine.spawn(killer())
        engine.run(until=10e-3)
        return (engine.events, round(engine.clock.now, 9), tuple(log),
                engine.pending_events, engine.live_tasks,
                dict(faults.stats),
                (p.counts.timeouts, p.counts.retries))

    def test_kill_lands_at_step_boundary_and_drains(self):
        events, now, log, pending, live, stats, _ = self._scenario(3)
        assert log and log[-1][0] == "crashed"
        # The blocked step finished (post-heal) before delivery; nothing
        # of the victim survives: no parked timer, no live generator.
        assert log[-1][1] >= 3e-3
        assert pending == 0 and live == 0

    def test_kill_race_is_seed_deterministic(self):
        assert self._scenario(11) == self._scenario(11)

    def test_no_leak_into_the_next_seeds_event_log(self):
        # A later, unrelated seeded run must be byte-identical whether or
        # not the kill race ran first in this process — the engines and
        # fault plans share no hidden global state.
        cfg = dict(num_hosts=4, clients_per_host=2, num_shards=8,
                   total_ops=400, seed=13, failover_ttl=1e-3)
        control = json.dumps(run_lock_table_sim("failover", **cfg).row(),
                             sort_keys=True)
        self._scenario(7)
        after = json.dumps(run_lock_table_sim("failover", **cfg).row(),
                           sort_keys=True)
        assert control == after
